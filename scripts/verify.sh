#!/usr/bin/env bash
# One-command tier-1 verify + perf smoke run.
#
#   scripts/verify.sh            # build, test, fast benches, sweep smoke
#
# The benches write rust/BENCH_hotpath.json (per-op ns, samples/s, and the
# kernel-vs-scalar-baseline speedups measured on this machine),
# rust/BENCH_fleet.json (sequential vs sharded event-loop wall time plus
# the sequential-vs-sharded provisioning split), rust/BENCH_sweep.json
# (naive vs memoized scenario grid), and rust/BENCH_serve.json (serve
# round-trip latency/throughput over loopback TCP); see rust/PERF.md for
# how to read them. Use scripts/bench_check.sh to gate a change on >10 % perf
# regressions against the previous accepted run.

set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
# the parallel-engine determinism contract, explicitly (it is part of the
# suite above too; run again by name so a sharding regression — event
# loop or provisioning — fails loudly and in isolation)
cargo test -q --test fleet_determinism
ODL_BENCH_FAST=1 cargo bench --bench bench_hotpath
ODL_BENCH_FAST=1 cargo bench --bench bench_fleet_scale
ODL_BENCH_FAST=1 cargo bench --bench bench_sweep
ODL_BENCH_FAST=1 cargo bench --bench bench_serve
# million-edge engine smoke: a 100k-edge aggregate-mode fleet end to end
# through the CLI — the time-wheel event loop at scale, with the O(1)
# sketched report (sketch summaries, no per-edge rows) on stdout
fleet_out=$(./target/release/odl-har fleet --config configs/fleet_100k.toml --workers 0)
grep -q "fleet: 100000 edges" <<< "$fleet_out"
grep -q "aggregate: events" <<< "$fleet_out"
# sweep smoke: a TOML-declared grid (incl. the n_hidden/loss/teacher-error
# axes) end to end through the CLI; the results file must contain
# header + 16 cells + stats trailer
./target/release/odl-har sweep --config configs/sweep_smoke.toml --out /tmp/odl_sweep_smoke.jsonl
lines=$(wc -l < /tmp/odl_sweep_smoke.jsonl)
if [[ "$lines" -ne 18 ]]; then
  echo "sweep smoke: expected 18 result lines, got $lines" >&2
  exit 1
fi
# dry-run smoke: the plan printer must enumerate the grid without running
# a cell (and without touching any results file); capture-then-grep avoids
# a SIGPIPE from grep -q under pipefail
dry_out=$(./target/release/odl-har sweep --config configs/sweep_smoke.toml --dry-run)
grep -q "memo plan:" <<< "$dry_out"
grep -q "cell   15" <<< "$dry_out"
# kill-then-resume smoke: truncate the results mid-grid (simulating a
# kill), resume, and require the final file byte-identical to the
# uninterrupted run; resuming the complete file again must be a no-op
head -n 5 /tmp/odl_sweep_smoke.jsonl > /tmp/odl_sweep_resume.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --out /tmp/odl_sweep_resume.jsonl --resume
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_resume.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --out /tmp/odl_sweep_resume.jsonl --resume
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_resume.jsonl
# shard/merge smoke: a 2-way process-level split of the same grid, with
# one shard killed mid-slice and resumed, must merge back byte-identical
# to the single-process file (and --shard 1/1 IS the unsharded stream)
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard 1/2 --out /tmp/odl_sweep_shard1.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard 2/2 --out /tmp/odl_sweep_shard2.jsonl
head -n 4 /tmp/odl_sweep_shard2.jsonl > /tmp/odl_sweep_shard2_cut.jsonl
mv /tmp/odl_sweep_shard2_cut.jsonl /tmp/odl_sweep_shard2.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard 2/2 --out /tmp/odl_sweep_shard2.jsonl --resume
./target/release/odl-har merge --config configs/sweep_smoke.toml --out /tmp/odl_sweep_merged.jsonl \
  /tmp/odl_sweep_shard2.jsonl /tmp/odl_sweep_shard1.jsonl
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_merged.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard 1/1 --out /tmp/odl_sweep_shard11.jsonl
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_shard11.jsonl
# chaos smoke: the self-healing supervisor (--shard auto) with an
# injected mid-run child SIGKILL must relaunch onto --resume, auto-merge,
# and produce bytes identical to the clean single-process run (exit 0)
rm -f /tmp/odl_sweep_chaos.jsonl /tmp/odl_sweep_chaos.shard*.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard auto:2 \
  --retry-budget 3 --inject-faults 7:kill@3 --out /tmp/odl_sweep_chaos.jsonl
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_chaos.jsonl
# exit-code contract: all shards quarantined (torn write every attempt,
# no retry budget) must exit 3; a single quarantined shard must exit 2
rm -f /tmp/odl_sweep_chaos_fail.jsonl /tmp/odl_sweep_chaos_fail.shard*.jsonl
rc=0
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard auto:2 \
  --retry-budget 0 --fault-attempts 9 --inject-faults 7:tear@1 \
  --out /tmp/odl_sweep_chaos_fail.jsonl >/dev/null 2>&1 || rc=$?
if [[ "$rc" -ne 3 ]]; then
  echo "chaos smoke: all-quarantined supervisor run must exit 3, got $rc" >&2
  exit 1
fi
rm -f /tmp/odl_sweep_chaos_deg.jsonl /tmp/odl_sweep_chaos_deg.shard*.jsonl
rc=0
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard auto:2 \
  --retry-budget 0 --fault-attempts 9 --inject-faults "7:tear@1#2" \
  --out /tmp/odl_sweep_chaos_deg.jsonl >/dev/null 2>&1 || rc=$?
if [[ "$rc" -ne 2 ]]; then
  echo "chaos smoke: degraded supervisor run must exit 2, got $rc" >&2
  exit 1
fi
if [[ -f /tmp/odl_sweep_chaos_deg.jsonl ]]; then
  echo "chaos smoke: a degraded run must not publish a merged file" >&2
  exit 1
fi
# storage smoke: a supervised sweep publishing through --storage (the
# local-dir backend: spool == object, heartbeat probes routed through
# the trait) with an injected child kill, then a merge on a "host" with
# no local shard files that hydrates them from the backend — merged
# bytes, backend object, and remerge all identical to the clean run
rm -rf /tmp/odl_sweep_store /tmp/odl_sweep_store_pull
rm -f /tmp/odl_sweep_storage.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard auto:2 \
  --retry-budget 3 --inject-faults 7:kill@3 --storage /tmp/odl_sweep_store \
  --out /tmp/odl_sweep_storage.jsonl
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_storage.jsonl
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_store/odl_sweep_storage.jsonl
mkdir -p /tmp/odl_sweep_store_pull
./target/release/odl-har merge --config configs/sweep_smoke.toml \
  --storage /tmp/odl_sweep_store --out /tmp/odl_sweep_store_pull/remerged.jsonl \
  /tmp/odl_sweep_store_pull/odl_sweep_storage.shard1of2.jsonl \
  /tmp/odl_sweep_store_pull/odl_sweep_storage.shard2of2.jsonl
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_store_pull/remerged.jsonl
# serve smoke: the fault-tolerant teacher service end to end through the
# CLI — ephemeral port, a client killed mid-stream by an injected abort,
# a chaos-schedule rerun that must still deliver everything (the server
# watermark dedups the replayed prefix), then a graceful drain that
# publishes the snapshot
rm -f /tmp/odl_serve_smoke.snap /tmp/odl_serve_smoke.log
./target/release/odl-har serve --config configs/serve_smoke.toml \
  --bind 127.0.0.1:0 --snapshot /tmp/odl_serve_smoke.snap \
  > /tmp/odl_serve_smoke.log &
serve_pid=$!
addr=""
for _ in $(seq 1 100); do
  addr=$(sed -n 's/^serve: listening on //p' /tmp/odl_serve_smoke.log)
  [[ -n "$addr" ]] && break
  sleep 0.05
done
if [[ -z "$addr" ]]; then
  echo "serve smoke: server never printed its ready line" >&2
  exit 1
fi
rc=0
./target/release/odl-har loadgen --connect "$addr" --config configs/serve_smoke.toml \
  --client edge-0 --events 24 --inject-faults 5:kill@5#2 >/dev/null 2>&1 || rc=$?
if [[ "$rc" -eq 0 ]]; then
  echo "serve smoke: the kill schedule must abort the client" >&2
  exit 1
fi
lg_out=$(./target/release/odl-har loadgen --connect "$addr" --config configs/serve_smoke.toml \
  --client edge-0 --events 24 --inject-faults 5:drop@4#2,garble@9#2)
grep -q '"delivered":24' <<< "$lg_out"
# batched frames: a second edge streams its 24 events packed 6 per
# `events` frame — 4 frames on the wire, every event still acked
lg_out=$(./target/release/odl-har loadgen --connect "$addr" --config configs/serve_smoke.toml \
  --client edge-1 --events 24 --batch 6)
grep -q '"delivered":24' <<< "$lg_out"
grep -q '"frames":4' <<< "$lg_out"
./target/release/odl-har loadgen --connect "$addr" --config configs/serve_smoke.toml \
  --client edge-0 --events 0 --shutdown >/dev/null
wait "$serve_pid"
if [[ ! -s /tmp/odl_serve_smoke.snap ]]; then
  echo "serve smoke: the drained server must publish its snapshot" >&2
  exit 1
fi
# restore round-trip: a restarted server loads the snapshot and a second
# drain must re-publish it byte-identically (nothing new was applied)
cp /tmp/odl_serve_smoke.snap /tmp/odl_serve_smoke.snap.orig
rm -f /tmp/odl_serve_smoke.log
./target/release/odl-har serve --config configs/serve_smoke.toml \
  --bind 127.0.0.1:0 --snapshot /tmp/odl_serve_smoke.snap \
  > /tmp/odl_serve_smoke.log &
serve_pid=$!
addr=""
for _ in $(seq 1 100); do
  addr=$(sed -n 's/^serve: listening on //p' /tmp/odl_serve_smoke.log)
  [[ -n "$addr" ]] && break
  sleep 0.05
done
[[ -n "$addr" ]]
./target/release/odl-har loadgen --connect "$addr" --config configs/serve_smoke.toml \
  --client edge-0 --events 0 --shutdown >/dev/null
wait "$serve_pid"
cmp /tmp/odl_serve_smoke.snap /tmp/odl_serve_smoke.snap.orig
# CLI misuse contract: unknown subcommand and missing required args must
# exit non-zero with usage on stderr (stdout stays parseable)
rc=0
./target/release/odl-har frobnicate >/dev/null 2>/tmp/odl_cli_err.log || rc=$?
if [[ "$rc" -eq 0 ]] || ! grep -q "subcommands:" /tmp/odl_cli_err.log; then
  echo "cli smoke: unknown subcommand must fail with usage on stderr" >&2
  exit 1
fi
rc=0
./target/release/odl-har serve >/dev/null 2>/tmp/odl_cli_err.log || rc=$?
if [[ "$rc" -eq 0 ]] || ! grep -q "serve requires --config" /tmp/odl_cli_err.log; then
  echo "cli smoke: serve without --config must fail with usage on stderr" >&2
  exit 1
fi
# the bench_check gate's own fixture suite (no toolchain needed)
../scripts/test_bench_check.sh
echo "verify: OK"
