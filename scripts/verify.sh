#!/usr/bin/env bash
# One-command tier-1 verify + perf smoke run.
#
#   scripts/verify.sh            # build, test, fast benches
#
# The benches write rust/BENCH_hotpath.json (per-op ns, samples/s, and the
# kernel-vs-scalar-baseline speedups measured on this machine) and
# rust/BENCH_fleet.json (sequential vs sharded event-loop wall time); see
# rust/PERF.md for how to read them. Use scripts/bench_check.sh to gate a
# change on >10 % perf regressions against the previous accepted run.

set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
# the parallel-engine determinism contract, explicitly (it is part of the
# suite above too; run again by name so a sharding regression fails loudly
# and in isolation)
cargo test -q --test fleet_determinism
ODL_BENCH_FAST=1 cargo bench --bench bench_hotpath
ODL_BENCH_FAST=1 cargo bench --bench bench_fleet_scale
