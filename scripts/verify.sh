#!/usr/bin/env bash
# One-command tier-1 verify + perf smoke run.
#
#   scripts/verify.sh            # build, test, fast benches, sweep smoke
#
# The benches write rust/BENCH_hotpath.json (per-op ns, samples/s, and the
# kernel-vs-scalar-baseline speedups measured on this machine),
# rust/BENCH_fleet.json (sequential vs sharded event-loop wall time plus
# the sequential-vs-sharded provisioning split), and rust/BENCH_sweep.json
# (naive vs memoized scenario grid); see rust/PERF.md for how to read
# them. Use scripts/bench_check.sh to gate a change on >10 % perf
# regressions against the previous accepted run.

set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
# the parallel-engine determinism contract, explicitly (it is part of the
# suite above too; run again by name so a sharding regression — event
# loop or provisioning — fails loudly and in isolation)
cargo test -q --test fleet_determinism
ODL_BENCH_FAST=1 cargo bench --bench bench_hotpath
ODL_BENCH_FAST=1 cargo bench --bench bench_fleet_scale
ODL_BENCH_FAST=1 cargo bench --bench bench_sweep
# sweep smoke: a TOML-declared grid (incl. the n_hidden/loss/teacher-error
# axes) end to end through the CLI; the results file must contain
# header + 16 cells + stats trailer
./target/release/odl-har sweep --config configs/sweep_smoke.toml --out /tmp/odl_sweep_smoke.jsonl
lines=$(wc -l < /tmp/odl_sweep_smoke.jsonl)
if [[ "$lines" -ne 18 ]]; then
  echo "sweep smoke: expected 18 result lines, got $lines" >&2
  exit 1
fi
# dry-run smoke: the plan printer must enumerate the grid without running
# a cell (and without touching any results file); capture-then-grep avoids
# a SIGPIPE from grep -q under pipefail
dry_out=$(./target/release/odl-har sweep --config configs/sweep_smoke.toml --dry-run)
grep -q "memo plan:" <<< "$dry_out"
grep -q "cell   15" <<< "$dry_out"
# kill-then-resume smoke: truncate the results mid-grid (simulating a
# kill), resume, and require the final file byte-identical to the
# uninterrupted run; resuming the complete file again must be a no-op
head -n 5 /tmp/odl_sweep_smoke.jsonl > /tmp/odl_sweep_resume.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --out /tmp/odl_sweep_resume.jsonl --resume
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_resume.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --out /tmp/odl_sweep_resume.jsonl --resume
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_resume.jsonl
# shard/merge smoke: a 2-way process-level split of the same grid, with
# one shard killed mid-slice and resumed, must merge back byte-identical
# to the single-process file (and --shard 1/1 IS the unsharded stream)
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard 1/2 --out /tmp/odl_sweep_shard1.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard 2/2 --out /tmp/odl_sweep_shard2.jsonl
head -n 4 /tmp/odl_sweep_shard2.jsonl > /tmp/odl_sweep_shard2_cut.jsonl
mv /tmp/odl_sweep_shard2_cut.jsonl /tmp/odl_sweep_shard2.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard 2/2 --out /tmp/odl_sweep_shard2.jsonl --resume
./target/release/odl-har merge --config configs/sweep_smoke.toml --out /tmp/odl_sweep_merged.jsonl \
  /tmp/odl_sweep_shard2.jsonl /tmp/odl_sweep_shard1.jsonl
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_merged.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard 1/1 --out /tmp/odl_sweep_shard11.jsonl
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_shard11.jsonl
# chaos smoke: the self-healing supervisor (--shard auto) with an
# injected mid-run child SIGKILL must relaunch onto --resume, auto-merge,
# and produce bytes identical to the clean single-process run (exit 0)
rm -f /tmp/odl_sweep_chaos.jsonl /tmp/odl_sweep_chaos.shard*.jsonl
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard auto:2 \
  --retry-budget 3 --inject-faults 7:kill@3 --out /tmp/odl_sweep_chaos.jsonl
cmp /tmp/odl_sweep_smoke.jsonl /tmp/odl_sweep_chaos.jsonl
# exit-code contract: all shards quarantined (torn write every attempt,
# no retry budget) must exit 3; a single quarantined shard must exit 2
rm -f /tmp/odl_sweep_chaos_fail.jsonl /tmp/odl_sweep_chaos_fail.shard*.jsonl
rc=0
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard auto:2 \
  --retry-budget 0 --fault-attempts 9 --inject-faults 7:tear@1 \
  --out /tmp/odl_sweep_chaos_fail.jsonl >/dev/null 2>&1 || rc=$?
if [[ "$rc" -ne 3 ]]; then
  echo "chaos smoke: all-quarantined supervisor run must exit 3, got $rc" >&2
  exit 1
fi
rm -f /tmp/odl_sweep_chaos_deg.jsonl /tmp/odl_sweep_chaos_deg.shard*.jsonl
rc=0
./target/release/odl-har sweep --config configs/sweep_smoke.toml --shard auto:2 \
  --retry-budget 0 --fault-attempts 9 --inject-faults "7:tear@1#2" \
  --out /tmp/odl_sweep_chaos_deg.jsonl >/dev/null 2>&1 || rc=$?
if [[ "$rc" -ne 2 ]]; then
  echo "chaos smoke: degraded supervisor run must exit 2, got $rc" >&2
  exit 1
fi
if [[ -f /tmp/odl_sweep_chaos_deg.jsonl ]]; then
  echo "chaos smoke: a degraded run must not publish a merged file" >&2
  exit 1
fi
# the bench_check gate's own fixture suite (no toolchain needed)
../scripts/test_bench_check.sh
echo "verify: OK"
