#!/usr/bin/env bash
# One-command tier-1 verify + perf smoke run.
#
#   scripts/verify.sh            # build, test, fast benches, sweep smoke
#
# The benches write rust/BENCH_hotpath.json (per-op ns, samples/s, and the
# kernel-vs-scalar-baseline speedups measured on this machine),
# rust/BENCH_fleet.json (sequential vs sharded event-loop wall time plus
# the sequential-vs-sharded provisioning split), and rust/BENCH_sweep.json
# (naive vs memoized scenario grid); see rust/PERF.md for how to read
# them. Use scripts/bench_check.sh to gate a change on >10 % perf
# regressions against the previous accepted run.

set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
# the parallel-engine determinism contract, explicitly (it is part of the
# suite above too; run again by name so a sharding regression — event
# loop or provisioning — fails loudly and in isolation)
cargo test -q --test fleet_determinism
ODL_BENCH_FAST=1 cargo bench --bench bench_hotpath
ODL_BENCH_FAST=1 cargo bench --bench bench_fleet_scale
ODL_BENCH_FAST=1 cargo bench --bench bench_sweep
# sweep smoke: a TOML-declared grid end to end through the CLI; the
# results file must contain header + 4 cells + stats trailer
./target/release/odl-har sweep --config configs/sweep_smoke.toml --out /tmp/odl_sweep_smoke.jsonl
lines=$(wc -l < /tmp/odl_sweep_smoke.jsonl)
if [[ "$lines" -ne 6 ]]; then
  echo "sweep smoke: expected 6 result lines, got $lines" >&2
  exit 1
fi
echo "verify: OK"
