#!/usr/bin/env bash
# One-command tier-1 verify + perf smoke run.
#
#   scripts/verify.sh            # build, test, fast hot-path bench
#
# The bench writes rust/BENCH_hotpath.json (per-op ns, samples/s, and the
# kernel-vs-scalar-baseline speedups measured on this machine); see
# rust/PERF.md for how to read it.

set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
ODL_BENCH_FAST=1 cargo bench --bench bench_hotpath
