#!/usr/bin/env bash
# Fixture tests for scripts/bench_check.sh — runnable without a Rust
# toolchain (SKIP_BENCH=1 compares existing JSONs only; BENCH_DIR points
# the gate at a throwaway fixture directory).
#
#   scripts/test_bench_check.sh
#
# Covers the graceful-degradation paths (missing, empty, and corrupt
# bench/baseline files must warn and skip — a fresh tree seeds baselines,
# it never fails) and each gate (baseline-relative memo_speedup /
# edge_memo_speedup, the serve throughput_eps / p99_ms pair plus the v2
# 64-client and batch-16 points and the absolute batch_speedup_64c >= 2
# floor, the fleet events_per_sec @ 100k aggregate throughput point,
# absolute resume_overhead_frac / edge_hit_rate / edge_memo_speedup /
# supervise_overhead_frac floors and ceilings).

set -euo pipefail
here="$(cd "$(dirname "$0")" && pwd)"
check="$here/bench_check.sh"
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

pass=0
fail=0

# run_case NAME EXPECTED_EXIT GREP_PATTERN
run_case() {
  local name="$1" want="$2" pattern="$3"
  local out rc=0
  out=$(SKIP_BENCH=1 BENCH_DIR="$tmp" bash "$check" 2>&1) || rc=$?
  if [[ "$rc" -ne "$want" ]]; then
    echo "FAIL $name: exit $rc (wanted $want)"
    echo "$out" | sed 's/^/    /'
    fail=$((fail + 1))
    return
  fi
  if ! grep -q "$pattern" <<<"$out"; then
    echo "FAIL $name: output missing pattern '$pattern'"
    echo "$out" | sed 's/^/    /'
    fail=$((fail + 1))
    return
  fi
  echo "ok   $name"
  pass=$((pass + 1))
}

sweep_json() {
  # sweep_json MEMO_SPEEDUP RESUME_FRAC EDGE_HIT_RATE EDGE_MEMO_SPEEDUP SUPERVISE_FRAC
  printf '{"schema":"bench_sweep/v4","memo_speedup":%s,"resume_overhead_frac":%s,"edge_hit_rate":%s,"edge_memo_speedup":%s,"supervise_overhead_frac":%s}' \
    "$1" "$2" "$3" "$4" "$5"
}

serve_json() {
  # serve_json THROUGHPUT_EPS P99_MS
  printf '{"schema":"bench_serve/v1","throughput_eps":%s,"p50_ms":0.05,"p99_ms":%s}' \
    "$1" "$2"
}

serve_v2_json() {
  # serve_v2_json THROUGHPUT_EPS P99_MS C64_TP C64_P99 C64B16_TP SPEEDUP
  # (the legacy 64-client thread-per-conn point rides along as a healthy
  # constant; batch_speedup_64c is supplied, not derived, so the absolute
  # gate can be exercised independently)
  printf '{"schema":"bench_serve/v2","throughput_eps":%s,"p50_ms":0.05,"p99_ms":%s,"c64":{"clients":64,"batch":1,"throughput_eps":%s,"p99_ms":%s},"c64_b16":{"clients":64,"batch":16,"throughput_eps":%s,"p99_ms":0.8},"c64_legacy":{"clients":64,"batch":1,"throughput_eps":30000,"p99_ms":4.0},"batch_speedup_64c":%s}' \
    "$1" "$2" "$3" "$4" "$5" "$6"
}

fleet_json() {
  # fleet_json EVENTS_PER_SEC_AT_100K — the 8/64/256 rows carry healthy
  # constants; only the 100k aggregate throughput point varies
  printf '{"schema":"bench_fleet/v1","results":[{"edges":256,"speedup_loop":3.0,"seq_loop_s":1.0,"provision_speedup":4.0,"provision_ms":50.0},{"edges":100000,"metrics":"aggregate","events_per_sec":%s}]}' \
    "$1"
}

# 1. fresh tree: nothing measured at all — degrade, never fail
run_case "fresh tree (all JSONs missing)" 0 "BENCH_sweep.json missing"

# 2. empty bench file (the current bench trajectory): warn + skip + pass
: > "$tmp/BENCH_sweep.json"
run_case "empty BENCH_sweep.json" 0 "BENCH_sweep.json is empty"

# 3. corrupt bench file: warn + skip + pass
echo '{"schema": truncated' > "$tmp/BENCH_sweep.json"
run_case "corrupt BENCH_sweep.json" 0 "unreadable"

# 4. first healthy run, no baseline yet: accepted as baseline
sweep_json 2.0 0.05 0.8 3.0 0.05 > "$tmp/BENCH_sweep.json"
run_case "first run seeds baseline" 0 "first run, accepting as baseline"

# 5. empty baseline file: treated as a first run, not a crash
: > "$tmp/BENCH_sweep.prev.json"
run_case "empty baseline degrades to first run" 0 "BENCH_sweep.prev.json is empty"

# 6. healthy numbers vs a healthy baseline: PASS
sweep_json 2.0 0.05 0.8 3.0 0.05 > "$tmp/BENCH_sweep.prev.json"
run_case "healthy vs baseline" 0 "bench_check: PASS"

# 7. memo_speedup regression (>10% below baseline): FAIL
sweep_json 1.0 0.05 0.8 3.0 0.05 > "$tmp/BENCH_sweep.json"
run_case "memo_speedup regression fails" 1 "sweep:memo_speedup.*REGRESSION"

# 8. edge_memo_speedup regression vs baseline: FAIL
sweep_json 2.0 0.05 0.8 2.0 0.05 > "$tmp/BENCH_sweep.json"
run_case "edge_memo_speedup regression fails" 1 "sweep:edge_memo_speedup.*REGRESSION"

# 9. absolute resume gate: a resumed-complete run must be ~free
sweep_json 2.0 0.50 0.8 3.0 0.05 > "$tmp/BENCH_sweep.json"
run_case "resume_overhead_frac gate fails" 1 "sweep:resume_overhead_frac.*REGRESSION"

# 10. absolute edge-hit-rate floor: the memo must engage
sweep_json 2.0 0.05 0.2 3.0 0.05 > "$tmp/BENCH_sweep.json"
run_case "edge_hit_rate floor fails" 1 "sweep:edge_hit_rate.*REGRESSION"

# 11. absolute edge wall-clock floor (0.9 = 1.0 minus the shared noise
# tolerance): a memo that clearly loses wall clock must fail
sweep_json 2.0 0.05 0.8 0.85 0.05 > "$tmp/BENCH_sweep.json"
run_case "edge_memo_speedup floor fails" 1 "sweep:edge_memo_speedup.*REGRESSION"
# 11b. and a within-noise 0.95 passes the floor (the relative gate is
# judged against its own baseline, here equal)
sweep_json 2.0 0.05 0.8 0.95 0.05 > "$tmp/BENCH_sweep.json"
sweep_json 2.0 0.05 0.8 0.95 0.05 > "$tmp/BENCH_sweep.prev.json"
run_case "within-noise speedup passes floor" 0 "bench_check: PASS"
sweep_json 2.0 0.05 0.8 3.0 0.05 > "$tmp/BENCH_sweep.prev.json"

# 12. an old bench JSON without the edge metrics: skip those gates
printf '{"schema":"bench_sweep/v2","memo_speedup":2.0,"resume_overhead_frac":0.05}' \
  > "$tmp/BENCH_sweep.json"
run_case "pre-v3 bench JSON skips edge gates" 0 "edge_hit_rate not measured"

# 12b. absolute supervise ceiling: the fault-free --shard auto supervisor
# must cost <= 15% over a single-process run of the same grid
sweep_json 2.0 0.05 0.8 3.0 0.50 > "$tmp/BENCH_sweep.json"
run_case "supervise_overhead_frac gate fails" 1 "sweep:supervise_overhead_frac.*REGRESSION"

# 12c. a v3-era bench JSON without the supervise metric skips that gate
printf '{"schema":"bench_sweep/v3","memo_speedup":2.0,"resume_overhead_frac":0.05,"edge_hit_rate":0.8,"edge_memo_speedup":3.0}' \
  > "$tmp/BENCH_sweep.json"
run_case "pre-v4 bench JSON skips supervise gate" 0 "supervise_overhead_frac not measured"

# 12d. serve gates: healthy vs baseline passes; a throughput drop or a
# p99 increase beyond the tolerance fails (p99 is lower-is-better — the
# direction must be inverted, which these two cases pin)
serve_json 20000 0.20 > "$tmp/BENCH_serve.json"
serve_json 20000 0.20 > "$tmp/BENCH_serve.prev.json"
run_case "healthy serve vs baseline" 0 "bench_check: PASS"
serve_json 10000 0.20 > "$tmp/BENCH_serve.json"
run_case "serve throughput regression fails" 1 "serve:throughput_eps.*REGRESSION"
serve_json 20000 0.40 > "$tmp/BENCH_serve.json"
run_case "serve p99 regression fails" 1 "serve:p99_ms.*REGRESSION"
serve_json 22000 0.19 > "$tmp/BENCH_serve.json"
run_case "serve improvement passes" 0 "bench_check: PASS"
rm -f "$tmp/BENCH_serve.json" "$tmp/BENCH_serve.prev.json"

# 12d2. serve v2 gates: the 64-client and batch-16 points are tracked
# baseline-relative; batch_speedup_64c carries an absolute >= 2.0 floor
serve_v2_json 20000 0.20 60000 2.0 120000 4.0 > "$tmp/BENCH_serve.json"
serve_v2_json 20000 0.20 60000 2.0 120000 4.0 > "$tmp/BENCH_serve.prev.json"
run_case "healthy serve v2 vs baseline" 0 "serve:c64.throughput_eps.*ok"
serve_v2_json 20000 0.20 40000 2.0 120000 4.0 > "$tmp/BENCH_serve.json"
run_case "serve 64-client throughput regression fails" 1 "serve:c64.throughput_eps.*REGRESSION"
serve_v2_json 20000 0.20 60000 2.0 80000 4.0 > "$tmp/BENCH_serve.json"
run_case "serve batch-16 throughput regression fails" 1 "serve:c64_b16.throughput_eps.*REGRESSION"
serve_v2_json 20000 0.20 60000 2.0 120000 1.5 > "$tmp/BENCH_serve.json"
run_case "batch_speedup_64c floor fails" 1 "serve:batch_speedup_64c.*REGRESSION"
# a v1-era fresh JSON against a v2 baseline skips the v2-only gates
# instead of failing (and the absolute floor skips when unmeasured)
serve_json 20000 0.20 > "$tmp/BENCH_serve.json"
run_case "v1 serve JSON skips v2 gates" 0 "serve:c64.throughput_eps not comparable"
run_case "v1 serve JSON skips speedup floor" 0 "serve:batch_speedup_64c not measured"
rm -f "$tmp/BENCH_serve.json" "$tmp/BENCH_serve.prev.json"

# 12e. fleet gates: the 100k-edge aggregate throughput point is tracked
# baseline-relative like the rest of the fleet family — healthy passes,
# a >10% events_per_sec drop fails, and an old bench JSON without the
# 100k row skips the gate instead of failing
fleet_json 3000000 > "$tmp/BENCH_fleet.json"
fleet_json 3000000 > "$tmp/BENCH_fleet.prev.json"
run_case "healthy fleet 100k point vs baseline" 0 "bench_check: PASS"
fleet_json 2000000 > "$tmp/BENCH_fleet.json"
run_case "fleet events_per_sec@100k regression fails" 1 "fleet:events_per_sec@100kedges.*REGRESSION"
printf '{"schema":"bench_fleet/v1","results":[{"edges":256,"speedup_loop":3.0,"seq_loop_s":1.0,"provision_speedup":4.0,"provision_ms":50.0}]}' \
  > "$tmp/BENCH_fleet.json"
run_case "pre-100k fleet JSON skips the gate" 0 "fleet:events_per_sec@100kedges not comparable"
rm -f "$tmp/BENCH_fleet.json" "$tmp/BENCH_fleet.prev.json"

# 13. a bench-run invocation (REQUIRE_FRESH=1) must FAIL on a missing
# fresh measurement — write failures cannot hide regressions
rm -f "$tmp"/BENCH_*.json "$tmp"/BENCH_*.prev.json
out=$(SKIP_BENCH=1 REQUIRE_FRESH=1 BENCH_DIR="$tmp" bash "$check" 2>&1) && rc=0 || rc=$?
if [[ "$rc" -eq 1 ]] && grep -q "missing-results" <<<"$out"; then
  echo "ok   missing fresh measurement fails when benches ran"
  pass=$((pass + 1))
else
  echo "FAIL missing fresh measurement must fail when benches ran (rc=$rc)"
  echo "$out" | sed 's/^/    /'
  fail=$((fail + 1))
fi

# 14. and passes again once the fresh measurements exist (every bench
# family, BENCH_serve.json included, must be present under REQUIRE_FRESH)
sweep_json 2.0 0.05 0.8 3.0 0.05 > "$tmp/BENCH_sweep.json"
serve_json 20000 0.20 > "$tmp/BENCH_serve.json"
printf '{"schema":"bench_hotpath/v1","speedup_vs_baseline":{}}' > "$tmp/BENCH_hotpath.json"
printf '{"schema":"bench_fleet/v1","results":[]}' > "$tmp/BENCH_fleet.json"
out=$(SKIP_BENCH=1 REQUIRE_FRESH=1 BENCH_DIR="$tmp" bash "$check" 2>&1) && rc=0 || rc=$?
if [[ "$rc" -eq 0 ]] && grep -q "bench_check: PASS" <<<"$out"; then
  echo "ok   fresh measurements satisfy REQUIRE_FRESH"
  pass=$((pass + 1))
else
  echo "FAIL fresh measurements should pass under REQUIRE_FRESH (rc=$rc)"
  echo "$out" | sed 's/^/    /'
  fail=$((fail + 1))
fi
rm -f "$tmp"/BENCH_hotpath.json "$tmp"/BENCH_fleet.json "$tmp"/BENCH_serve.json

# 15. compare-only mode never rotates baselines
sweep_json 2.0 0.05 0.8 3.0 0.05 > "$tmp/BENCH_sweep.json"
rm -f "$tmp/BENCH_sweep.prev.json"
SKIP_BENCH=1 BENCH_DIR="$tmp" bash "$check" > /dev/null 2>&1
if [[ -f "$tmp/BENCH_sweep.prev.json" ]]; then
  echo "FAIL compare-only must not rotate baselines"
  fail=$((fail + 1))
else
  echo "ok   compare-only does not rotate baselines"
  pass=$((pass + 1))
fi

echo "test_bench_check: $pass passed, $fail failed"
[[ "$fail" -eq 0 ]]
