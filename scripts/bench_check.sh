#!/usr/bin/env bash
# Perf regression gate: re-runs the self-measuring benches and compares
# BENCH_hotpath.json / BENCH_fleet.json / BENCH_sweep.json /
# BENCH_serve.json against the previous accepted run
# (kept next to them as BENCH_<name>.prev.json). Fails on a >10 %
# regression of any tracked metric; on success rotates the fresh numbers
# in as the new baseline.
#
#   scripts/bench_check.sh                 # bench + compare + rotate
#   SKIP_BENCH=1 scripts/bench_check.sh    # compare existing JSONs only
#   BENCH_DIR=/path scripts/bench_check.sh # read/rotate JSONs there
#                                          # (fixture tests use this)
#
# Missing, empty, or unparseable JSONs degrade gracefully: a fresh tree
# (no bench has ever run) or a half-seeded baseline set warns and skips
# those comparisons instead of failing — the first toolchain run seeds
# the baselines. A damaged file is never rotated in as a baseline.
#
# Tracked metrics (baseline-relative):
#   hotpath: speedup_vs_baseline.{predict,train_step}_561_128_6,
#            train_step_561_256_6             (higher is better)
#   fleet:   speedup_loop @ 256 edges         (higher is better)
#            seq_loop_s   @ 256 edges         (lower is better)
#            provision_speedup @ 256 edges    (higher is better)
#            provision_ms @ 256 edges         (lower is better)
#            events_per_sec @ 100k edges      (higher is better; the
#            aggregate-mode time-wheel throughput point)
#   sweep:   memo_speedup                     (higher is better)
#            edge_memo_speedup                (higher is better)
#   serve:   throughput_eps                   (higher is better)
#            p99_ms                           (lower is better)
#            c64.throughput_eps               (higher is better; 64-client
#            connection-scaling point on the shard worker pool)
#            c64.p99_ms                       (lower is better)
#            c64_b16.throughput_eps           (higher is better; 64 clients
#            sending batched `events` frames of 16)
#
# Absolute gates (not baseline-relative):
#   serve:   batch_speedup_64c >= 2.0 — at 64 clients, the batched pool
#            engine must be at least 2x the unbatched thread-per-
#            connection baseline measured in the same bench run
#   sweep:   resume_overhead_frac <= 0.20 — resuming an already complete
#            results file must be ~free (parse + verify, no cells run)
#   sweep:   edge_hit_rate >= 0.5 — the edge-state memo must engage on
#            the bench's edge_counts-heavy grid (plan-derived, exact)
#   sweep:   edge_memo_speedup >= 0.9 — sharing provisioned cores must
#            be a wall-clock win; the floor carries the same 10%
#            tolerance as the relative gates because it compares two
#            noisy timings (the baseline-relative gate above still
#            catches sustained drift, and the expected value on the
#            bench grid is several x)
#   sweep:   supervise_overhead_frac <= 0.15 — the fault-free --shard
#            auto supervisor (child processes + heartbeat polling +
#            auto-merge) must cost at most 15% over a single-process
#            run of the same grid

set -euo pipefail
cd "${BENCH_DIR:-"$(dirname "$0")/../rust"}"

if [[ "${SKIP_BENCH:-0}" != "1" ]]; then
  ODL_BENCH_FAST=1 cargo bench --bench bench_hotpath
  ODL_BENCH_FAST=1 cargo bench --bench bench_fleet_scale
  ODL_BENCH_FAST=1 cargo bench --bench bench_sweep
  ODL_BENCH_FAST=1 cargo bench --bench bench_serve
fi

# When the benches just ran (not SKIP_BENCH), a missing/empty fresh JSON
# means a bench failed to write its results — that must FAIL, not skip;
# the graceful degradation is for baselines and for compare-only mode on
# a fresh tree. REQUIRE_FRESH is overridable for the fixture tests.
if [[ "${SKIP_BENCH:-0}" != "1" ]]; then
  export REQUIRE_FRESH="${REQUIRE_FRESH:-1}"
else
  export REQUIRE_FRESH="${REQUIRE_FRESH:-0}"
fi

python3 - <<'PY'
import json, os, sys

TOL = 0.10
REQUIRE_FRESH = os.environ.get("REQUIRE_FRESH") == "1"
failures = []

def load(path):
    """Parse a bench JSON; None (with a warning) when missing/empty/corrupt."""
    if not os.path.exists(path):
        print(f"bench_check: {path} missing — skipping its checks")
        return None
    try:
        with open(path) as f:
            text = f.read()
        if not text.strip():
            print(f"bench_check: {path} is empty — skipping its checks")
            return None
        return json.loads(text)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_check: {path} unreadable ({e}) — skipping its checks")
        return None

def check(name, new_path, prev_path, metrics):
    """Compare fresh numbers against the baseline; returns the parsed
    fresh JSON (or None) so callers needing it don't re-load/re-warn."""
    new = load(new_path)
    if new is None:
        if REQUIRE_FRESH:
            # the benches just ran: a missing fresh measurement is a bench
            # failure, not a fresh tree — do not let regressions hide
            print(f"bench_check: {new_path} expected after a bench run")
            failures.append(f"{name}:missing-results")
        # compare-only mode on a fresh tree degrades gracefully
        return None
    prev = load(prev_path)
    if prev is None:
        print(f"bench_check: no usable {prev_path} — first run, accepting as baseline")
        return new
    for label, getter, higher_is_better in metrics:
        try:
            a, b = getter(prev), getter(new)
        except Exception:
            a = b = None
        if a is None or b is None or a <= 0 or b <= 0:
            print(f"bench_check: {name}:{label} not comparable, skipping")
            continue
        ratio = (b / a) if higher_is_better else (a / b)
        status = "ok" if ratio >= 1.0 - TOL else "REGRESSION"
        print(f"bench_check: {name}:{label} prev={a:.4g} new={b:.4g} [{status}]")
        if status != "ok":
            failures.append(f"{name}:{label}")
    return new

def hot_speedup(key):
    return lambda d: d.get("speedup_vs_baseline", {}).get(key)

def fleet_metric(edges, key):
    def get(d):
        for row in d.get("results", []):
            if row.get("edges") == edges:
                return row.get(key)
        return None
    return get

check("hotpath", "BENCH_hotpath.json", "BENCH_hotpath.prev.json", [
    ("predict_561_128_6", hot_speedup("predict_561_128_6"), True),
    ("train_step_561_128_6", hot_speedup("train_step_561_128_6"), True),
    ("train_step_561_256_6", hot_speedup("train_step_561_256_6"), True),
])
check("fleet", "BENCH_fleet.json", "BENCH_fleet.prev.json", [
    ("speedup_loop@256edges", fleet_metric(256, "speedup_loop"), True),
    ("seq_loop_s@256edges", fleet_metric(256, "seq_loop_s"), False),
    ("provision_speedup@256edges", fleet_metric(256, "provision_speedup"), True),
    ("provision_ms@256edges", fleet_metric(256, "provision_ms"), False),
    ("events_per_sec@100kedges", fleet_metric(100000, "events_per_sec"), True),
])
sweep = check("sweep", "BENCH_sweep.json", "BENCH_sweep.prev.json", [
    ("memo_speedup", lambda d: d.get("memo_speedup"), True),
    ("edge_memo_speedup", lambda d: d.get("edge_memo_speedup"), True),
])
def serve_point(point, key):
    return lambda d: d.get(point, {}).get(key)

serve = check("serve", "BENCH_serve.json", "BENCH_serve.prev.json", [
    ("throughput_eps", lambda d: d.get("throughput_eps"), True),
    ("p99_ms", lambda d: d.get("p99_ms"), False),
    # v2 multi-client points; "not comparable" against v1 baselines,
    # which lack the nested objects — the first v2 rotation arms them
    ("c64.throughput_eps", serve_point("c64", "throughput_eps"), True),
    ("c64.p99_ms", serve_point("c64", "p99_ms"), False),
    ("c64_b16.throughput_eps", serve_point("c64_b16", "throughput_eps"), True),
])

# absolute gates: thresholds a fresh run must clear on its own, no
# baseline involved
def absolute_gate(family, d, key, limit, higher_is_better):
    v = d.get(key)
    if v is None:
        print(f"bench_check: {family}:{key} not measured (old bench?), skipping")
        return
    ok = v >= limit if higher_is_better else v <= limit
    bound = ">=" if higher_is_better else "<="
    if ok:
        print(f"bench_check: {family}:{key} {v:.3f} [ok {bound} {limit}]")
    else:
        print(f"bench_check: {family}:{key} {v:.3f} [REGRESSION not {bound} {limit}]")
        failures.append(f"{family}:{key}")

# sweep engine: the resumed-complete run skips every cell (so it must be
# ~free), the edge-state memo must engage (plan-derived hit rate) and
# must be a real wall-clock win
if sweep is not None:
    absolute_gate("sweep", sweep, "resume_overhead_frac", 0.20, False)
    absolute_gate("sweep", sweep, "edge_hit_rate", 0.5, True)
    # wall-clock floor with the shared 10% noise tolerance (expected
    # value on the bench grid is several x; the relative gate catches
    # sustained drift)
    absolute_gate("sweep", sweep, "edge_memo_speedup", 1.0 - TOL, True)
    # self-healing supervision must be ~free when nothing fails
    absolute_gate("sweep", sweep, "supervise_overhead_frac", 0.15, False)

# serve engine: batching at 64 clients must beat the unbatched
# thread-per-connection baseline measured in the same bench run by >= 2x
if serve is not None:
    absolute_gate("serve", serve, "batch_speedup_64c", 2.0, True)

if failures:
    print("bench_check: FAIL (regression): " + ", ".join(failures))
    sys.exit(1)
print("bench_check: PASS")
PY

# compare-only mode must not accept numbers it did not measure: rotating
# here would let repeated <=10% regressions compound into the baseline
if [[ "${SKIP_BENCH:-0}" == "1" ]]; then
  echo "bench_check: SKIP_BENCH=1 — compare only, baselines NOT rotated"
  exit 0
fi
for f in BENCH_hotpath.json BENCH_fleet.json BENCH_sweep.json BENCH_serve.json; do
  # never rotate a missing, empty, or unparseable file in as a baseline —
  # a damaged baseline would demote its metric family to "first run" on
  # every later invocation and hide regressions for good
  if [[ -s "$f" ]] && python3 -c 'import json,sys; json.load(open(sys.argv[1]))' "$f" 2>/dev/null; then
    cp "$f" "${f%.json}.prev.json"
  else
    echo "bench_check: $f missing, empty, or unparseable — baseline not rotated"
  fi
done
echo "bench_check: baselines rotated (*.prev.json)"
