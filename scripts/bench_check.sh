#!/usr/bin/env bash
# Perf regression gate: re-runs the self-measuring benches and compares
# BENCH_hotpath.json / BENCH_fleet.json / BENCH_sweep.json against the
# previous accepted run
# (kept next to them as BENCH_<name>.prev.json). Fails on a >10 %
# regression of any tracked metric; on success rotates the fresh numbers
# in as the new baseline.
#
#   scripts/bench_check.sh                 # bench + compare + rotate
#   SKIP_BENCH=1 scripts/bench_check.sh    # compare existing JSONs only
#
# Tracked metrics:
#   hotpath: speedup_vs_baseline.{predict,train_step}_561_128_6,
#            train_step_561_256_6             (higher is better)
#   fleet:   speedup_loop @ 256 edges         (higher is better)
#            seq_loop_s   @ 256 edges         (lower is better)
#            provision_speedup @ 256 edges    (higher is better)
#            provision_ms @ 256 edges         (lower is better)
#   sweep:   memo_speedup                     (higher is better)
#
# Absolute gates (not baseline-relative):
#   sweep:   resume_overhead_frac <= 0.20 — resuming an already complete
#            results file must be ~free (parse + verify, no cells run)

set -euo pipefail
cd "$(dirname "$0")/../rust"

if [[ "${SKIP_BENCH:-0}" != "1" ]]; then
  ODL_BENCH_FAST=1 cargo bench --bench bench_hotpath
  ODL_BENCH_FAST=1 cargo bench --bench bench_fleet_scale
  ODL_BENCH_FAST=1 cargo bench --bench bench_sweep
fi

python3 - <<'PY'
import json, os, sys

TOL = 0.10
failures = []

def load(path):
    with open(path) as f:
        return json.load(f)

def check(name, new_path, prev_path, metrics):
    if not os.path.exists(new_path):
        print(f"bench_check: {new_path} missing (bench not run?)")
        sys.exit(2)
    if not os.path.exists(prev_path):
        print(f"bench_check: no {prev_path} — first run, accepting as baseline")
        return
    new, prev = load(new_path), load(prev_path)
    for label, getter, higher_is_better in metrics:
        try:
            a, b = getter(prev), getter(new)
        except Exception:
            a = b = None
        if a is None or b is None or a <= 0 or b <= 0:
            print(f"bench_check: {name}:{label} not comparable, skipping")
            continue
        ratio = (b / a) if higher_is_better else (a / b)
        status = "ok" if ratio >= 1.0 - TOL else "REGRESSION"
        print(f"bench_check: {name}:{label} prev={a:.4g} new={b:.4g} [{status}]")
        if status != "ok":
            failures.append(f"{name}:{label}")

def hot_speedup(key):
    return lambda d: d.get("speedup_vs_baseline", {}).get(key)

def fleet_metric(edges, key):
    def get(d):
        for row in d.get("results", []):
            if row.get("edges") == edges:
                return row.get(key)
        return None
    return get

check("hotpath", "BENCH_hotpath.json", "BENCH_hotpath.prev.json", [
    ("predict_561_128_6", hot_speedup("predict_561_128_6"), True),
    ("train_step_561_128_6", hot_speedup("train_step_561_128_6"), True),
    ("train_step_561_256_6", hot_speedup("train_step_561_256_6"), True),
])
check("fleet", "BENCH_fleet.json", "BENCH_fleet.prev.json", [
    ("speedup_loop@256edges", fleet_metric(256, "speedup_loop"), True),
    ("seq_loop_s@256edges", fleet_metric(256, "seq_loop_s"), False),
    ("provision_speedup@256edges", fleet_metric(256, "provision_speedup"), True),
    ("provision_ms@256edges", fleet_metric(256, "provision_ms"), False),
])
check("sweep", "BENCH_sweep.json", "BENCH_sweep.prev.json", [
    ("memo_speedup", lambda d: d.get("memo_speedup"), True),
])

# absolute resume gate: a resumed-complete run skips every cell, so its
# cost must be a small fraction of a full file run on any machine
RESUME_TOL = 0.20
sweep = load("BENCH_sweep.json")
frac = sweep.get("resume_overhead_frac")
if frac is None:
    print("bench_check: sweep:resume_overhead_frac not measured (old bench?), skipping")
elif frac > RESUME_TOL:
    print(f"bench_check: sweep:resume_overhead_frac {frac:.3f} [REGRESSION > {RESUME_TOL}]")
    failures.append("sweep:resume_overhead_frac")
else:
    print(f"bench_check: sweep:resume_overhead_frac {frac:.3f} [ok]")

if failures:
    print("bench_check: FAIL (>10% regression): " + ", ".join(failures))
    sys.exit(1)
print("bench_check: PASS")
PY

# compare-only mode must not accept numbers it did not measure: rotating
# here would let repeated <=10% regressions compound into the baseline
if [[ "${SKIP_BENCH:-0}" == "1" ]]; then
  echo "bench_check: SKIP_BENCH=1 — compare only, baselines NOT rotated"
  exit 0
fi
for f in BENCH_hotpath.json BENCH_fleet.json BENCH_sweep.json; do
  if [[ -f "$f" ]]; then
    cp "$f" "${f%.json}.prev.json"
  fi
done
echo "bench_check: baselines rotated (*.prev.json)"
