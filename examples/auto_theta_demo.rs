//! Auto-θ in action: traces the ladder's trajectory through a drift
//! episode and compares the endpoint against every fixed θ — the
//! "no manual tuning needed" demonstration of §2.2.
//!
//! Run: `cargo run --release --example auto_theta_demo`

use odl_har::data::{DriftSplit, Standardizer, SynthConfig, SynthHar};
use odl_har::exp::protocol::{run, ProtocolConfig, PruningSpec, Variant};
use odl_har::odl::{AlphaKind, OsElm, OsElmConfig};
use odl_har::pruning::{warmup_for, Decision, Metric, Pruner, ThetaPolicy};
use odl_har::util::rng::Rng64;

fn main() -> anyhow::Result<()> {
    // --- 1. trace one episode -----------------------------------------------
    let mut data_rng = Rng64::new(0xDA7A_5EED);
    let pool = SynthHar::new(SynthConfig::default(), &mut data_rng).generate(&mut data_rng);
    let mut rng = Rng64::new(7);
    let mut split = DriftSplit::build(&pool, 0.7, &mut rng);
    let std = Standardizer::fit(&split.train.xs);
    for part in [
        &mut split.train,
        &mut split.test0,
        &mut split.odl_stream,
        &mut split.test1,
    ] {
        std.apply(&mut part.xs);
    }

    let mut core = OsElm::new(OsElmConfig::default(), &mut rng, 0x2A6D);
    let (init, rest) = split.train.split_at(300);
    core.init_batch(&init.xs, &init.labels)?;
    for r in 0..rest.len() {
        core.train_step(rest.xs.row(r), rest.labels[r]);
    }

    let mut pruner = Pruner::new(ThetaPolicy::auto(), Metric::P1P2, warmup_for(128));
    let (mut queries, mut trained, mut skips) = (0usize, 0usize, 0usize);
    println!("event  theta  queries  skips  (trace of one drift episode)");
    for r in 0..split.odl_stream.len() {
        let x = split.odl_stream.xs.row(r);
        let pred = core.predict(x);
        match pruner.decide(&pred, trained, false) {
            Decision::Skip => {
                skips += 1;
                pruner.observe(Decision::Skip, None);
            }
            Decision::Query => {
                queries += 1;
                let t = split.odl_stream.labels[r];
                pruner.observe(Decision::Query, Some(pred.class == t));
                core.train_step(x, t);
                trained += 1;
            }
        }
        if r % 128 == 0 || r + 1 == split.odl_stream.len() {
            println!(
                "{r:>5}  {:>5.2}  {queries:>7}  {skips:>5}",
                pruner.policy.theta()
            );
        }
    }

    // --- 2. compare against the fixed-θ frontier ------------------------------
    println!("\nfixed-theta frontier vs auto (3 trials each):");
    println!("theta   after-acc   comm%");
    for spec in [
        PruningSpec::Off,
        PruningSpec::Fixed(0.64),
        PruningSpec::Fixed(0.32),
        PruningSpec::Fixed(0.16),
        PruningSpec::Fixed(0.08),
        PruningSpec::Auto { x: 10 },
    ] {
        let label = match &spec {
            PruningSpec::Off => "1.00".to_string(),
            PruningSpec::Fixed(t) => format!("{t:.2}"),
            PruningSpec::Auto { .. } => "Auto".to_string(),
        };
        let mut cfg = ProtocolConfig::new(Variant::Odl(AlphaKind::Hash), 128);
        cfg.trials = 3;
        cfg.pruning = spec;
        let agg = run(&cfg)?;
        println!(
            "{label}   {:>6.1}      {:>5.1}",
            agg.after.mean(),
            agg.comm.mean()
        );
    }
    println!(
        "\nauto-θ reaches the low-communication regime without sweeping θ by hand —\n\
         the paper's point: manual tuning of θ at deployment time is impractical."
    );
    Ok(())
}
