//! Quickstart: the core library in ~40 lines.
//!
//! Builds the paper's ODLHash core (561 → 128 → 6), trains it on the
//! synthetic HAR workload, drifts the distribution, and shows on-device
//! recovery with auto-pruned teacher queries — all on the native rust
//! golden model (see `e2e_drift_pjrt` for the same flow through the
//! PJRT artifacts).
//!
//! Run: `cargo run --release --example quickstart`

use odl_har::data::{DriftSplit, Standardizer, SynthConfig, SynthHar};
use odl_har::odl::{AlphaKind, OsElm, OsElmConfig};
use odl_har::pruning::{warmup_for, Decision, Metric, Pruner, ThetaPolicy};
use odl_har::util::rng::Rng64;

fn main() -> anyhow::Result<()> {
    // 1. A drifting HAR workload: 25 in-distribution subjects for training,
    //    5 held-out subjects as the post-deployment distribution.
    let mut data_rng = Rng64::new(0xDA7A_5EED);
    let pool = SynthHar::new(SynthConfig::default(), &mut data_rng).generate(&mut data_rng);
    let mut rng = Rng64::new(42);
    let mut split = DriftSplit::build(&pool, 0.7, &mut rng);
    let std = Standardizer::fit(&split.train.xs);
    for part in [
        &mut split.train,
        &mut split.test0,
        &mut split.odl_stream,
        &mut split.test1,
    ] {
        std.apply(&mut part.xs);
    }

    // 2. The tiny supervised ODL core: ODLHash, N = 128 (136.39 kB on the ASIC).
    let cfg = OsElmConfig {
        alpha: AlphaKind::Hash,
        ..Default::default()
    };
    let mut core = OsElm::new(cfg, &mut rng, 0x2A6D);

    // 3. Initial training: batch init + sequential ODL over the train stream.
    let (init, rest) = split.train.split_at(300);
    core.init_batch(&init.xs, &init.labels)?;
    for r in 0..rest.len() {
        core.train_step(rest.xs.row(r), rest.labels[r]);
    }
    let before = core.accuracy(&split.test0.xs, &split.test0.labels) * 100.0;
    let drifted = core.accuracy(&split.test1.xs, &split.test1.labels) * 100.0;

    // 4. Drift hits: retrain on-device, querying the teacher only when the
    //    P1P2 confidence gate (auto-tuned θ) says the sample is worth it.
    let mut pruner = Pruner::new(ThetaPolicy::auto(), Metric::P1P2, warmup_for(128));
    let (mut queries, mut trained) = (0usize, 0usize);
    for r in 0..split.odl_stream.len() {
        let x = split.odl_stream.xs.row(r);
        let pred = core.predict(x);
        match pruner.decide(&pred, trained, false) {
            Decision::Skip => pruner.observe(Decision::Skip, None),
            Decision::Query => {
                queries += 1;
                let teacher_label = split.odl_stream.labels[r];
                pruner.observe(Decision::Query, Some(pred.class == teacher_label));
                core.train_step(x, teacher_label);
                trained += 1;
            }
        }
    }
    let after = core.accuracy(&split.test1.xs, &split.test1.labels) * 100.0;

    println!("accuracy before drift      : {before:.1} %");
    println!("accuracy at drift (frozen) : {drifted:.1} %");
    println!("accuracy after ODL recovery: {after:.1} %");
    println!(
        "teacher queries: {queries}/{} ({:.1} % of stream; θ ended at {:.2})",
        split.odl_stream.len(),
        100.0 * queries as f64 / split.odl_stream.len() as f64,
        pruner.policy.theta(),
    );
    Ok(())
}
