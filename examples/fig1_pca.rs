//! Figure 1 regeneration: per-class 2-D PCA projections of the HAR pool,
//! colored by subject — writes one CSV per class under `results/` and an
//! ASCII scatter preview of the first class so the cluster structure is
//! visible without plotting tools.
//!
//! Run: `cargo run --release --example fig1_pca`

use odl_har::data::{SynthConfig, SynthHar, HELD_OUT_SUBJECTS};
use odl_har::exp::fig1;
use odl_har::util::rng::Rng64;

fn main() -> anyhow::Result<()> {
    let mut data_rng = Rng64::new(0xDA7A_5EED);
    let pool = match odl_har::data::uci::load_from_env()? {
        Some(real) => real,
        None => SynthHar::new(SynthConfig::default(), &mut data_rng).generate(&mut data_rng),
    };
    let out = std::path::PathBuf::from("results");
    let table = fig1::run(&pool, &out, 7)?;
    println!("{}", table.render());

    // ASCII preview of class 0: in-distribution subjects '.', held-out 'X'
    let class0 = pool.filter(|l, _| l == 0);
    let mut rng = Rng64::new(7);
    let pca = odl_har::data::pca::Pca::fit(&class0.xs, 2, &mut rng);
    let proj = pca.transform(&class0.xs);
    let (w, h) = (72usize, 24usize);
    let (mut min_x, mut max_x, mut min_y, mut max_y) = (f32::MAX, f32::MIN, f32::MAX, f32::MIN);
    for r in 0..proj.rows {
        min_x = min_x.min(proj.at(r, 0));
        max_x = max_x.max(proj.at(r, 0));
        min_y = min_y.min(proj.at(r, 1));
        max_y = max_y.max(proj.at(r, 1));
    }
    let mut grid = vec![vec![' '; w]; h];
    for r in 0..proj.rows {
        let cx = (((proj.at(r, 0) - min_x) / (max_x - min_x)) * (w as f32 - 1.0)) as usize;
        let cy = (((proj.at(r, 1) - min_y) / (max_y - min_y)) * (h as f32 - 1.0)) as usize;
        let held = HELD_OUT_SUBJECTS.contains(&class0.subjects[r]);
        grid[cy][cx] = if held { 'X' } else { '.' };
    }
    println!("class 0 projection ('.' = training subjects, 'X' = held-out {HELD_OUT_SUBJECTS:?}):");
    for row in grid {
        println!("{}", row.into_iter().collect::<String>());
    }
    println!("\nper-class CSVs written to results/fig1_class*.csv");
    Ok(())
}
