//! END-TO-END DRIVER (full three-layer stack on a real workload).
//!
//! Runs the paper's §3 drift protocol with **all model compute executed
//! through the PJRT artifacts** (JAX/Pallas → HLO text → XLA → rust):
//!
//!   1. batch-init on 512 training samples (`init_batch_hash_n128`),
//!   2. sequential OS-ELM training over the remaining training stream
//!      (`train_step_hash_n128`, one XLA execution per sample),
//!   3. pre-drift evaluation (`predict_batch_hash_n128`, B = 256),
//!   4. ODL phase on the held-out-subject stream with the paper's
//!      auto-θ data pruning (P1P2 gate on `predict_one_hash_n128`),
//!   5. post-drift evaluation,
//!
//! and prints the Table-3-style row plus the Figure-3 headline numbers
//! (communication volume under auto pruning). Recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_drift_pjrt`

use odl_har::data::{DriftSplit, Standardizer, SynthConfig, SynthHar};
use odl_har::pruning::{Decision, Metric, Pruner, ThetaPolicy};
use odl_har::runtime::{default_artifact_dir, PjrtOsElm, Runtime};
use odl_har::util::rng::Rng64;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    odl_har::util::logging::init();
    let t0 = Instant::now();

    // --- data: the calibrated synthetic HAR workload (or real UCI via env)
    let mut rng = Rng64::new(0xE2E);
    let pool = match odl_har::data::uci::load_from_env()? {
        Some(real) => {
            println!("using real UCI HAR dataset from $HAR_DATASET_DIR");
            real
        }
        None => {
            let mut data_rng = Rng64::new(0xDA7A_5EED);
            SynthHar::new(SynthConfig::default(), &mut data_rng).generate(&mut data_rng)
        }
    };
    let mut split = DriftSplit::build(&pool, 0.7, &mut rng);
    let std = Standardizer::fit(&split.train.xs);
    std.apply(&mut split.train.xs);
    std.apply(&mut split.test0.xs);
    std.apply(&mut split.odl_stream.xs);
    std.apply(&mut split.test1.xs);
    println!(
        "data: train {} / test0 {} / odl-stream {} / test1 {}",
        split.train.len(),
        split.test0.len(),
        split.odl_stream.len(),
        split.test1.len()
    );

    // --- runtime + model (every op below runs through XLA executables)
    let rt = Runtime::open(default_artifact_dir())?;
    let mut model = PjrtOsElm::new(&rt, 128, 0x2A6D)?;
    println!("artifacts compiled: init/train/predict_one/predict_batch (N=128)");

    // 1. initial training (scan-fused streaming artifact: one XLA launch
    //    per 32 samples — the §Perf L2 optimization)
    let t_init = Instant::now();
    model.init_batch(&split.train.xs, &split.train.labels)?;
    let k0 = 512;
    let rest: Vec<usize> = (k0..split.train.len()).collect();
    let rest_ds = split.train.take(&rest);
    model.train_stream(&rest_ds.xs, &rest_ds.labels)?;
    println!(
        "initial training: {} samples in {:.1}s ({:.3} ms/step via scan-fused PJRT)",
        split.train.len(),
        t_init.elapsed().as_secs_f32(),
        t_init.elapsed().as_millis() as f64 / (split.train.len() - k0) as f64
    );

    // 2. pre-drift evaluation
    let acc_before = model.accuracy(&split.test0.xs, &split.test0.labels)? * 100.0;

    // 3. ODL with auto-θ pruning (teacher = label oracle, per the paper)
    let warmup = odl_har::pruning::warmup_for(128);
    let mut pruner = Pruner::new(ThetaPolicy::auto(), Metric::P1P2, warmup);
    let (mut queries, mut skips, mut trained) = (0usize, 0usize, 0usize);
    let t_odl = Instant::now();
    for r in 0..split.odl_stream.len() {
        let x = split.odl_stream.xs.row(r);
        let pred = model.predict(x)?;
        match pruner.decide(&pred, trained, false) {
            Decision::Skip => {
                skips += 1;
                pruner.observe(Decision::Skip, None);
            }
            Decision::Query => {
                queries += 1;
                let t = split.odl_stream.labels[r]; // oracle teacher
                pruner.observe(Decision::Query, Some(pred.class == t));
                model.train_step(x, t)?;
                trained += 1;
            }
        }
    }
    let comm = 100.0 * queries as f64 / split.odl_stream.len() as f64;

    // 4. post-drift evaluation
    let acc_after = model.accuracy(&split.test1.xs, &split.test1.labels)? * 100.0;

    println!("\n=== e2e results (full PJRT stack) ===");
    println!("accuracy before drift : {acc_before:.1} %   (paper ODLHash N=128: 93.1)");
    println!("accuracy after  drift : {acc_after:.1} %   (paper: 90.7)");
    println!(
        "ODL phase: {} events, {} queries, {} skips → comm volume {comm:.1} % (paper auto: 44.3 %)",
        split.odl_stream.len(),
        queries,
        skips
    );
    println!("final θ: {:.2}", pruner.policy.theta());
    println!(
        "ODL wall time {:.1}s; total {:.1}s",
        t_odl.elapsed().as_secs_f32(),
        t0.elapsed().as_secs_f32()
    );

    // sanity gates so `make examples` fails loudly on regression
    anyhow::ensure!(acc_before > 85.0, "pre-drift accuracy collapsed");
    anyhow::ensure!(acc_after > 85.0, "ODL failed to recover from drift");
    anyhow::ensure!(comm < 80.0, "auto pruning saved no communication");
    println!("e2e OK");
    Ok(())
}
