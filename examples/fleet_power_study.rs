//! Fleet power case study (§3.3 at system scale): eight edge devices, one
//! teacher, lossy BLE, a mid-run distribution shift — reports per-edge
//! communication volume and mean power with/without auto pruning, plus an
//! organic-detection variant (CUSUM centroid detector instead of the
//! scripted oracle).
//!
//! The simulation runs on the sharded engine (`Fleet::run_parallel`),
//! spreading edges across the machine's cores; the numbers are bitwise
//! identical to the single-threaded event loop, so `--workers` (or the
//! auto default) is purely a wall-clock knob.
//!
//! `--metrics aggregate` switches the rollup to the O(1)-memory
//! `FleetAggregate` (the `fleet.metrics = "aggregate"` mode of the CLI):
//! no per-edge rows are kept, communication volume comes from the exact
//! fleet-wide query/skip counters, and final accuracy is the sketch
//! median instead of a per-edge mean. Pair it with `--edges N` to push
//! the study to fleet sizes where per-edge rows would not fit.
//!
//! Run: `cargo run --release --example fleet_power_study
//!       [-- --workers N --metrics full|aggregate --edges N]`

use odl_har::coordinator::fleet::{DetectorKind, Fleet, FleetConfig, Scenario};
use odl_har::coordinator::{ChannelConfig, MetricsMode};
use odl_har::data::SynthConfig;

fn scenario(
    n_edges: usize,
    metrics: MetricsMode,
    fixed_theta: Option<f32>,
    detector: DetectorKind,
) -> Scenario {
    Scenario {
        n_edges,
        n_hidden: 128,
        event_period_s: 1.0,
        horizon_s: 900.0,
        drift_at_s: 200.0,
        detector,
        fixed_theta,
        teacher_error: 0.0,
        channel: ChannelConfig {
            loss_prob: 0.05,
            max_retries: 2,
            ..Default::default()
        },
        synth: SynthConfig::default(),
        train_target: 450,
        metrics,
        ..Default::default()
    }
}

fn report(tag: &str, sc: Scenario, workers: usize) -> anyhow::Result<(f64, f64)> {
    let fleet = Fleet::new(FleetConfig {
        scenario: sc,
        seed: 42,
    })?;
    let r = fleet.run_parallel(workers);
    let (comm, acc) = match &r.aggregate {
        // aggregate mode: exact fleet-wide counters (no per-edge rows
        // exist), final accuracy as the sketch median across edges
        Some(agg) => {
            let considered = agg.total_queries + agg.skips;
            let comm = if considered == 0 {
                0.0
            } else {
                100.0 * agg.total_queries as f64 / considered as f64
            };
            (comm, agg.accuracy.p50())
        }
        // full mode: unweighted per-edge means, as the study always
        // reported them
        None => {
            let comm: f64 = r
                .per_edge
                .iter()
                .map(|m| m.comm_fraction() * 100.0)
                .sum::<f64>()
                / r.per_edge.len() as f64;
            let acc: f64 = r
                .per_edge
                .iter()
                .filter_map(|m| m.accuracy_trace.last().map(|&(_, a)| a))
                .sum::<f64>()
                / r.per_edge.len() as f64;
            (comm, acc)
        }
    };
    let power = r.mean_edge_power_mw();
    println!(
        "{tag:<34} comm {comm:>5.1} %   mean power {power:>6.3} mW   final acc {:>5.1} %   (teacher served {}, channel failures {})",
        acc * 100.0,
        r.teacher_queries,
        r.channel_failures
    );
    Ok((comm, power))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flag_val = |name: &str| -> Option<&String> {
        args.iter().position(|a| a == name).map(|i| args.get(i + 1)).unwrap_or(None)
    };
    // 0 (or omitting the flag) means auto, per the repo-wide convention
    let workers = odl_har::util::auto_workers(match args.iter().position(|a| a == "--workers") {
        Some(_) => flag_val("--workers")
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("--workers requires a number"))?,
        None => 0,
    });
    let n_edges: usize = match args.iter().position(|a| a == "--edges") {
        Some(_) => flag_val("--edges")
            .and_then(|v| v.parse().ok())
            .filter(|&n| n > 0)
            .ok_or_else(|| anyhow::anyhow!("--edges requires a positive number"))?,
        None => 8,
    };
    let metrics = match flag_val("--metrics").map(String::as_str) {
        None | Some("full") => MetricsMode::Full,
        Some("aggregate") => MetricsMode::Aggregate,
        Some(other) => anyhow::bail!("--metrics must be full or aggregate, got {other}"),
    };
    println!(
        "fleet: {n_edges} edges, 1 teacher, BLE loss 5 %, drift at t=200 s, horizon 900 s \
         ({workers} workers, {} metrics)\n",
        metrics.name()
    );
    let (comm_off, p_off) = report(
        "no pruning (theta = 1)",
        scenario(n_edges, metrics, Some(1.0), DetectorKind::Oracle),
        workers,
    )?;
    let (comm_auto, p_auto) = report(
        "auto-theta pruning",
        scenario(n_edges, metrics, None, DetectorKind::Oracle),
        workers,
    )?;
    report(
        "auto-theta + organic detection",
        scenario(n_edges, metrics, None, DetectorKind::Centroid),
        workers,
    )?;
    println!(
        "\nauto pruning: communication volume {:.1} % -> {:.1} %, mean training-mode power -{:.1} %",
        comm_off,
        comm_auto,
        100.0 * (1.0 - p_auto / p_off)
    );
    anyhow::ensure!(comm_auto < comm_off, "pruning must reduce communication");
    Ok(())
}
