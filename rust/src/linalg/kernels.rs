//! Fixed-width micro-kernels for the L3 hot path.
//!
//! Everything that runs once per event per edge in the fleet simulator —
//! the hidden-layer panel matvec, the Sherman–Morrison P update, logits,
//! Gram/covariance builds — bottoms out in this module. The kernels are
//! written in **stable Rust only** (no `std::simd`, no intrinsics): each
//! inner loop has a compile-time-known width of [`LANES`] = 8 independent
//! lanes, the shape LLVM's autovectorizer reliably turns into SIMD with
//! the baseline `x86-64` / `aarch64` targets (2×f32x4 or 2×f32x8 when
//! `target-cpu` allows).
//!
//! **Determinism.** Every kernel has one fixed association order, so a
//! given input always produces bitwise-identical output across runs and
//! call sites:
//!
//! * elementwise kernels ([`axpy`], [`rank1_sym_update`]'s upper triangle,
//!   [`fx_scale_sub`]) are bit-for-bit equal to the naive scalar loop;
//! * reductions ([`dot`], [`dist2`]) use 8 accumulation lanes + a scalar
//!   tail, a *different but fixed* association vs. the naive sum (the
//!   property tests bound the difference; for lengths < 8 the orders
//!   coincide exactly);
//! * [`gemm`]/[`gram`]/[`matvec`] accumulate strictly in ascending-k
//!   order, so cache blocking does not change their numerics: `gemm` and
//!   `gram` are bit-for-bit equal to the naive triple loop;
//! * the Q16.16 kernels accumulate in `i64`, where addition is associative
//!   — lane-splitting is bitwise-exact by construction.
//!
//! **Symmetry.** OS-ELM's P is symmetric positive definite by
//! construction; [`rank1_sym_update`] exploits that by updating only the
//! upper triangle (half the multiplies and half the read traffic of the
//! full N² sweep) and mirroring rows into the lower triangle, which keeps
//! P *exactly* symmetric — `p[j][i]` is a bitwise copy of `p[i][j]`.

use crate::fixed::Fx;

/// Lane width of the chunked kernels. 8 × f32 = one AVX register / two
/// NEON or SSE registers; the accumulators of one chunk stay resident in
/// registers for the whole reduction.
pub const LANES: usize = 8;

/// Cache-block sizes for [`gemm`]: a `BLK_K × BLK_N` panel of B is
/// 64 KiB-safe (64·256·4 B = 64 KiB, L2-resident; each `BLK_N` slice of a
/// C row stays in L1 across the k-block).
pub const BLK_K: usize = 64;
pub const BLK_N: usize = 256;

// --- reductions --------------------------------------------------------------

/// Dot product, 8-lane chunked.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let ac = a.chunks_exact(LANES);
    let bc = b.chunks_exact(LANES);
    let (ra, rb) = (ac.remainder(), bc.remainder());
    let mut lanes = [0.0f32; LANES];
    for (ca, cb) in ac.zip(bc) {
        for l in 0..LANES {
            lanes[l] += ca[l] * cb[l];
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
        + tail
}

/// Squared Euclidean distance `‖a − b‖²`, 8-lane chunked (drift detector
/// hot loop: one call per sensed sample per edge).
#[inline]
pub fn dist2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let ac = a.chunks_exact(LANES);
    let bc = b.chunks_exact(LANES);
    let (ra, rb) = (ac.remainder(), bc.remainder());
    let mut lanes = [0.0f32; LANES];
    for (ca, cb) in ac.zip(bc) {
        for l in 0..LANES {
            let d = ca[l] - cb[l];
            lanes[l] += d * d;
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        tail += d * d;
    }
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
        + tail
}

/// Widening dot product `Σ aᵢ·bᵢ` with one strictly sequential `f64`
/// accumulator — the association order of the SPD solver's reference
/// loops (`linalg::solve`). Unlike [`dot`]'s 8-lane split, the
/// accumulation chain here must stay sequential: the Cholesky
/// factorization and triangular solves are pinned **bit-for-bit** to the
/// historical scalar code, and a lane split would change the f64
/// rounding sequence. The speedup of the blocked factorization comes
/// from its panel schedule (cache reuse), not from reassociating this
/// reduction.
#[inline]
pub fn dot_wide(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        s += x as f64 * y as f64;
    }
    s
}

/// Substitution kernel `acc − Σ aᵢ·bᵢ` with strictly sequential `f64`
/// decrements (`acc -= x·y` per element) — the inner loop of the
/// forward/backward triangular solves in `linalg::solve`, which start
/// from the right-hand side and subtract term by term. The decrement
/// association differs from `acc − dot_wide(a, b)` in f64 rounding, so
/// it gets its own kernel; bit-for-bit the naive loop by construction.
#[inline]
pub fn subdot_wide(acc: f64, a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = acc;
    for (&x, &y) in a.iter().zip(b) {
        s -= x as f64 * y as f64;
    }
    s
}

// --- elementwise kernels -----------------------------------------------------

/// `y += alpha · x`. Elementwise (no reduction), so the plain zip loop is
/// both autovectorization-friendly and bit-for-bit the naive result.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// EWMA tracking `c += rate · (x − c)` (drift-detector centroid update).
#[inline]
pub fn ewma(c: &mut [f32], x: &[f32], rate: f32) {
    debug_assert_eq!(c.len(), x.len());
    for (ci, &xi) in c.iter_mut().zip(x) {
        *ci += rate * (xi - *ci);
    }
}

// --- matrix kernels ----------------------------------------------------------

/// `out[r] = dot(a.row(r), x)` for a row-major `rows × cols` matrix.
pub fn matvec(a: &[f32], rows: usize, cols: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(x.len(), cols);
    debug_assert_eq!(out.len(), rows);
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot(&a[r * cols..(r + 1) * cols], x);
    }
}

/// Cache-blocked `C += A · B` for row-major `A (m×k)`, `B (k×n)`,
/// `C (m×n)`.
///
/// Loop order is jc→pc→i→p with an [`axpy`] inner loop over a `BLK_N`-wide
/// slice of a C row: the `BLK_K × BLK_N` panel of B is reused across all m
/// rows of A, and each C slice stays in L1 across the k-block.
/// Accumulation into any C element happens strictly in ascending-k order,
/// so the result is bitwise identical to the naive i→k→j triple loop.
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let mut jc = 0;
    while jc < n {
        let nb = BLK_N.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kb = BLK_K.min(k - pc);
            for i in 0..m {
                let arow = &a[i * k + pc..i * k + pc + kb];
                let crow = &mut c[i * n + jc..i * n + jc + nb];
                for (p, &av) in arow.iter().enumerate() {
                    let brow = &b[(pc + p) * n + jc..(pc + p) * n + jc + nb];
                    axpy(av, brow, crow);
                }
            }
            pc += kb;
        }
        jc += nb;
    }
}

/// Gram matrix `G = AᵀA` for row-major `A (rows × cols)`, exploiting
/// symmetry: only the upper triangle is accumulated (half the FLOPs of the
/// seed's full sweep), then mirrored. Accumulation is in ascending-row
/// order, so the upper triangle is bitwise identical to the naive triple
/// loop, and the mirrored lower triangle matches it too (IEEE
/// multiplication commutes).
pub fn gram(a: &[f32], rows: usize, cols: usize, g: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(g.len(), cols * cols);
    g.fill(0.0);
    for r in 0..rows {
        let row = &a[r * cols..(r + 1) * cols];
        for i in 0..cols {
            let xi = row[i];
            let grow = &mut g[i * cols + i..(i + 1) * cols];
            axpy(xi, &row[i..], grow);
        }
    }
    mirror_upper(g, cols);
}

/// Symmetric rank-1 update `P −= scale · v·vᵀ` for row-major `P (n×n)`.
///
/// The inner loop of OS-ELM's Sherman–Morrison step (`scale = 1/denom`,
/// `v = Ph`). Updates only the upper triangle — halving the multiply count
/// and the read traffic of the seed's full-matrix sweep — then mirrors, so
/// a symmetric P stays **exactly** symmetric (the lower triangle is a
/// bitwise copy of the upper). The upper triangle is bit-for-bit the naive
/// `p[i][j] -= (v[i]·scale)·v[j]`.
pub fn rank1_sym_update(p: &mut [f32], n: usize, v: &[f32], scale: f32) {
    debug_assert_eq!(p.len(), n * n);
    debug_assert_eq!(v.len(), n);
    for i in 0..n {
        let s = v[i] * scale;
        let prow = &mut p[i * n + i..(i + 1) * n];
        for (pj, &vj) in prow.iter_mut().zip(&v[i..]) {
            *pj -= s * vj;
        }
    }
    mirror_upper(p, n);
}

/// Matrix order at which [`mirror_upper`] switches to the tiled
/// transpose-copy. Below this the whole matrix fits comfortably in L2 and
/// the naive sweep wins on simplicity; at N = 512 a row is already 2 KiB,
/// so the naive pass reads one strided element per cache line and misses
/// on nearly every load.
pub const MIRROR_BLOCK_MIN_N: usize = 512;

/// Tile edge of the blocked mirror: a 32×32 f32 tile is 4 KiB, and the
/// strided reads of one tile touch only 32 distinct cache lines, which
/// stay resident across the whole tile.
const MIRROR_TILE: usize = 32;

/// Copy the upper triangle of a row-major `n×n` matrix onto the lower
/// (`g[i][j] ← g[j][i]` for `j < i`). Row-major writes; for
/// `n ≥ MIRROR_BLOCK_MIN_N` the lower triangle is walked in
/// `MIRROR_TILE`-square tiles so the strided upper-triangle reads reuse
/// cache lines instead of missing once per element. Every entry is a pure
/// copy, so the result is bitwise identical to the naive sweep in either
/// path.
pub fn mirror_upper(g: &mut [f32], n: usize) {
    debug_assert_eq!(g.len(), n * n);
    if n < MIRROR_BLOCK_MIN_N {
        for i in 1..n {
            for j in 0..i {
                g[i * n + j] = g[j * n + i];
            }
        }
        return;
    }
    let t = MIRROR_TILE;
    let mut ib = 0;
    while ib < n {
        let imax = (ib + t).min(n);
        let mut jb = 0;
        // only tiles intersecting the strict lower triangle (j < i < imax)
        while jb < imax {
            let jmax = (jb + t).min(n);
            for i in ib..imax {
                let row = i * n;
                let jhi = jmax.min(i);
                for j in jb..jhi {
                    g[row + j] = g[j * n + i];
                }
            }
            jb += t;
        }
        ib += t;
    }
}

/// Exact symmetrization `P ← (P + Pᵀ)/2` in place (used once after the
/// batch init, whose Cholesky inverse can carry ~1-ulp asymmetry, and as
/// the periodic drift guard in `OsElm::train_step`).
pub fn symmetrize(p: &mut [f32], n: usize) {
    debug_assert_eq!(p.len(), n * n);
    for i in 0..n {
        for j in i + 1..n {
            let avg = 0.5 * (p[i * n + j] + p[j * n + i]);
            p[i * n + j] = avg;
            p[j * n + i] = avg;
        }
    }
}

// --- Q16.16 kernels ----------------------------------------------------------
//
// The fixed-point twins used by `crate::fixed::vecops` (the ASIC datapath
// model). Products are 32×32→64-bit raw MACs accumulated in i64 — integer
// addition is associative, so the 8-lane split is bitwise identical to the
// sequential walk while autovectorizing to SIMD integer MACs.

/// Raw wide-accumulator dot product: `Σ aᵢ·bᵢ` in the 32.32 product
/// domain. Callers renormalize once (`acc_to_fx`), like the hardware MAC.
#[inline]
pub fn fx_dot_raw(a: &[Fx], b: &[Fx]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    let ac = a.chunks_exact(LANES);
    let bc = b.chunks_exact(LANES);
    let (ra, rb) = (ac.remainder(), bc.remainder());
    let mut lanes = [0i64; LANES];
    for (ca, cb) in ac.zip(bc) {
        for l in 0..LANES {
            lanes[l] += ca[l].mac_raw(cb[l]);
        }
    }
    let mut acc: i64 = lanes.iter().sum();
    for (x, y) in ra.iter().zip(rb) {
        acc += x.mac_raw(*y);
    }
    acc
}

/// `row[j] −= scale · v[j]` in saturating Q16.16 — the fixed-point P-update
/// row sweep (`scale = Ph[i]/denom`, one divide per row like the ASIC
/// schedule). Elementwise, bit-for-bit the naive loop.
#[inline]
pub fn fx_scale_sub(row: &mut [Fx], v: &[Fx], scale: Fx) {
    debug_assert_eq!(row.len(), v.len());
    for (r, &p) in row.iter_mut().zip(v) {
        *r = r.sub(scale.mul(p));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    // Naive references: scalar loops with the textbook association order.
    fn naive_dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
        c
    }

    fn naive_rank1(p: &mut [f32], n: usize, v: &[f32], scale: f32) {
        for i in 0..n {
            let s = v[i] * scale;
            for j in 0..n {
                p[i * n + j] -= s * v[j];
            }
        }
    }

    #[test]
    fn dot_matches_naive_0_to_130() {
        forall(
            "kernels-dot",
            |r| {
                let len = gen::usize_in(r, 0, 130);
                (gen::vec_normal(r, len, 1.0), gen::vec_normal(r, len, 1.0))
            },
            |(a, b)| {
                let naive = naive_dot(a, b);
                (dot(a, b) - naive).abs() <= 1e-4 * (1.0 + naive.abs())
            },
        );
    }

    #[test]
    fn dot_is_deterministic_and_exact_below_lane_width() {
        forall(
            "kernels-dot-det",
            |r| {
                let len = gen::usize_in(r, 0, 130);
                (gen::vec_normal(r, len, 1.0), gen::vec_normal(r, len, 1.0))
            },
            |(a, b)| {
                let repeat_bits = dot(a, b).to_bits() == dot(a, b).to_bits();
                // below one chunk the lane order degenerates to the naive one
                let small_exact = a.len() >= LANES
                    || dot(a, b).to_bits() == naive_dot(a, b).to_bits();
                repeat_bits && small_exact
            },
        );
    }

    #[test]
    fn dot_wide_bitwise_matches_naive_widening_loop() {
        forall(
            "kernels-dot-wide",
            |r| {
                let len = gen::usize_in(r, 0, 130);
                (gen::vec_normal(r, len, 1.0), gen::vec_normal(r, len, 1.0))
            },
            |(a, b)| {
                let mut s = 0.0f64;
                for (&x, &y) in a.iter().zip(b) {
                    s += x as f64 * y as f64;
                }
                dot_wide(a, b).to_bits() == s.to_bits()
            },
        );
    }

    #[test]
    fn subdot_wide_bitwise_matches_naive_decrement_loop() {
        forall(
            "kernels-subdot-wide",
            |r| {
                let len = gen::usize_in(r, 0, 130);
                (
                    gen::f32_in(r, -3.0, 3.0) as f64,
                    gen::vec_normal(r, len, 1.0),
                    gen::vec_normal(r, len, 1.0),
                )
            },
            |(acc, a, b)| {
                let mut s = *acc;
                for (&x, &y) in a.iter().zip(b) {
                    s -= x as f64 * y as f64;
                }
                subdot_wide(*acc, a, b).to_bits() == s.to_bits()
            },
        );
    }

    #[test]
    fn axpy_bitwise_matches_naive() {
        forall(
            "kernels-axpy",
            |r| {
                let len = gen::usize_in(r, 0, 130);
                (
                    gen::f32_in(r, -2.0, 2.0),
                    gen::vec_normal(r, len, 1.0),
                    gen::vec_normal(r, len, 1.0),
                )
            },
            |(alpha, x, y)| {
                let mut got = y.clone();
                axpy(*alpha, x, &mut got);
                got.iter()
                    .zip(x.iter().zip(y))
                    .all(|(g, (xi, yi))| g.to_bits() == (yi + alpha * xi).to_bits())
            },
        );
    }

    #[test]
    fn gemm_bitwise_matches_naive_triple_loop() {
        forall(
            "kernels-gemm",
            |r| {
                let m = gen::usize_in(r, 0, 9);
                let k = gen::usize_in(r, 0, 9);
                let n = gen::usize_in(r, 0, 9);
                (m, k, n, gen::vec_normal(r, m * k, 1.0), gen::vec_normal(r, k * n, 1.0))
            },
            |(m, k, n, a, b)| {
                let mut c = vec![0.0f32; m * n];
                gemm(a, b, &mut c, *m, *k, *n);
                let naive = naive_gemm(a, b, *m, *k, *n);
                c.iter().zip(&naive).all(|(x, y)| x.to_bits() == y.to_bits())
            },
        );
    }

    #[test]
    fn gemm_blocking_boundaries_exact() {
        // dims straddling BLK_K/BLK_N force multi-block paths
        let mut rng = crate::util::rng::Rng64::new(99);
        let (m, k, n) = (5, BLK_K + 17, BLK_N + 33);
        let a = gen::vec_normal(&mut rng, m * k, 1.0);
        let b = gen::vec_normal(&mut rng, k * n, 1.0);
        let mut c = vec![0.0f32; m * n];
        gemm(&a, &b, &mut c, m, k, n);
        let naive = naive_gemm(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&naive) {
            assert_eq!(x.to_bits(), y.to_bits(), "blocked gemm must be k-ordered");
        }
    }

    #[test]
    fn gram_matches_naive_and_is_exactly_symmetric() {
        forall(
            "kernels-gram",
            |r| {
                let rows = gen::usize_in(r, 0, 12);
                let cols = gen::usize_in(r, 0, 12);
                (rows, cols, gen::vec_normal(r, rows * cols, 1.0))
            },
            |(rows, cols, a)| {
                let (rows, cols) = (*rows, *cols);
                let mut g = vec![0.0f32; cols * cols];
                gram(a, rows, cols, &mut g);
                // upper triangle: bitwise the naive ascending-r accumulation
                let mut ok = true;
                for i in 0..cols {
                    for j in i..cols {
                        let mut acc = 0.0f32;
                        for r in 0..rows {
                            acc += a[r * cols + i] * a[r * cols + j];
                        }
                        ok &= g[i * cols + j].to_bits() == acc.to_bits();
                    }
                }
                // lower: exact mirror
                for i in 0..cols {
                    for j in 0..i {
                        ok &= g[i * cols + j].to_bits() == g[j * cols + i].to_bits();
                    }
                }
                ok
            },
        );
    }

    #[test]
    fn rank1_sym_update_matches_naive() {
        forall(
            "kernels-rank1",
            |r| {
                let n = gen::usize_in(r, 0, 130);
                // start from a symmetric matrix, like OS-ELM's P
                let half = gen::vec_normal(r, n * n, 1.0);
                let mut p = vec![0.0f32; n * n];
                for i in 0..n {
                    for j in 0..n {
                        p[i * n + j] = half[i * n + j] + half[j * n + i];
                    }
                }
                (n, p, gen::vec_normal(r, n, 1.0), gen::f32_in(r, -1.0, 1.0))
            },
            |(n, p, v, scale)| {
                let n = *n;
                let mut got = p.clone();
                rank1_sym_update(&mut got, n, v, *scale);
                let mut naive = p.clone();
                naive_rank1(&mut naive, n, v, *scale);
                let mut ok = true;
                for i in 0..n {
                    for j in i..n {
                        // upper triangle: bit-for-bit the naive update
                        ok &= got[i * n + j].to_bits() == naive[i * n + j].to_bits();
                    }
                    for j in 0..i {
                        // lower: exactly symmetric, and within float noise of
                        // the naive (which rounds (v_j·s)·v_i independently)
                        ok &= got[i * n + j].to_bits() == got[j * n + i].to_bits();
                        ok &= (got[i * n + j] - naive[i * n + j]).abs()
                            <= 1e-5 * (1.0 + naive[i * n + j].abs());
                    }
                }
                ok
            },
        );
    }

    #[test]
    fn matvec_matches_naive() {
        forall(
            "kernels-matvec",
            |r| {
                let rows = gen::usize_in(r, 0, 20);
                let cols = gen::usize_in(r, 0, 130);
                (
                    rows,
                    cols,
                    gen::vec_normal(r, rows * cols, 1.0),
                    gen::vec_normal(r, cols, 1.0),
                )
            },
            |(rows, cols, a, x)| {
                let (rows, cols) = (*rows, *cols);
                let mut out = vec![0.0f32; rows];
                matvec(a, rows, cols, x, &mut out);
                out.iter().enumerate().all(|(r, &o)| {
                    let naive = naive_dot(&a[r * cols..(r + 1) * cols], x);
                    (o - naive).abs() <= 1e-4 * (1.0 + naive.abs())
                })
            },
        );
    }

    #[test]
    fn dist2_matches_naive() {
        forall(
            "kernels-dist2",
            |r| {
                let len = gen::usize_in(r, 0, 130);
                (gen::vec_normal(r, len, 1.0), gen::vec_normal(r, len, 1.0))
            },
            |(a, b)| {
                let naive: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                (dist2(a, b) - naive).abs() <= 1e-4 * (1.0 + naive.abs())
            },
        );
    }

    #[test]
    fn ewma_bitwise_matches_naive() {
        let mut rng = crate::util::rng::Rng64::new(5);
        let x = gen::vec_normal(&mut rng, 130, 1.0);
        let c0 = gen::vec_normal(&mut rng, 130, 1.0);
        let mut c = c0.clone();
        ewma(&mut c, &x, 0.02);
        for ((got, &ci), &xi) in c.iter().zip(&c0).zip(&x) {
            assert_eq!(got.to_bits(), (ci + 0.02 * (xi - ci)).to_bits());
        }
    }

    #[test]
    fn mirror_upper_matches_naive_0_to_600() {
        // Property: the (possibly tiled) mirror is bitwise the naive
        // sweep. Sizes up to 600 straddle MIRROR_BLOCK_MIN_N so both the
        // naive and the tiled path are exercised.
        let naive_mirror = |g: &mut [f32], n: usize| {
            for i in 1..n {
                for j in 0..i {
                    g[i * n + j] = g[j * n + i];
                }
            }
        };
        forall(
            "kernels-mirror",
            |r| {
                // bias toward the tiled regime half the time
                let n = if r.bernoulli(0.5) {
                    gen::usize_in(r, 0, 130)
                } else {
                    gen::usize_in(r, MIRROR_BLOCK_MIN_N - 2, 600)
                };
                (n, gen::vec_f32(r, n * n, -3.0, 3.0))
            },
            |(n, src)| {
                let n = *n;
                let mut blocked = src.clone();
                mirror_upper(&mut blocked, n);
                let mut naive = src.clone();
                naive_mirror(&mut naive, n);
                blocked
                    .iter()
                    .zip(&naive)
                    .all(|(a, b)| a.to_bits() == b.to_bits())
            },
        );
        // pin the boundary sizes explicitly
        let mut rng = crate::util::rng::Rng64::new(17);
        for n in [0usize, 1, MIRROR_BLOCK_MIN_N - 1, MIRROR_BLOCK_MIN_N, 545, 600] {
            let src = gen::vec_f32(&mut rng, n * n, -3.0, 3.0);
            let mut blocked = src.clone();
            mirror_upper(&mut blocked, n);
            let mut naive = src.clone();
            naive_mirror(&mut naive, n);
            for (k, (a, b)) in blocked.iter().zip(&naive).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "n {n} idx {k}");
            }
        }
    }

    #[test]
    fn symmetrize_produces_exact_symmetry() {
        let mut rng = crate::util::rng::Rng64::new(7);
        let n = 17;
        let mut p = gen::vec_normal(&mut rng, n * n, 1.0);
        symmetrize(&mut p, n);
        for i in 0..n {
            for j in 0..n {
                assert_eq!(p[i * n + j].to_bits(), p[j * n + i].to_bits());
            }
        }
    }

    #[test]
    fn fx_dot_raw_lane_split_is_exact() {
        use crate::fixed::Fx;
        forall(
            "kernels-fx-dot",
            |r| {
                let len = gen::usize_in(r, 0, 130);
                (gen::vec_f32(r, len, -4.0, 4.0), gen::vec_f32(r, len, -4.0, 4.0))
            },
            |(a, b)| {
                let fa: Vec<Fx> = a.iter().map(|&x| Fx::from_f32(x)).collect();
                let fb: Vec<Fx> = b.iter().map(|&x| Fx::from_f32(x)).collect();
                // integer accumulation is associative: lane split must be
                // *exactly* the sequential sum
                let sequential: i64 = fa.iter().zip(&fb).map(|(x, y)| x.mac_raw(*y)).sum();
                fx_dot_raw(&fa, &fb) == sequential
            },
        );
    }

    #[test]
    fn fx_scale_sub_matches_naive() {
        use crate::fixed::Fx;
        let mut rng = crate::util::rng::Rng64::new(11);
        let row0: Vec<Fx> = gen::vec_f32(&mut rng, 130, -4.0, 4.0)
            .iter()
            .map(|&x| Fx::from_f32(x))
            .collect();
        let v: Vec<Fx> = gen::vec_f32(&mut rng, 130, -2.0, 2.0)
            .iter()
            .map(|&x| Fx::from_f32(x))
            .collect();
        let scale = Fx::from_f32(0.375);
        let mut row = row0.clone();
        fx_scale_sub(&mut row, &v, scale);
        for ((got, &r0), &vi) in row.iter().zip(&row0).zip(&v) {
            assert_eq!(*got, r0.sub(scale.mul(vi)));
        }
    }
}
