//! Symmetric-positive-definite solves (Cholesky) and a pivoted-LU inverse.
//!
//! OS-ELM's batch initialization needs `P₀ = (H₀ᵀH₀ + λI)⁻¹` where the
//! regularized Gram matrix is SPD — Cholesky is the right tool. The LU
//! path is kept for generality (tests, baselines) and as a fallback when a
//! matrix is not quite SPD in f32.

use super::mat::Mat;
use anyhow::{bail, Result};

/// Cholesky factorization in place: returns lower-triangular `L` with
/// `A = L·Lᵀ`. Fails if the matrix is not positive definite.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // sum_{k<j} L[i][k] * L[j][k]
            let mut s = 0.0f64;
            for k in 0..j {
                s += l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                let d = a.at(i, i) as f64 - s;
                if d <= 0.0 {
                    bail!("matrix not positive definite at pivot {} (d={})", i, d);
                }
                *l.at_mut(i, j) = d.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = ((a.at(i, j) as f64 - s) / l.at(j, j) as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Solve `A x = b` in place for SPD `A` given its Cholesky factor `L`.
pub fn cholesky_solve_with(l: &Mat, b: &mut [f32]) {
    let n = l.rows;
    assert_eq!(b.len(), n);
    // forward: L y = b
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l.at(i, k) as f64 * b[k] as f64;
        }
        b[i] = (s / l.at(i, i) as f64) as f32;
    }
    // backward: Lᵀ x = y
    for i in (0..n).rev() {
        let mut s = b[i] as f64;
        for k in i + 1..n {
            s -= l.at(k, i) as f64 * b[k] as f64;
        }
        b[i] = (s / l.at(i, i) as f64) as f32;
    }
}

/// Solve `A X = B` for SPD `A` (B given column-wise as a matrix), in place.
pub fn cholesky_solve_inplace(a: &Mat, b: &mut Mat) -> Result<()> {
    let l = cholesky(a)?;
    let n = a.rows;
    let mut col = vec![0.0f32; n];
    for j in 0..b.cols {
        for i in 0..n {
            col[i] = b.at(i, j);
        }
        cholesky_solve_with(&l, &mut col);
        for i in 0..n {
            *b.at_mut(i, j) = col[i];
        }
    }
    Ok(())
}

/// Inverse of an SPD matrix via Cholesky.
pub fn cholesky_inverse(a: &Mat) -> Result<Mat> {
    let mut inv = Mat::eye(a.rows);
    cholesky_solve_inplace(a, &mut inv)?;
    Ok(inv)
}

/// Inverse via partially pivoted LU (general square matrices).
pub fn lu_inverse(a: &Mat) -> Result<Mat> {
    assert_eq!(a.rows, a.cols, "lu_inverse needs a square matrix");
    let n = a.rows;
    // Work in f64 for stability; shapes are small (≤ 512).
    let mut lu: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut piv: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // pivot
        let mut p = k;
        let mut pmax = lu[k * n + k].abs();
        for i in k + 1..n {
            let v = lu[i * n + k].abs();
            if v > pmax {
                pmax = v;
                p = i;
            }
        }
        if pmax < 1e-300 {
            bail!("singular matrix at pivot {}", k);
        }
        if p != k {
            for j in 0..n {
                lu.swap(k * n + j, p * n + j);
            }
            piv.swap(k, p);
        }
        let pivot = lu[k * n + k];
        for i in k + 1..n {
            let f = lu[i * n + k] / pivot;
            lu[i * n + k] = f;
            for j in k + 1..n {
                lu[i * n + j] -= f * lu[k * n + j];
            }
        }
    }
    // Solve A X = I column by column using the LU factors.
    let mut inv = Mat::zeros(n, n);
    let mut col = vec![0.0f64; n];
    for c in 0..n {
        for i in 0..n {
            col[i] = if piv[i] == c { 1.0 } else { 0.0 };
        }
        // forward
        for i in 0..n {
            for k in 0..i {
                col[i] -= lu[i * n + k] * col[k];
            }
        }
        // backward
        for i in (0..n).rev() {
            for k in i + 1..n {
                col[i] -= lu[i * n + k] * col[k];
            }
            col[i] /= lu[i * n + i];
        }
        for i in 0..n {
            *inv.at_mut(i, c) = col[i] as f32;
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};
    use crate::util::rng::Rng64;

    fn random_spd(rng: &mut Rng64, n: usize) -> Mat {
        // A = BᵀB + I is SPD.
        let b = Mat::from_vec(n, n, gen::vec_normal(rng, n * n, 1.0));
        let mut g = b.gram();
        g.add_diag(1.0);
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng64::new(3);
        let a = random_spd(&mut rng, 8);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-3, "diff {}", rec.max_abs_diff(&a));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn spd_inverse_property() {
        forall(
            "cholesky-inverse",
            |r| {
                let n = gen::usize_in(r, 1, 12);
                random_spd(r, n)
            },
            |a| {
                let inv = cholesky_inverse(a).unwrap();
                let eye = a.matmul(&inv);
                eye.max_abs_diff(&Mat::eye(a.rows)) < 1e-2
            },
        );
    }

    #[test]
    fn lu_inverse_property() {
        forall(
            "lu-inverse",
            |r| {
                let n = gen::usize_in(r, 1, 12);
                // General well-conditioned matrix: random + n·I
                let mut m = Mat::from_vec(n, n, gen::vec_normal(r, n * n, 1.0));
                m.add_diag(n as f32);
                m
            },
            |a| {
                let inv = lu_inverse(a).unwrap();
                a.matmul(&inv).max_abs_diff(&Mat::eye(a.rows)) < 1e-2
            },
        );
    }

    #[test]
    fn lu_rejects_singular() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(lu_inverse(&a).is_err());
    }

    #[test]
    fn lu_matches_cholesky_on_spd() {
        let mut rng = Rng64::new(17);
        let a = random_spd(&mut rng, 16);
        let i1 = cholesky_inverse(&a).unwrap();
        let i2 = lu_inverse(&a).unwrap();
        assert!(i1.max_abs_diff(&i2) < 1e-2);
    }

    #[test]
    fn solve_matches_inverse() {
        let mut rng = Rng64::new(23);
        let a = random_spd(&mut rng, 10);
        let b = Mat::from_vec(10, 3, gen::vec_normal(&mut rng, 30, 1.0));
        let mut x = b.clone();
        cholesky_solve_inplace(&a, &mut x).unwrap();
        let x2 = cholesky_inverse(&a).unwrap().matmul(&b);
        assert!(x.max_abs_diff(&x2) < 1e-2);
    }
}
