//! Symmetric-positive-definite solves (Cholesky) and a pivoted-LU inverse.
//!
//! OS-ELM's batch initialization needs `P₀ = (H₀ᵀH₀ + λI)⁻¹` where the
//! regularized Gram matrix is SPD — Cholesky is the right tool. The LU
//! path is kept for generality (tests, baselines) and as a fallback when a
//! matrix is not quite SPD in f32.
//!
//! # The blocked factorization
//!
//! [`cholesky`] runs a panel-blocked schedule: columns are processed in
//! panels of [`CHOL_PANEL`], the panel's diagonal block is factored
//! column by column, and the sub-diagonal rows are then filled in
//! [`CHOL_ROW_TILE`]-row tiles. Every element is still computed as one
//! widening prefix dot ([`kernels::dot_wide`]: a single sequential `f64`
//! accumulator over `k < j`), so the value of each `L[i][j]` — and the
//! index/value of the first failing pivot — is **bit-for-bit identical**
//! to the historical row-by-row scalar loop (property-tested below on
//! SPD matrices with sizes spanning 0..=600). What the schedule changes
//! is locality: a tile's dots reuse the panel's pivot rows (≤
//! `CHOL_PANEL · n` floats, cache-resident) instead of re-streaming the
//! whole factored triangle per row, which is what the seed loop did.
//!
//! The triangular solves route through [`kernels::subdot_wide`] (the
//! sequential-decrement substitution kernel); back-substitution reads
//! `Lᵀ` rows — contiguous — instead of striding down columns of `L`.
//! [`cholesky_solve_inplace`] builds the transpose once per solve batch.

use super::mat::Mat;
use crate::linalg::kernels;
use anyhow::{bail, Result};

/// Column-panel width of the blocked [`cholesky`]. A panel's pivot-row
/// block is `CHOL_PANEL × n` f32 (64 KiB at n = 256), which stays
/// L2-resident while a row tile sweeps over it.
pub const CHOL_PANEL: usize = 64;

/// Row-tile height of the sub-diagonal fill: tile rows' own prefixes stay
/// L1-hot across the panel's columns.
pub const CHOL_ROW_TILE: usize = 32;

/// Cholesky factorization: returns lower-triangular `L` with `A = L·Lᵀ`.
/// Fails if the matrix is not positive definite (same pivot index and
/// discriminant as the scalar reference — the element schedule is blocked
/// but the per-element arithmetic is unchanged).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    let ld = &mut l.data;
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + CHOL_PANEL).min(n);
        // factor the diagonal block, column by column: pivot j needs row j
        // finalized through column j−1 (previous panels + this block)
        for j in j0..j1 {
            let s = kernels::dot_wide(&ld[j * n..j * n + j], &ld[j * n..j * n + j]);
            let d = a.at(j, j) as f64 - s;
            if d <= 0.0 {
                bail!("matrix not positive definite at pivot {} (d={})", j, d);
            }
            ld[j * n + j] = d.sqrt() as f32;
            for i in j + 1..j1 {
                let s = kernels::dot_wide(&ld[i * n..i * n + j], &ld[j * n..j * n + j]);
                ld[i * n + j] = ((a.at(i, j) as f64 - s) / ld[j * n + j] as f64) as f32;
            }
        }
        // sub-diagonal fill in row tiles; within a row, columns ascend so
        // the row's own panel prefix is always finalized before it is read
        let mut i0 = j1;
        while i0 < n {
            let i1 = (i0 + CHOL_ROW_TILE).min(n);
            for i in i0..i1 {
                for j in j0..j1 {
                    let s =
                        kernels::dot_wide(&ld[i * n..i * n + j], &ld[j * n..j * n + j]);
                    ld[i * n + j] = ((a.at(i, j) as f64 - s) / ld[j * n + j] as f64) as f32;
                }
            }
            i0 = i1;
        }
        j0 = j1;
    }
    Ok(l)
}

/// Solve `A x = b` in place for SPD `A` given its Cholesky factor `L`.
/// Builds `Lᵀ` for the back-substitution pass; batch callers should
/// transpose once and use [`cholesky_solve_with_t`].
pub fn cholesky_solve_with(l: &Mat, b: &mut [f32]) {
    let lt = l.transpose();
    cholesky_solve_with_t(l, &lt, b);
}

/// Solve `A x = b` in place given the factor `L` *and* its transpose
/// (amortizes the transpose across a batch of right-hand sides). Both
/// substitution sweeps are sequential-decrement [`kernels::subdot_wide`]
/// walks over contiguous rows — bit-for-bit the historical scalar loops,
/// which strided down columns of `L` in the backward pass.
pub fn cholesky_solve_with_t(l: &Mat, lt: &Mat, b: &mut [f32]) {
    let n = l.rows;
    assert_eq!(b.len(), n);
    debug_assert_eq!(lt.rows, n);
    // forward: L y = b
    for i in 0..n {
        let s = kernels::subdot_wide(b[i] as f64, &l.data[i * n..i * n + i], &b[..i]);
        b[i] = (s / l.at(i, i) as f64) as f32;
    }
    // backward: Lᵀ x = y (row i of Lᵀ = column i of L, contiguous in lt)
    for i in (0..n).rev() {
        let s = kernels::subdot_wide(
            b[i] as f64,
            &lt.data[i * n + i + 1..(i + 1) * n],
            &b[i + 1..],
        );
        b[i] = (s / l.at(i, i) as f64) as f32;
    }
}

/// Solve `A X = B` for SPD `A` (B given column-wise as a matrix), in place.
pub fn cholesky_solve_inplace(a: &Mat, b: &mut Mat) -> Result<()> {
    let l = cholesky(a)?;
    let lt = l.transpose();
    let n = a.rows;
    let mut col = vec![0.0f32; n];
    for j in 0..b.cols {
        for i in 0..n {
            col[i] = b.at(i, j);
        }
        cholesky_solve_with_t(&l, &lt, &mut col);
        for i in 0..n {
            *b.at_mut(i, j) = col[i];
        }
    }
    Ok(())
}

/// Inverse of an SPD matrix via Cholesky.
pub fn cholesky_inverse(a: &Mat) -> Result<Mat> {
    let mut inv = Mat::eye(a.rows);
    cholesky_solve_inplace(a, &mut inv)?;
    Ok(inv)
}

/// Inverse via partially pivoted LU (general square matrices).
pub fn lu_inverse(a: &Mat) -> Result<Mat> {
    assert_eq!(a.rows, a.cols, "lu_inverse needs a square matrix");
    let n = a.rows;
    // Work in f64 for stability; shapes are small (≤ 512).
    let mut lu: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut piv: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // pivot
        let mut p = k;
        let mut pmax = lu[k * n + k].abs();
        for i in k + 1..n {
            let v = lu[i * n + k].abs();
            if v > pmax {
                pmax = v;
                p = i;
            }
        }
        if pmax < 1e-300 {
            bail!("singular matrix at pivot {}", k);
        }
        if p != k {
            for j in 0..n {
                lu.swap(k * n + j, p * n + j);
            }
            piv.swap(k, p);
        }
        let pivot = lu[k * n + k];
        for i in k + 1..n {
            let f = lu[i * n + k] / pivot;
            lu[i * n + k] = f;
            for j in k + 1..n {
                lu[i * n + j] -= f * lu[k * n + j];
            }
        }
    }
    // Solve A X = I column by column using the LU factors.
    let mut inv = Mat::zeros(n, n);
    let mut col = vec![0.0f64; n];
    for c in 0..n {
        for i in 0..n {
            col[i] = if piv[i] == c { 1.0 } else { 0.0 };
        }
        // forward
        for i in 0..n {
            for k in 0..i {
                col[i] -= lu[i * n + k] * col[k];
            }
        }
        // backward
        for i in (0..n).rev() {
            for k in i + 1..n {
                col[i] -= lu[i * n + k] * col[k];
            }
            col[i] /= lu[i * n + i];
        }
        for i in 0..n {
            *inv.at_mut(i, c) = col[i] as f32;
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};
    use crate::util::rng::Rng64;

    fn random_spd(rng: &mut Rng64, n: usize) -> Mat {
        // A = BᵀB + I is SPD.
        let b = Mat::from_vec(n, n, gen::vec_normal(rng, n * n, 1.0));
        let mut g = b.gram();
        g.add_diag(1.0);
        g
    }

    /// SPD by diagonal dominance: `M + Mᵀ + (2n+1)·I` — O(n²) to build, so
    /// the large-size bitwise pins stay cheap (no O(n³) Gram).
    fn random_spd_dd(rng: &mut Rng64, n: usize) -> Mat {
        let m = gen::vec_normal(rng, n * n, 1.0);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                *a.at_mut(i, j) = m[i * n + j] + m[j * n + i];
            }
            *a.at_mut(i, i) += 2.0 * n as f32 + 1.0;
        }
        a
    }

    /// The seed's scalar Cholesky, verbatim — the bitwise reference the
    /// blocked schedule is pinned against.
    mod reference {
        use crate::linalg::mat::Mat;
        use anyhow::{bail, Result};

        pub fn cholesky_ref(a: &Mat) -> Result<Mat> {
            let n = a.rows;
            let mut l = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..=i {
                    let mut s = 0.0f64;
                    for k in 0..j {
                        s += l.at(i, k) as f64 * l.at(j, k) as f64;
                    }
                    if i == j {
                        let d = a.at(i, i) as f64 - s;
                        if d <= 0.0 {
                            bail!("matrix not positive definite at pivot {} (d={})", i, d);
                        }
                        *l.at_mut(i, j) = d.sqrt() as f32;
                    } else {
                        *l.at_mut(i, j) =
                            ((a.at(i, j) as f64 - s) / l.at(j, j) as f64) as f32;
                    }
                }
            }
            Ok(l)
        }

        pub fn solve_with_ref(l: &Mat, b: &mut [f32]) {
            let n = l.rows;
            for i in 0..n {
                let mut s = b[i] as f64;
                for k in 0..i {
                    s -= l.at(i, k) as f64 * b[k] as f64;
                }
                b[i] = (s / l.at(i, i) as f64) as f32;
            }
            for i in (0..n).rev() {
                let mut s = b[i] as f64;
                for k in i + 1..n {
                    s -= l.at(k, i) as f64 * b[k] as f64;
                }
                b[i] = (s / l.at(i, i) as f64) as f32;
            }
        }
    }

    fn assert_bitwise_eq_mat(got: &Mat, want: &Mat, tag: &str) {
        assert_eq!(got.rows, want.rows, "{tag}: rows");
        for (k, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: element {k}");
        }
    }

    #[test]
    fn blocked_cholesky_bitwise_matches_scalar_reference() {
        // random sizes around the row-tile boundary…
        forall(
            "cholesky-bitwise",
            |r| {
                let n = gen::usize_in(r, 0, 40);
                random_spd(r, n)
            },
            |a| {
                let blocked = cholesky(a).unwrap();
                let scalar = reference::cholesky_ref(a).unwrap();
                blocked
                    .data
                    .iter()
                    .zip(&scalar.data)
                    .all(|(x, y)| x.to_bits() == y.to_bits())
            },
        );
        // …and explicit pins straddling CHOL_ROW_TILE / CHOL_PANEL /
        // multi-panel boundaries up to 600 (the same span the PR-1 mirror
        // kernel pins)
        let mut rng = Rng64::new(41);
        for n in [
            0usize,
            1,
            CHOL_ROW_TILE - 1,
            CHOL_ROW_TILE,
            CHOL_ROW_TILE + 1,
            CHOL_PANEL - 1,
            CHOL_PANEL,
            CHOL_PANEL + 1,
            2 * CHOL_PANEL + CHOL_ROW_TILE + 5,
            256,
            600,
        ] {
            let a = random_spd_dd(&mut rng, n);
            let blocked = cholesky(&a).unwrap();
            let scalar = reference::cholesky_ref(&a).unwrap();
            assert_bitwise_eq_mat(&blocked, &scalar, &format!("cholesky n={n}"));
        }
    }

    #[test]
    fn kernel_solves_bitwise_match_scalar_reference() {
        forall(
            "cholesky-solve-bitwise",
            |r| {
                let n = gen::usize_in(r, 0, 40);
                let a = random_spd(r, n);
                let b = gen::vec_normal(r, n, 1.0);
                (a, b)
            },
            |(a, b)| {
                let l = cholesky(a).unwrap();
                let mut kernel = b.clone();
                cholesky_solve_with(&l, &mut kernel);
                let mut scalar = b.clone();
                reference::solve_with_ref(&l, &mut scalar);
                kernel
                    .iter()
                    .zip(&scalar)
                    .all(|(x, y)| x.to_bits() == y.to_bits())
            },
        );
        // a large pin so the transposed back-substitution crosses many
        // cache lines
        let mut rng = Rng64::new(43);
        for n in [CHOL_PANEL + 3, 300] {
            let a = random_spd_dd(&mut rng, n);
            let l = cholesky(&a).unwrap();
            let b = gen::vec_normal(&mut rng, n, 1.0);
            let mut kernel = b.clone();
            cholesky_solve_with(&l, &mut kernel);
            let mut scalar = b;
            reference::solve_with_ref(&l, &mut scalar);
            for (k, (x, y)) in kernel.iter().zip(&scalar).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "solve n={n} idx {k}");
            }
        }
    }

    #[test]
    fn non_pd_failure_pivot_matches_reference() {
        // both paths must report the same first failing pivot
        let a = Mat::from_rows(&[
            &[4.0, 2.0, 0.5],
            &[2.0, 1.0, 0.3], // pivot 1 goes non-positive after elimination
            &[0.5, 0.3, 2.0],
        ]);
        let e_blocked = cholesky(&a).unwrap_err().to_string();
        let e_scalar = reference::cholesky_ref(&a).unwrap_err().to_string();
        assert_eq!(e_blocked, e_scalar);
        assert!(e_blocked.contains("pivot 1"), "{e_blocked}");
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng64::new(3);
        let a = random_spd(&mut rng, 8);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-3, "diff {}", rec.max_abs_diff(&a));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn spd_inverse_property() {
        forall(
            "cholesky-inverse",
            |r| {
                let n = gen::usize_in(r, 1, 12);
                random_spd(r, n)
            },
            |a| {
                let inv = cholesky_inverse(a).unwrap();
                let eye = a.matmul(&inv);
                eye.max_abs_diff(&Mat::eye(a.rows)) < 1e-2
            },
        );
    }

    #[test]
    fn lu_inverse_property() {
        forall(
            "lu-inverse",
            |r| {
                let n = gen::usize_in(r, 1, 12);
                // General well-conditioned matrix: random + n·I
                let mut m = Mat::from_vec(n, n, gen::vec_normal(r, n * n, 1.0));
                m.add_diag(n as f32);
                m
            },
            |a| {
                let inv = lu_inverse(a).unwrap();
                a.matmul(&inv).max_abs_diff(&Mat::eye(a.rows)) < 1e-2
            },
        );
    }

    #[test]
    fn lu_rejects_singular() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(lu_inverse(&a).is_err());
    }

    #[test]
    fn lu_matches_cholesky_on_spd() {
        let mut rng = Rng64::new(17);
        let a = random_spd(&mut rng, 16);
        let i1 = cholesky_inverse(&a).unwrap();
        let i2 = lu_inverse(&a).unwrap();
        assert!(i1.max_abs_diff(&i2) < 1e-2);
    }

    #[test]
    fn solve_matches_inverse() {
        let mut rng = Rng64::new(23);
        let a = random_spd(&mut rng, 10);
        let b = Mat::from_vec(10, 3, gen::vec_normal(&mut rng, 30, 1.0));
        let mut x = b.clone();
        cholesky_solve_inplace(&a, &mut x).unwrap();
        let x2 = cholesky_inverse(&a).unwrap().matmul(&b);
        assert!(x.max_abs_diff(&x2) < 1e-2);
    }
}
