//! Small dense linear algebra over `f32`/`f64` (row-major), sufficient for
//! OS-ELM: matmul, matvec, transpose, symmetric solves (Cholesky) and a
//! pivoted-LU fallback for the batch initialization `P₀ = (H₀ᵀH₀+λI)⁻¹`.
//!
//! No external BLAS — the shapes here (N ≤ 512) don't warrant one, and the
//! offline vendor set has none. The hot path (rank-1 OS-ELM update) is
//! hand-written in `crate::odl` against raw slices; this module serves
//! initialization, baselines, PCA, and tests.

pub mod mat;
pub mod solve;

pub use mat::Mat;
pub use solve::{cholesky_inverse, cholesky_solve_inplace, lu_inverse};
