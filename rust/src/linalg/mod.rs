//! Small dense linear algebra over `f32`/`f64` (row-major), sufficient for
//! OS-ELM: matmul, matvec, transpose, symmetric solves (Cholesky) and a
//! pivoted-LU fallback for the batch initialization `P₀ = (H₀ᵀH₀+λI)⁻¹`.
//!
//! No external BLAS — the shapes here (N ≤ 512) don't warrant one, and the
//! offline vendor set has none. Instead, [`kernels`] provides the
//! fixed-width (8-lane chunked, autovectorization-friendly) micro-kernels
//! that every hot path in the crate bottoms out in: the OS-ELM sequential
//! update and packed-α hidden panel in `crate::odl`, the Q16.16 hardware
//! model in `crate::fixed`, the drift detectors, and PCA. [`Mat`]'s
//! `matmul`/`gram`/`matvec` route through the same kernels, so the batch
//! initialization and the baselines speed up together with the hot path.

pub mod kernels;
pub mod mat;
pub mod solve;

pub use mat::Mat;
pub use solve::{cholesky_inverse, cholesky_solve_inplace, lu_inverse};
