//! Row-major dense matrix over `f32`.

use super::kernels;
use std::fmt;

/// Row-major `rows × cols` matrix of `f32`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 36 {
            writeln!(f)?;
            for r in 0..self.rows {
                writeln!(f, "  {:?}", &self.data[r * self.cols..(r + 1) * self.cols])?;
            }
        }
        Ok(())
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self::from_vec(r, c, data)
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// `self · other` via the cache-blocked [`kernels::gemm`] (bitwise the
    /// naive ikj loop, but L1/L2-blocked and autovectorized).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        kernels::gemm(
            &self.data,
            &other.data,
            &mut out.data,
            self.rows,
            self.cols,
            other.cols,
        );
        out
    }

    /// `self · v` for a column vector `v`.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        let mut out = vec![0.0; self.rows];
        kernels::matvec(&self.data, self.rows, self.cols, v, &mut out);
        out
    }

    /// `selfᵀ · self` (Gram matrix) without materializing the transpose —
    /// upper triangle accumulated by [`kernels::gram`], mirrored exactly.
    pub fn gram(&self) -> Mat {
        let mut g = Mat::zeros(self.cols, self.cols);
        kernels::gram(&self.data, self.rows, self.cols, &mut g.data);
        g
    }

    /// Add `lambda` to the diagonal in place.
    pub fn add_diag(&mut self, lambda: f32) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += lambda;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a-b| over entries.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Dot product (8-lane chunked; see [`kernels::dot`]). Kept here because
/// half the crate imports it as `linalg::mat::dot`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    kernels::dot(a, b)
}

/// `y += alpha * x` (axpy); see [`kernels::axpy`].
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    kernels::axpy(alpha, x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};
    use crate::util::rng::Rng64;

    fn random_mat(rng: &mut Rng64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, gen::vec_normal(rng, r * c, 1.0))
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng64::new(1);
        let a = random_mat(&mut rng, 5, 5);
        let i = Mat::eye(5);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-6);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn transpose_involution() {
        forall(
            "transpose-involution",
            |r| {
                let rows = gen::usize_in(r, 1, 8);
                let cols = gen::usize_in(r, 1, 8);
                random_mat(r, rows, cols)
            },
            |m| m.transpose().transpose() == *m,
        );
    }

    #[test]
    fn matmul_transpose_property() {
        // (AB)ᵀ = BᵀAᵀ
        forall(
            "matmul-transpose",
            |r| {
                let (m, k, n) = (
                    gen::usize_in(r, 1, 6),
                    gen::usize_in(r, 1, 6),
                    gen::usize_in(r, 1, 6),
                );
                (random_mat(r, m, k), random_mat(r, k, n))
            },
            |(a, b)| {
                let lhs = a.matmul(b).transpose();
                let rhs = b.transpose().matmul(&a.transpose());
                lhs.max_abs_diff(&rhs) < 1e-4
            },
        );
    }

    #[test]
    fn gram_equals_at_a() {
        forall(
            "gram",
            |r| {
                let rows = gen::usize_in(r, 1, 7);
                let cols = gen::usize_in(r, 1, 7);
                random_mat(r, rows, cols)
            },
            |a| a.gram().max_abs_diff(&a.transpose().matmul(a)) < 1e-4,
        );
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng64::new(2);
        let a = random_mat(&mut rng, 4, 7);
        let v = gen::vec_normal(&mut rng, 7, 1.0);
        let mv = a.matvec(&v);
        let vm = Mat::from_vec(7, 1, v.clone());
        let mm = a.matmul(&vm);
        for i in 0..4 {
            assert!((mv[i] - mm.data[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        forall(
            "dot-unrolled",
            |r| {
                let n = gen::usize_in(r, 0, 33);
                let a = gen::vec_normal(r, n, 1.0);
                let b = gen::vec_normal(r, n, 1.0);
                (a, b)
            },
            |(a, b)| {
                let naive: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
                (dot(a, b) - naive).abs() <= 1e-4 * (1.0 + naive.abs())
            },
        );
    }

    #[test]
    fn add_diag() {
        let mut m = Mat::zeros(3, 3);
        m.add_diag(2.5);
        assert_eq!(m.at(1, 1), 2.5);
        assert_eq!(m.at(0, 1), 0.0);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_checked() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
