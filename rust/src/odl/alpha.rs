//! Input-weight (α) providers: the paper's two variants.
//!
//! * **ODLBase** (`AlphaKind::Stored`): 32-bit random values stored as α —
//!   n·N words of SRAM on the ASIC.
//! * **ODLHash** (`AlphaKind::Hash`): α regenerated from a 16-bit Xorshift,
//!   zero SRAM (Table 1's memory win comes exactly from dropping this
//!   array).
//!
//! Both variants expose α through the same interface; the golden model
//! materializes the matrix once per model instance (host memory is not the
//! constrained resource here — the *hardware* memory model in
//! [`crate::hw::memory`] is what tracks the paper's SRAM cost).
//!
//! # Packed hidden panels
//!
//! Besides the row-major matrix, each provider builds a **column-packed
//! panel layout** once at construction: hidden units are grouped into
//! [`LANES`]-wide panels, and within a panel the weights are interleaved
//! by input feature (`panel[i·LANES + l] = α[i][j₀+l]`). The hidden
//! pre-activation then becomes a blocked panel-matvec whose inner loop is
//! exactly `LANES` independent multiply-adds — the accumulators live in
//! registers for the entire feature walk, eliminating the per-feature
//! load/store sweep over the N-wide accumulator that the seed's row-axpy
//! formulation paid (the dominant memory traffic of `predict` and
//! `train_step`). [`AlphaProvider::accumulate_hidden_batch`] additionally
//! reuses each streamed panel across a whole block of samples, which is
//! what makes batched predict cache-efficient at fleet scale.
//!
//! The panel walk accumulates features in ascending order — the same
//! association as the seed's axpy walk — so per-sample results are
//! bitwise identical between `accumulate_hidden`, the batched variant,
//! and the naive column dot (modulo the seed's skip of exact-zero inputs,
//! which only ever differed on signed zeros).
//!
//! The `*_sigmoid` variants fuse G1 into the panel epilogue: the sigmoid
//! is applied to the `LANES` accumulators before they are stored, so the
//! hidden *activation* block is produced in one pass with no second
//! read-modify-write sweep over `rows × N` — this is the hidden layer the
//! OS-ELM hot paths actually consume.

use super::activation::sigmoid;
use super::xorshift::counter_alpha;
use crate::linalg::kernels::LANES;
use crate::util::rng::Rng64;

/// Which α scheme a model uses. Carried through configs, experiment
/// harnesses, and the hardware memory model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlphaKind {
    /// ODLBase: stored 32-bit random weights.
    Stored,
    /// ODLHash: 16-bit Xorshift-generated weights (counter-based variant).
    Hash,
}

impl AlphaKind {
    pub fn label(&self) -> &'static str {
        match self {
            AlphaKind::Stored => "ODLBase",
            AlphaKind::Hash => "ODLHash",
        }
    }
}

/// A materialized α matrix (n × hidden, row-major) plus its provenance and
/// the packed panel layout (see module docs).
#[derive(Clone, Debug)]
pub struct AlphaProvider {
    pub kind: AlphaKind,
    pub n: usize,
    pub hidden: usize,
    pub scale: f32,
    data: Vec<f32>,
    /// `ceil(hidden/LANES)` panels of `n × LANES` interleaved weights.
    panels: Vec<f32>,
}

impl AlphaProvider {
    /// ODLBase: α ~ U[−1, 1) · scale from the experiment RNG stream.
    pub fn stored(rng: &mut Rng64, n: usize, hidden: usize, scale: f32) -> Self {
        let data = (0..n * hidden)
            .map(|_| rng.uniform(-1.0, 1.0) as f32 * scale)
            .collect();
        Self::from_data(AlphaKind::Stored, n, hidden, scale, data)
    }

    /// ODLHash: α from the counter-based 16-bit Xorshift (kernel-identical).
    pub fn hash(seed: u16, n: usize, hidden: usize, scale: f32) -> Self {
        let data = counter_alpha(seed, n, hidden, scale);
        Self::from_data(AlphaKind::Hash, n, hidden, scale, data)
    }

    /// ODLHash with the ASIC's *sequential* Xorshift stream — feature-
    /// compatible with [`crate::odl::fixed_oselm::FixedOsElm`] (used for
    /// float↔fixed co-simulation handoffs).
    pub fn hash_sequential(seed: u16, n: usize, hidden: usize, scale: f32) -> Self {
        let data = super::xorshift::sequential_alpha(seed, n, hidden, scale);
        Self::from_data(AlphaKind::Hash, n, hidden, scale, data)
    }

    /// Build from a materialized weight matrix, packing the panels.
    fn from_data(kind: AlphaKind, n: usize, hidden: usize, scale: f32, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * hidden, "alpha shape mismatch");
        let n_panels = hidden.div_ceil(LANES);
        let mut panels = vec![0.0f32; n_panels * n * LANES];
        for pp in 0..n_panels {
            let j0 = pp * LANES;
            let w = LANES.min(hidden - j0);
            let base = pp * n * LANES;
            for i in 0..n {
                for l in 0..w {
                    panels[base + i * LANES + l] = data[i * hidden + j0 + l];
                }
            }
        }
        Self {
            kind,
            n,
            hidden,
            scale,
            data,
            panels,
        }
    }

    /// Row-major (n × hidden) weight data.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Column `j` gathered (used by tests; the hot path walks panels).
    pub fn column(&self, j: usize) -> Vec<f32> {
        (0..self.n).map(|i| self.data[i * self.hidden + j]).collect()
    }

    /// Hidden pre-activation `xᵀ·α` into `out` (length hidden).
    #[inline]
    pub fn accumulate_hidden(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.n, "input dim mismatch");
        assert_eq!(out.len(), self.hidden, "hidden dim mismatch");
        self.accumulate_hidden_batch(x, 1, out);
    }

    /// Hidden pre-activations for a block of `rows` samples: `xs` is
    /// row-major `rows × n`, `out` row-major `rows × hidden`.
    ///
    /// Panels are the outer loop so each `n × LANES` weight panel is
    /// streamed from cache once per *block* instead of once per sample;
    /// per sample the result is bitwise identical to
    /// [`Self::accumulate_hidden`].
    pub fn accumulate_hidden_batch(&self, xs: &[f32], rows: usize, out: &mut [f32]) {
        self.panel_matvec::<false>(xs, rows, out);
    }

    /// Hidden **activations** for one sample: `out = σ(xᵀ·α)`, with the
    /// sigmoid fused into the panel epilogue (see the batched variant).
    #[inline]
    pub fn accumulate_hidden_sigmoid(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.n, "input dim mismatch");
        assert_eq!(out.len(), self.hidden, "hidden dim mismatch");
        self.panel_matvec::<true>(x, 1, out);
    }

    /// Hidden activations for a block: `out = σ(xs·α)` row-major. G1 runs
    /// on the `LANES` accumulators while they are still in registers, so
    /// the activation costs zero extra memory traffic — the seed schedule
    /// instead wrote the `rows × N` pre-activation block and re-read it in
    /// a second `sigmoid_inplace` sweep. Applying the same scalar function
    /// to the same f32 values, the result is bitwise identical to
    /// [`Self::accumulate_hidden_batch`] followed by that sweep.
    pub fn accumulate_hidden_batch_sigmoid(&self, xs: &[f32], rows: usize, out: &mut [f32]) {
        self.panel_matvec::<true>(xs, rows, out);
    }

    fn panel_matvec<const SIGMOID: bool>(&self, xs: &[f32], rows: usize, out: &mut [f32]) {
        assert_eq!(xs.len(), rows * self.n, "input block shape mismatch");
        assert_eq!(out.len(), rows * self.hidden, "output block shape mismatch");
        let n = self.n;
        let h = self.hidden;
        if n == 0 {
            out.fill(if SIGMOID { sigmoid(0.0) } else { 0.0 });
            return;
        }
        for (pp, panel) in self.panels.chunks_exact(n * LANES).enumerate() {
            let j0 = pp * LANES;
            let w = LANES.min(h - j0);
            for r in 0..rows {
                let x = &xs[r * n..(r + 1) * n];
                let mut acc = [0.0f32; LANES];
                for (&xi, lane) in x.iter().zip(panel.chunks_exact(LANES)) {
                    for l in 0..LANES {
                        acc[l] += xi * lane[l];
                    }
                }
                if SIGMOID {
                    for a in acc[..w].iter_mut() {
                        *a = sigmoid(*a);
                    }
                }
                out[r * h + j0..r * h + j0 + w].copy_from_slice(&acc[..w]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_alpha_reproducible() {
        let a = AlphaProvider::hash(7, 20, 10, 1.0);
        let b = AlphaProvider::hash(7, 20, 10, 1.0);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn stored_alpha_in_range() {
        let mut rng = Rng64::new(1);
        let a = AlphaProvider::stored(&mut rng, 50, 16, 0.5);
        assert!(a.data().iter().all(|&w| (-0.5..0.5).contains(&w)));
        assert_eq!(a.data().len(), 50 * 16);
    }

    #[test]
    fn accumulate_hidden_matches_matvec() {
        let a = AlphaProvider::hash(3, 12, 5, 1.0);
        let x: Vec<f32> = (0..12).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut out = vec![0.0f32; 5];
        a.accumulate_hidden(&x, &mut out);
        for j in 0..5 {
            let col = a.column(j);
            let expect: f32 = x.iter().zip(&col).map(|(u, v)| u * v).sum();
            assert!((out[j] - expect).abs() < 1e-4, "col {j}");
        }
    }

    #[test]
    fn batch_matches_per_row_bitwise() {
        // Batched panel matvec must equal the one-sample path bit for bit,
        // across hidden sizes that are below / on / off the lane boundary.
        for hidden in [1, 7, 8, 9, 16, 24, 31] {
            let a = AlphaProvider::hash(11, 23, hidden, 0.7);
            let rows = 5;
            let xs: Vec<f32> = (0..rows * 23)
                .map(|i| ((i as f32) * 0.213).sin() * 1.3)
                .collect();
            let mut batch = vec![0.0f32; rows * hidden];
            a.accumulate_hidden_batch(&xs, rows, &mut batch);
            let mut single = vec![0.0f32; hidden];
            for r in 0..rows {
                a.accumulate_hidden(&xs[r * 23..(r + 1) * 23], &mut single);
                for j in 0..hidden {
                    assert_eq!(
                        batch[r * hidden + j].to_bits(),
                        single[j].to_bits(),
                        "row {r} unit {j} hidden {hidden}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_sigmoid_epilogue_bitwise_matches_two_pass() {
        use crate::odl::activation::sigmoid_inplace;
        for (n, hidden) in [(23usize, 1usize), (23, 7), (23, 8), (23, 24), (23, 31), (0, 5)] {
            let a = AlphaProvider::hash(13, n, hidden, 0.7);
            let rows = 5;
            let xs: Vec<f32> = (0..rows * n)
                .map(|i| ((i as f32) * 0.171).sin() * 1.7)
                .collect();
            // reference: raw panel matvec + separate sigmoid sweep
            let mut two_pass = vec![0.0f32; rows * hidden];
            a.accumulate_hidden_batch(&xs, rows, &mut two_pass);
            sigmoid_inplace(&mut two_pass);
            // fused batch
            let mut fused = vec![0.0f32; rows * hidden];
            a.accumulate_hidden_batch_sigmoid(&xs, rows, &mut fused);
            for (k, (f, t)) in fused.iter().zip(&two_pass).enumerate() {
                assert_eq!(f.to_bits(), t.to_bits(), "n {n} hidden {hidden} idx {k}");
            }
            // fused single-sample
            let mut single = vec![0.0f32; hidden];
            for r in 0..rows {
                a.accumulate_hidden_sigmoid(&xs[r * n..(r + 1) * n], &mut single);
                for j in 0..hidden {
                    assert_eq!(
                        single[j].to_bits(),
                        two_pass[r * hidden + j].to_bits(),
                        "row {r} unit {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn panel_layout_covers_all_weights() {
        // Every α entry must land in exactly one panel slot (padding aside):
        // reconstruct columns from the hot path by probing with basis inputs.
        let a = AlphaProvider::stored(&mut Rng64::new(3), 9, 13, 1.0);
        let mut out = vec![0.0f32; 13];
        for i in 0..9 {
            let mut e = vec![0.0f32; 9];
            e[i] = 1.0;
            a.accumulate_hidden(&e, &mut out);
            for j in 0..13 {
                assert_eq!(out[j].to_bits(), a.data()[i * 13 + j].to_bits());
            }
        }
    }

    #[test]
    fn labels() {
        assert_eq!(AlphaKind::Stored.label(), "ODLBase");
        assert_eq!(AlphaKind::Hash.label(), "ODLHash");
    }
}
