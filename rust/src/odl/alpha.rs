//! Input-weight (α) providers: the paper's two variants.
//!
//! * **ODLBase** (`AlphaKind::Stored`): 32-bit random values stored as α —
//!   n·N words of SRAM on the ASIC.
//! * **ODLHash** (`AlphaKind::Hash`): α regenerated from a 16-bit Xorshift,
//!   zero SRAM (Table 1's memory win comes exactly from dropping this
//!   array).
//!
//! Both variants expose α through the same interface; the golden model
//! materializes the matrix once per model instance (host memory is not the
//! constrained resource here — the *hardware* memory model in
//! [`crate::hw::memory`] is what tracks the paper's SRAM cost).

use super::xorshift::counter_alpha;
use crate::util::rng::Rng64;

/// Which α scheme a model uses. Carried through configs, experiment
/// harnesses, and the hardware memory model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlphaKind {
    /// ODLBase: stored 32-bit random weights.
    Stored,
    /// ODLHash: 16-bit Xorshift-generated weights (counter-based variant).
    Hash,
}

impl AlphaKind {
    pub fn label(&self) -> &'static str {
        match self {
            AlphaKind::Stored => "ODLBase",
            AlphaKind::Hash => "ODLHash",
        }
    }
}

/// A materialized α matrix (n × hidden, row-major) plus its provenance.
#[derive(Clone, Debug)]
pub struct AlphaProvider {
    pub kind: AlphaKind,
    pub n: usize,
    pub hidden: usize,
    pub scale: f32,
    data: Vec<f32>,
}

impl AlphaProvider {
    /// ODLBase: α ~ U[−1, 1) · scale from the experiment RNG stream.
    pub fn stored(rng: &mut Rng64, n: usize, hidden: usize, scale: f32) -> Self {
        let data = (0..n * hidden)
            .map(|_| rng.uniform(-1.0, 1.0) as f32 * scale)
            .collect();
        Self {
            kind: AlphaKind::Stored,
            n,
            hidden,
            scale,
            data,
        }
    }

    /// ODLHash: α from the counter-based 16-bit Xorshift (kernel-identical).
    pub fn hash(seed: u16, n: usize, hidden: usize, scale: f32) -> Self {
        Self {
            kind: AlphaKind::Hash,
            n,
            hidden,
            scale,
            data: counter_alpha(seed, n, hidden, scale),
        }
    }

    /// ODLHash with the ASIC's *sequential* Xorshift stream — feature-
    /// compatible with [`crate::odl::fixed_oselm::FixedOsElm`] (used for
    /// float↔fixed co-simulation handoffs).
    pub fn hash_sequential(seed: u16, n: usize, hidden: usize, scale: f32) -> Self {
        Self {
            kind: AlphaKind::Hash,
            n,
            hidden,
            scale,
            data: super::xorshift::sequential_alpha(seed, n, hidden, scale),
        }
    }

    /// Row-major (n × hidden) weight data.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Column `j` gathered (used by tests; the hot path walks rows).
    pub fn column(&self, j: usize) -> Vec<f32> {
        (0..self.n).map(|i| self.data[i * self.hidden + j]).collect()
    }

    /// Hidden pre-activation `xᵀ·α` into `out` (length hidden).
    ///
    /// Row-major walk: for each input feature i, axpy its α row into the
    /// accumulator — sequential memory access on both x and α.
    pub fn accumulate_hidden(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.n, "input dim mismatch");
        assert_eq!(out.len(), self.hidden, "hidden dim mismatch");
        out.fill(0.0);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.data[i * self.hidden..(i + 1) * self.hidden];
            crate::linalg::mat::axpy(xi, row, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_alpha_reproducible() {
        let a = AlphaProvider::hash(7, 20, 10, 1.0);
        let b = AlphaProvider::hash(7, 20, 10, 1.0);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn stored_alpha_in_range() {
        let mut rng = Rng64::new(1);
        let a = AlphaProvider::stored(&mut rng, 50, 16, 0.5);
        assert!(a.data().iter().all(|&w| (-0.5..0.5).contains(&w)));
        assert_eq!(a.data().len(), 50 * 16);
    }

    #[test]
    fn accumulate_hidden_matches_matvec() {
        let a = AlphaProvider::hash(3, 12, 5, 1.0);
        let x: Vec<f32> = (0..12).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut out = vec![0.0f32; 5];
        a.accumulate_hidden(&x, &mut out);
        for j in 0..5 {
            let col = a.column(j);
            let expect: f32 = x.iter().zip(&col).map(|(u, v)| u * v).sum();
            assert!((out[j] - expect).abs() < 1e-4, "col {j}");
        }
    }

    #[test]
    fn labels() {
        assert_eq!(AlphaKind::Stored.label(), "ODLBase");
        assert_eq!(AlphaKind::Hash.label(), "ODLHash");
    }
}
