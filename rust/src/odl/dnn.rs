//! Backprop MLP baseline — the paper's "DNN (561,512,256,6)" row in
//! Table 3: a simple two-hidden-layer network trained with SGD, *without*
//! on-device learning capability (its Table-3 role is to show that even a
//! bigger offline-trained model degrades under drift).
//!
//! Implementation: plain SGD + momentum on softmax cross-entropy, ReLU
//! hidden layers, He init. A native rust twin of the L2 JAX definition in
//! `python/compile/model.py` (`dnn_*` graphs); the two are cross-checked
//! through the PJRT runtime in integration tests.

use crate::linalg::Mat;
use crate::util::rng::Rng64;
use crate::util::stats::argmax;

/// Layer sizes, e.g. [561, 512, 256, 6].
#[derive(Clone, Debug)]
pub struct DnnConfig {
    pub layers: Vec<usize>,
    pub lr: f32,
    pub momentum: f32,
    pub epochs: usize,
    pub batch: usize,
}

impl Default for DnnConfig {
    fn default() -> Self {
        Self {
            layers: vec![561, 512, 256, 6],
            lr: 0.05,
            momentum: 0.9,
            epochs: 10,
            batch: 32,
        }
    }
}

/// A trained / trainable MLP.
pub struct Dnn {
    pub cfg: DnnConfig,
    /// weights[l]: (layers[l] × layers[l+1]) row-major; biases[l]: layers[l+1].
    pub weights: Vec<Mat>,
    pub biases: Vec<Vec<f32>>,
    vel_w: Vec<Mat>,
    vel_b: Vec<Vec<f32>>,
}

impl Dnn {
    pub fn new(cfg: DnnConfig, rng: &mut Rng64) -> Self {
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        let mut vel_w = Vec::new();
        let mut vel_b = Vec::new();
        for w in cfg.layers.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let std = (2.0 / fan_in as f64).sqrt();
            let data: Vec<f32> = (0..fan_in * fan_out)
                .map(|_| rng.normal_ms(0.0, std) as f32)
                .collect();
            weights.push(Mat::from_vec(fan_in, fan_out, data));
            biases.push(vec![0.0; fan_out]);
            vel_w.push(Mat::zeros(fan_in, fan_out));
            vel_b.push(vec![0.0; fan_out]);
        }
        Self {
            cfg,
            weights,
            biases,
            vel_w,
            vel_b,
        }
    }

    pub fn n_params(&self) -> usize {
        self.weights
            .iter()
            .map(|w| w.data.len())
            .chain(self.biases.iter().map(|b| b.len()))
            .sum()
    }

    /// Forward pass for one sample; returns activations per layer
    /// (activations[0] = input, last = logits).
    fn forward(&self, x: &[f32]) -> Vec<Vec<f32>> {
        let n_layers = self.weights.len();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers + 1);
        acts.push(x.to_vec());
        for l in 0..n_layers {
            let input = &acts[l];
            let w = &self.weights[l];
            let mut out = self.biases[l].clone();
            for (i, &xi) in input.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                crate::linalg::mat::axpy(xi, w.row(i), &mut out);
            }
            if l + 1 < n_layers {
                for v in out.iter_mut() {
                    *v = v.max(0.0); // ReLU
                }
            }
            acts.push(out);
        }
        acts
    }

    /// Logits for one sample.
    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        self.forward(x).pop().unwrap()
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        argmax(&self.logits(x))
    }

    pub fn accuracy(&self, xs: &Mat, labels: &[usize]) -> f64 {
        if xs.rows == 0 {
            return 0.0;
        }
        let correct = (0..xs.rows)
            .filter(|&r| self.predict(xs.row(r)) == labels[r])
            .count();
        correct as f64 / xs.rows as f64
    }

    /// One SGD minibatch step on softmax cross-entropy; returns mean loss.
    pub fn train_batch(&mut self, xs: &Mat, labels: &[usize], rows: &[usize]) -> f64 {
        let n_layers = self.weights.len();
        let scale = 1.0 / rows.len() as f32;
        // gradient accumulators
        let mut gw: Vec<Mat> = self
            .weights
            .iter()
            .map(|w| Mat::zeros(w.rows, w.cols))
            .collect();
        let mut gb: Vec<Vec<f32>> = self.biases.iter().map(|b| vec![0.0; b.len()]).collect();
        let mut loss = 0.0f64;

        for &r in rows {
            let acts = self.forward(xs.row(r));
            let logits = acts.last().unwrap();
            let probs = crate::odl::activation::softmax(logits);
            let y = labels[r];
            loss += -((probs[y].max(1e-9)) as f64).ln();
            // delta at output: p − onehot(y)
            let mut delta: Vec<f32> = probs;
            delta[y] -= 1.0;
            for l in (0..n_layers).rev() {
                let input = &acts[l];
                // dW += inputᵀ · delta ; db += delta
                for (i, &xi) in input.iter().enumerate() {
                    if xi != 0.0 {
                        crate::linalg::mat::axpy(xi * scale, &delta, gw[l].row_mut(i));
                    }
                }
                crate::linalg::mat::axpy(scale, &delta, &mut gb[l]);
                if l > 0 {
                    // propagate: delta_prev = (W · delta) ⊙ relu'(z_prev)
                    let w = &self.weights[l];
                    let mut prev = vec![0.0f32; w.rows];
                    for (i, p) in prev.iter_mut().enumerate() {
                        *p = crate::linalg::mat::dot(w.row(i), &delta);
                    }
                    for (p, &a) in prev.iter_mut().zip(&acts[l]) {
                        if a <= 0.0 {
                            *p = 0.0;
                        }
                    }
                    delta = prev;
                }
            }
        }

        // momentum update
        for l in 0..n_layers {
            for (v, g) in self.vel_w[l].data.iter_mut().zip(&gw[l].data) {
                *v = self.cfg.momentum * *v - self.cfg.lr * g;
            }
            for (w, v) in self.weights[l].data.iter_mut().zip(&self.vel_w[l].data) {
                *w += v;
            }
            for (v, g) in self.vel_b[l].iter_mut().zip(&gb[l]) {
                *v = self.cfg.momentum * *v - self.cfg.lr * g;
            }
            for (b, v) in self.biases[l].iter_mut().zip(&self.vel_b[l]) {
                *b += v;
            }
        }
        loss / rows.len() as f64
    }

    /// Full training loop; returns final-epoch mean loss.
    pub fn fit(&mut self, xs: &Mat, labels: &[usize], rng: &mut Rng64) -> f64 {
        let mut order: Vec<usize> = (0..xs.rows).collect();
        let mut last = f64::NAN;
        for _ in 0..self.cfg.epochs {
            rng.shuffle(&mut order);
            let mut epoch_loss = 0.0;
            let mut batches = 0;
            for chunk in order.chunks(self.cfg.batch) {
                epoch_loss += self.train_batch(xs, labels, chunk);
                batches += 1;
            }
            last = epoch_loss / batches.max(1) as f64;
        }
        last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(rng: &mut Rng64, rows: usize, n_in: usize) -> (Mat, Vec<usize>) {
        let mut xs = Mat::zeros(rows, n_in);
        let mut labels = Vec::with_capacity(rows);
        for r in 0..rows {
            let c = rng.below(3);
            labels.push(c);
            for j in 0..n_in {
                let mean = if j < 3 {
                    if j == c {
                        1.5
                    } else {
                        -0.7
                    }
                } else {
                    0.0
                };
                *xs.at_mut(r, j) = rng.normal_ms(mean, 0.5) as f32;
            }
        }
        (xs, labels)
    }

    fn small_cfg() -> DnnConfig {
        DnnConfig {
            layers: vec![10, 16, 8, 3],
            lr: 0.05,
            momentum: 0.9,
            epochs: 15,
            batch: 16,
        }
    }

    #[test]
    fn learns_toy_problem() {
        let mut rng = Rng64::new(7);
        let (xs, labels) = toy(&mut rng, 300, 10);
        let mut dnn = Dnn::new(small_cfg(), &mut rng);
        let loss = dnn.fit(&xs, &labels, &mut rng);
        assert!(loss < 0.3, "final loss {loss}");
        assert!(dnn.accuracy(&xs, &labels) > 0.9);
    }

    #[test]
    fn param_count() {
        let mut rng = Rng64::new(1);
        let dnn = Dnn::new(
            DnnConfig {
                layers: vec![561, 512, 256, 6],
                ..small_cfg()
            },
            &mut rng,
        );
        // 561·512 + 512 + 512·256 + 256 + 256·6 + 6 = 420_486
        assert_eq!(dnn.n_params(), 561 * 512 + 512 + 512 * 256 + 256 + 256 * 6 + 6);
    }

    #[test]
    fn loss_decreases() {
        let mut rng = Rng64::new(9);
        let (xs, labels) = toy(&mut rng, 200, 10);
        let mut dnn = Dnn::new(small_cfg(), &mut rng);
        let rows: Vec<usize> = (0..xs.rows).collect();
        let l0 = dnn.train_batch(&xs, &labels, &rows);
        for _ in 0..10 {
            dnn.train_batch(&xs, &labels, &rows);
        }
        let l1 = dnn.train_batch(&xs, &labels, &rows);
        assert!(l1 < l0, "loss {l0} -> {l1}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut rng = Rng64::new(5);
            let (xs, labels) = toy(&mut rng, 100, 10);
            let mut dnn = Dnn::new(small_cfg(), &mut rng);
            dnn.fit(&xs, &labels, &mut rng);
            dnn.logits(&vec![0.3; 10])
        };
        assert_eq!(mk(), mk());
    }
}
