//! The paper's on-device-learning core: OS-ELM prediction + sequential
//! training, with the ODLHash weight-generation scheme (16-bit Xorshift in
//! place of stored random input weights), in three implementations:
//!
//! * [`oselm::OsElm`] — f32 golden model (the reference for everything),
//! * [`fixed_oselm::FixedOsElm`] — bit-level Q16.16 model of the ASIC
//!   datapath (what [`crate::hw::cycles`] charges cycles for),
//! * the AOT JAX/Pallas artifacts executed through [`crate::runtime`]
//!   (cross-checked against the golden model in integration tests).

pub mod activation;
pub mod alpha;
pub mod dnn;
pub mod fixed_oselm;
pub mod oselm;
pub mod xorshift;

pub use alpha::{AlphaKind, AlphaProvider};
pub use oselm::{OsElm, OsElmConfig};
pub use xorshift::{counter_alpha, counter_alpha_value, Xorshift16};
