//! OS-ELM (Liang et al. 2006) — the paper's ODL algorithm (Figure 2(b)/(d)).
//!
//! Network: `x ∈ Rⁿ → H = G1(x·α) ∈ R^N → O = H·β ∈ R^m`, α fixed
//! (stored or hash-generated, see [`super::alpha`]), β trained.
//!
//! * **Batch init** (time 0, k₀ ≥ N samples): `P₀ = (H₀ᵀH₀ + λI)⁻¹`,
//!   `β₀ = P₀·H₀ᵀ·Y₀` — ridge-regularized least squares.
//! * **Sequential update** (Figure 2(d), one sample): with `h = H_i`,
//!   `Ph = P_{i−1}·h`, `denom = 1 + hᵀ·Ph`,
//!   `P_i = P_{i−1} − Ph·Phᵀ/denom`,
//!   `β_i = β_{i−1} + Ph·(yᵀ − hᵀ·β_{i−1})/denom`
//!   (Sherman–Morrison form of recursive least squares).
//!
//! The update is the L3 **hot path**: it runs once per training-mode event
//! for every edge device, so it is written allocation-free against a
//! preallocated [`Workspace`] and bottoms out in the fixed-width kernels
//! of [`crate::linalg::kernels`]:
//!
//! * the hidden layer is a packed-α panel matvec
//!   ([`AlphaProvider::accumulate_hidden`]) whose accumulators stay in
//!   registers for the whole feature walk;
//! * the Sherman–Morrison P update uses `rank1_sym_update`, which touches
//!   only the upper triangle (P is symmetric by construction) and mirrors
//!   it — half the multiplies and read traffic of the full N² sweep, and
//!   P stays **exactly** symmetric (plus a periodic [`RESYM_EVERY`]
//!   re-symmetrization guarding externally loaded state);
//! * [`OsElm::predict_batch`] / [`OsElm::accuracy`] evaluate labelled sets
//!   in blocks of [`PREDICT_BLOCK`] samples against preallocated
//!   workspace buffers (no per-sample allocation), reusing each α panel
//!   across the block and computing logits with one blocked GEMM.

use super::activation::Prediction;
use super::alpha::{AlphaKind, AlphaProvider};
use crate::linalg::kernels;
use crate::linalg::{cholesky_inverse, lu_inverse, Mat};
use crate::util::parallel;
use crate::util::rng::Rng64;
use anyhow::{ensure, Context, Result};

/// Sample-block size for [`OsElm::predict_batch`] / [`OsElm::accuracy`]:
/// 32 × 128 hidden activations = 16 KiB, L1-resident next to the streamed
/// α panel.
pub const PREDICT_BLOCK: usize = 32;

/// Sequential steps between exact `P ← (P+Pᵀ)/2` re-symmetrizations. The
/// mirrored rank-1 update keeps P bitwise symmetric on its own; the
/// periodic pass (amortized cost ≈ N²/2 adds per [`RESYM_EVERY`] steps,
/// < 1 % of one update) bounds drift for state loaded from outside the
/// update loop (PJRT handoffs, checkpoint restores).
pub const RESYM_EVERY: u64 = 64;

/// Model hyperparameters (defaults = the paper's prototype: 561/128/6).
#[derive(Clone, Copy, Debug)]
pub struct OsElmConfig {
    /// Input features n.
    pub n_in: usize,
    /// Hidden nodes N.
    pub n_hidden: usize,
    /// Output classes m.
    pub n_out: usize,
    /// α scheme (ODLBase stored vs ODLHash).
    pub alpha: AlphaKind,
    /// Ridge regularization λ for the batch init.
    pub lambda: f32,
    /// α scale; 1/√n keeps pre-activations O(1) for standardized inputs.
    pub alpha_scale: Option<f32>,
}

impl Default for OsElmConfig {
    fn default() -> Self {
        Self {
            n_in: 561,
            n_hidden: 128,
            n_out: 6,
            alpha: AlphaKind::Hash,
            lambda: 0.01,
            alpha_scale: None,
        }
    }
}

impl OsElmConfig {
    pub fn scale(&self) -> f32 {
        self.alpha_scale
            .unwrap_or_else(|| 1.0 / (self.n_in as f32).sqrt())
    }
}

/// Preallocated scratch for the sequential update (no allocation per step).
#[derive(Clone, Debug)]
pub struct Workspace {
    /// Hidden activations h (N).
    pub h: Vec<f32>,
    /// P·h (N).
    pub ph: Vec<f32>,
    /// Prediction error e = y − hᵀβ (m).
    pub err: Vec<f32>,
    /// Output logits (m).
    pub logits: Vec<f32>,
    /// Hidden activations for one predict block (PREDICT_BLOCK × N).
    pub hblock: Vec<f32>,
    /// Logits for one predict block (PREDICT_BLOCK × m).
    pub logit_block: Vec<f32>,
}

impl Workspace {
    pub fn new(cfg: &OsElmConfig) -> Self {
        Self {
            h: vec![0.0; cfg.n_hidden],
            ph: vec![0.0; cfg.n_hidden],
            err: vec![0.0; cfg.n_out],
            logits: vec![0.0; cfg.n_out],
            hblock: vec![0.0; PREDICT_BLOCK * cfg.n_hidden],
            logit_block: vec![0.0; PREDICT_BLOCK * cfg.n_out],
        }
    }
}

/// The f32 OS-ELM golden model.
#[derive(Clone, Debug)]
pub struct OsElm {
    pub cfg: OsElmConfig,
    pub alpha: AlphaProvider,
    /// β ∈ R^{N×m}, row-major.
    pub beta: Mat,
    /// P ∈ R^{N×N}, row-major, symmetric.
    pub p: Mat,
    /// Number of sequential updates applied since init.
    pub steps: u64,
    ws: Workspace,
}

impl OsElm {
    /// Create with α drawn per the config; β/P zero until [`Self::init_batch`].
    pub fn new(cfg: OsElmConfig, rng: &mut Rng64, hash_seed: u16) -> Self {
        let scale = cfg.scale();
        let alpha = match cfg.alpha {
            AlphaKind::Stored => AlphaProvider::stored(rng, cfg.n_in, cfg.n_hidden, scale),
            AlphaKind::Hash => AlphaProvider::hash(hash_seed, cfg.n_in, cfg.n_hidden, scale),
        };
        Self {
            alpha,
            beta: Mat::zeros(cfg.n_hidden, cfg.n_out),
            p: Mat::zeros(cfg.n_hidden, cfg.n_hidden),
            steps: 0,
            ws: Workspace::new(&cfg),
            cfg,
        }
    }

    /// Replace the α provider (co-simulation / ablation hook). Resets β/P
    /// implicitly being invalid is the caller's concern; normally called
    /// before `init_batch`.
    pub fn set_alpha(&mut self, alpha: AlphaProvider) {
        assert_eq!(alpha.n, self.cfg.n_in, "alpha n mismatch");
        assert_eq!(alpha.hidden, self.cfg.n_hidden, "alpha hidden mismatch");
        self.alpha = alpha;
    }

    /// Hidden activations for one sample into `out`: `G1(x·α)`, with the
    /// sigmoid fused into the panel-matvec epilogue.
    pub fn hidden(&self, x: &[f32], out: &mut [f32]) {
        self.alpha.accumulate_hidden_sigmoid(x, out);
    }

    /// Hidden activations for a batch (rows of `xs`): one panel-blocked
    /// sweep over all rows (each α panel is streamed once per batch, and
    /// G1 is applied in the epilogue — no second sweep over `rows × N`).
    pub fn hidden_batch(&self, xs: &Mat) -> Mat {
        ensure_dim(xs.cols, self.cfg.n_in);
        let mut h = Mat::zeros(xs.rows, self.cfg.n_hidden);
        self.alpha
            .accumulate_hidden_batch_sigmoid(&xs.data, xs.rows, &mut h.data);
        h
    }

    /// Batch initialization on (X₀, labels): `P₀=(H₀ᵀH₀+λI)⁻¹`, `β₀=P₀H₀ᵀY₀`.
    pub fn init_batch(&mut self, xs: &Mat, labels: &[usize]) -> Result<()> {
        ensure!(
            xs.rows == labels.len(),
            "init_batch: {} rows vs {} labels",
            xs.rows,
            labels.len()
        );
        ensure!(
            xs.rows >= self.cfg.n_hidden,
            "OS-ELM init needs ≥ N samples ({} < {})",
            xs.rows,
            self.cfg.n_hidden
        );
        let h = self.hidden_batch(xs);
        let mut gram = h.gram();
        gram.add_diag(self.cfg.lambda);
        self.p = cholesky_inverse(&gram)
            .or_else(|_| lu_inverse(&gram))
            .context("OS-ELM init: Gram matrix inversion failed")?;
        // The inverse of a symmetric matrix is symmetric, but the factored
        // solve can carry ~1-ulp asymmetry; pin it exactly so the mirrored
        // sequential update keeps P bitwise symmetric from here on.
        kernels::symmetrize(&mut self.p.data, self.cfg.n_hidden);
        // β = P · Hᵀ · Y, computed as P · (Hᵀ Y) to stay N×m. Y is one-hot,
        // so HᵀY column c is the sum of the H rows labelled c: accumulate
        // per-class row sums with contiguous kernels::axpy sweeps (the
        // seed's loop wrote an m-strided column per sample), then lay the
        // class rows out as columns. Ascending-row accumulation per
        // (hidden, class) cell with 1.0·x = x, so the result is bitwise
        // the seed's strided walk.
        let mut class_acc = Mat::zeros(self.cfg.n_out, self.cfg.n_hidden);
        for (r, &lbl) in labels.iter().enumerate() {
            ensure!(lbl < self.cfg.n_out, "label {} out of range", lbl);
            kernels::axpy(1.0, h.row(r), class_acc.row_mut(lbl));
        }
        let mut hty = Mat::zeros(self.cfg.n_hidden, self.cfg.n_out);
        for c in 0..self.cfg.n_out {
            for j in 0..self.cfg.n_hidden {
                *hty.at_mut(j, c) = class_acc.at(c, j);
            }
        }
        self.beta = self.p.matmul(&hty);
        self.steps = 0;
        Ok(())
    }

    /// One sequential training step (Figure 2(d)). `label` is the one-hot
    /// target class (the teacher's `t_i`). Allocation-free.
    pub fn train_step(&mut self, x: &[f32], label: usize) {
        debug_assert!(label < self.cfg.n_out);
        let nh = self.cfg.n_hidden;
        let m = self.cfg.n_out;

        // h = G1(x·α) — packed-α panel matvec, sigmoid fused in the epilogue
        self.alpha.accumulate_hidden_sigmoid(x, &mut self.ws.h);

        // Ph = P·h ; denom = 1 + hᵀPh
        let (h, ph) = (&self.ws.h, &mut self.ws.ph);
        kernels::matvec(&self.p.data, nh, nh, h, ph);
        let denom = 1.0 + kernels::dot(h, ph);
        let inv_denom = 1.0 / denom;

        // err = y − hᵀβ (length m)
        for j in 0..m {
            self.ws.err[j] = if j == label { 1.0 } else { 0.0 };
        }
        for i in 0..nh {
            kernels::axpy(-h[i], self.beta.row(i), &mut self.ws.err);
        }

        // P ← P − Ph·Phᵀ/denom — upper triangle + exact mirror (P is
        // symmetric by construction; half the multiplies/reads of the
        // full sweep).
        kernels::rank1_sym_update(&mut self.p.data, nh, &self.ws.ph, inv_denom);

        // β ← β + Ph·errᵀ/denom
        for i in 0..nh {
            let s = self.ws.ph[i] * inv_denom;
            kernels::axpy(s, &self.ws.err, self.beta.row_mut(i));
        }

        self.steps += 1;
        if self.steps % RESYM_EVERY == 0 {
            kernels::symmetrize(&mut self.p.data, nh);
        }
    }

    /// Predict one sample: logits + class + P1P2 confidence.
    pub fn predict(&mut self, x: &[f32]) -> Prediction {
        let nh = self.cfg.n_hidden;
        self.alpha.accumulate_hidden_sigmoid(x, &mut self.ws.h);
        self.ws.logits.fill(0.0);
        for i in 0..nh {
            kernels::axpy(self.ws.h[i], self.beta.row(i), &mut self.ws.logits);
        }
        Prediction::from_logits(&self.ws.logits)
    }

    /// Logits of the most recent [`Self::predict`] / [`Self::logits_ref`]
    /// call — the borrow-based path for the Error-L2 pruning metric (one
    /// read per training-mode event; no allocation, no recompute).
    #[inline]
    pub fn last_logits(&self) -> &[f32] {
        &self.ws.logits
    }

    /// Raw logits for one sample, borrowed from the workspace
    /// (allocation-free; invalidated by the next predict/train call).
    pub fn logits_ref(&mut self, x: &[f32]) -> &[f32] {
        let _ = self.predict(x);
        &self.ws.logits
    }

    /// Raw logits for one sample as an owned vector (test convenience;
    /// hot paths use [`Self::logits_ref`] / [`Self::last_logits`]).
    pub fn logits(&mut self, x: &[f32]) -> Vec<f32> {
        self.logits_ref(x).to_vec()
    }

    /// Run the batched predict pipeline over the rows of `xs`, invoking
    /// `f(row, prediction)` per sample. Blocks of [`PREDICT_BLOCK`]
    /// samples share one α-panel sweep (sigmoid fused in its epilogue) and
    /// one logits GEMM against the preallocated workspace — no per-sample
    /// allocation, and per-sample results are bitwise identical to
    /// [`Self::predict`].
    pub fn for_each_prediction(&mut self, xs: &Mat, mut f: impl FnMut(usize, Prediction)) {
        ensure_dim(xs.cols, self.cfg.n_in);
        predict_rows(
            &self.alpha,
            &self.beta,
            self.cfg.n_out,
            xs,
            0,
            xs.rows,
            &mut self.ws.hblock,
            &mut self.ws.logit_block,
            &mut f,
        );
    }

    /// Predictions for every row of `xs` (one output allocation; the
    /// pipeline itself is workspace-backed).
    pub fn predict_batch(&mut self, xs: &Mat) -> Vec<Prediction> {
        let mut out = Vec::with_capacity(xs.rows);
        self.for_each_prediction(xs, |_, p| out.push(p));
        out
    }

    /// Classification accuracy over a labelled set (batched, allocation-
    /// free).
    pub fn accuracy(&mut self, xs: &Mat, labels: &[usize]) -> f64 {
        assert_eq!(xs.rows, labels.len());
        if xs.rows == 0 {
            return 0.0;
        }
        let mut correct = 0usize;
        self.for_each_prediction(xs, |r, p| {
            if p.class == labels[r] {
                correct += 1;
            }
        });
        correct as f64 / xs.rows as f64
    }

    /// Classification accuracy with the [`PREDICT_BLOCK`]-aligned sample
    /// blocks sharded across `workers` scoped threads, each with its own
    /// scratch (so `&self` suffices and shards never contend). Because the
    /// shard boundaries are block-aligned and per-sample correctness is an
    /// integer, the result is **bitwise identical** to [`Self::accuracy`]
    /// for every worker count — which is what lets the fleet's evaluation
    /// windows spend idle cores without perturbing recorded reports.
    pub fn accuracy_par(&self, xs: &Mat, labels: &[usize], workers: usize) -> f64 {
        assert_eq!(xs.rows, labels.len());
        ensure_dim(xs.cols, self.cfg.n_in);
        if xs.rows == 0 {
            return 0.0;
        }
        let nh = self.cfg.n_hidden;
        let m = self.cfg.n_out;
        let blocks = xs.rows.div_ceil(PREDICT_BLOCK);
        let workers = workers.max(1).min(blocks);
        let count_range = |r0: usize, r1: usize| -> usize {
            let mut hblock = vec![0.0f32; PREDICT_BLOCK * nh];
            let mut logit_block = vec![0.0f32; PREDICT_BLOCK * m];
            let mut correct = 0usize;
            predict_rows(
                &self.alpha,
                &self.beta,
                m,
                xs,
                r0,
                r1,
                &mut hblock,
                &mut logit_block,
                &mut |r, p: Prediction| {
                    if p.class == labels[r] {
                        correct += 1;
                    }
                },
            );
            correct
        };
        let correct: usize = if workers <= 1 {
            count_range(0, xs.rows)
        } else {
            // block-aligned contiguous row shards, fanned over the shared
            // deterministic executor; the ordered result vector is summed
            // on the caller's thread (integer sum — any order would do,
            // but the fixed order keeps the argument trivial)
            let rows_per = blocks.div_ceil(workers) * PREDICT_BLOCK;
            let shards: Vec<(usize, usize)> = (0..workers)
                .map(|w| (w * rows_per, ((w + 1) * rows_per).min(xs.rows)))
                .filter(|&(r0, r1)| r0 < r1)
                .collect();
            parallel::parallel_map(shards.len(), &shards, |_, &(r0, r1)| count_range(r0, r1))
                .into_iter()
                .sum()
        };
        correct as f64 / xs.rows as f64
    }
}

/// The blocked predict pipeline over rows `r0..r1` of `xs` with caller-
/// provided scratch (`hblock` ≥ `PREDICT_BLOCK·N`, `logit_block` ≥
/// `PREDICT_BLOCK·m`). Free function over the model's immutable pieces so
/// the workspace path ([`OsElm::for_each_prediction`]) and the thread-
/// parallel path ([`OsElm::accuracy_par`]) share one implementation — and
/// therefore one bitwise result. `r0` must be a multiple of
/// [`PREDICT_BLOCK`] for the block decomposition to match a from-zero
/// walk.
#[allow(clippy::too_many_arguments)]
fn predict_rows<F: FnMut(usize, Prediction)>(
    alpha: &AlphaProvider,
    beta: &Mat,
    n_out: usize,
    xs: &Mat,
    r0: usize,
    r1: usize,
    hblock: &mut [f32],
    logit_block: &mut [f32],
    f: &mut F,
) {
    debug_assert_eq!(r0 % PREDICT_BLOCK, 0, "shard start must be block-aligned");
    let nh = alpha.hidden;
    let mut row = r0;
    while row < r1 {
        let take = PREDICT_BLOCK.min(r1 - row);
        let hb = &mut hblock[..take * nh];
        alpha.accumulate_hidden_batch_sigmoid(
            &xs.data[row * xs.cols..(row + take) * xs.cols],
            take,
            hb,
        );
        let lb = &mut logit_block[..take * n_out];
        lb.fill(0.0);
        kernels::gemm(hb, &beta.data, lb, take, nh, n_out);
        for i in 0..take {
            f(row + i, Prediction::from_logits(&lb[i * n_out..(i + 1) * n_out]));
        }
        row += take;
    }
}

fn ensure_dim(got: usize, want: usize) {
    assert_eq!(got, want, "feature dimension mismatch");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::gen;

    /// A small linearly-separable-ish 3-class problem.
    fn toy_data(rng: &mut Rng64, rows: usize, n_in: usize) -> (Mat, Vec<usize>) {
        let mut xs = Mat::zeros(rows, n_in);
        let mut labels = Vec::with_capacity(rows);
        for r in 0..rows {
            let c = rng.below(3);
            labels.push(c);
            for j in 0..n_in {
                // class-dependent mean on the first few features
                let mean = if j < 3 {
                    if j == c {
                        2.0
                    } else {
                        -1.0
                    }
                } else {
                    0.0
                };
                *xs.at_mut(r, j) = rng.normal_ms(mean, 0.6) as f32;
            }
        }
        (xs, labels)
    }

    fn small_cfg(alpha: AlphaKind) -> OsElmConfig {
        OsElmConfig {
            n_in: 12,
            n_hidden: 24,
            n_out: 3,
            alpha,
            lambda: 0.01,
            alpha_scale: None,
        }
    }

    #[test]
    fn init_batch_learns_toy_problem() {
        for alpha in [AlphaKind::Hash, AlphaKind::Stored] {
            let mut rng = Rng64::new(5);
            let (xs, labels) = toy_data(&mut rng, 200, 12);
            let mut m = OsElm::new(small_cfg(alpha), &mut rng, 7);
            m.init_batch(&xs, &labels).unwrap();
            let acc = m.accuracy(&xs, &labels);
            assert!(acc > 0.95, "{alpha:?} train accuracy {acc}");
        }
    }

    #[test]
    fn init_hty_axpy_accumulation_matches_scalar_walk() {
        // β = P·(HᵀY); the axpy-routed HᵀY must be bitwise the seed's
        // per-sample strided column walk, so β recomputed from the scalar
        // walk must equal the model's β bit for bit.
        let mut rng = Rng64::new(57);
        let (xs, labels) = toy_data(&mut rng, 90, 12);
        let mut m = OsElm::new(small_cfg(AlphaKind::Hash), &mut rng, 3);
        m.init_batch(&xs, &labels).unwrap();
        let h = m.hidden_batch(&xs);
        let mut hty = Mat::zeros(m.cfg.n_hidden, m.cfg.n_out);
        for (r, &lbl) in labels.iter().enumerate() {
            let hrow = h.row(r);
            for j in 0..m.cfg.n_hidden {
                *hty.at_mut(j, lbl) += hrow[j];
            }
        }
        let beta_scalar = m.p.matmul(&hty);
        for (a, b) in m.beta.data.iter().zip(&beta_scalar.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn sequential_matches_batch_ridge() {
        // Property: init on k0 then seq-train on the rest ≈ batch ridge
        // solution on all samples (RLS exactness, up to f32 drift).
        let mut rng = Rng64::new(9);
        let (xs, labels) = toy_data(&mut rng, 160, 12);
        let cfg = small_cfg(AlphaKind::Hash);

        let mut seq = OsElm::new(cfg, &mut rng.clone(), 3);
        let k0 = 40;
        let init = Mat::from_vec(k0, 12, xs.data[..k0 * 12].to_vec());
        seq.init_batch(&init, &labels[..k0]).unwrap();
        for r in k0..xs.rows {
            seq.train_step(xs.row(r), labels[r]);
        }

        let mut batch = OsElm::new(cfg, &mut rng.clone(), 3);
        batch.init_batch(&xs, &labels).unwrap();

        let diff = seq.beta.max_abs_diff(&batch.beta);
        assert!(diff < 5e-2, "beta diverged: {diff}");
        let acc_seq = seq.accuracy(&xs, &labels);
        let acc_batch = batch.accuracy(&xs, &labels);
        assert!(
            (acc_seq - acc_batch).abs() < 0.03,
            "seq {acc_seq} vs batch {acc_batch}"
        );
    }

    #[test]
    fn train_step_reduces_error_on_sample() {
        let mut rng = Rng64::new(11);
        let (xs, labels) = toy_data(&mut rng, 60, 12);
        let mut m = OsElm::new(small_cfg(AlphaKind::Hash), &mut rng, 2);
        m.init_batch(&xs, &labels).unwrap();
        // A fresh sample from class 0 trained repeatedly must move logits
        // toward one-hot(0).
        let x: Vec<f32> = (0..12)
            .map(|j| if j == 0 { 2.0 } else { -0.5 })
            .collect();
        let before = m.logits(&x)[0];
        for _ in 0..5 {
            m.train_step(&x, 0);
        }
        let after = m.logits(&x)[0];
        assert!(after > before, "logit for trained class must grow");
    }

    #[test]
    fn p_stays_symmetric() {
        // Exactness, not a tolerance: init pins P ← (P+Pᵀ)/2 and the
        // triangular rank-1 kernel mirrors the upper triangle bit for bit,
        // so asymmetry must be exactly zero — including across the
        // RESYM_EVERY re-symmetrization boundary (120 > 64).
        let mut rng = Rng64::new(13);
        let (xs, labels) = toy_data(&mut rng, 120, 12);
        let cfg = small_cfg(AlphaKind::Hash);
        let mut m = OsElm::new(cfg, &mut rng, 8);
        m.init_batch(&xs, &labels).unwrap();
        let pt0 = m.p.transpose();
        assert_eq!(m.p.max_abs_diff(&pt0), 0.0, "P must start exactly symmetric");
        for r in 0..120 {
            m.train_step(xs.row(r), labels[r]);
        }
        assert!(m.steps > RESYM_EVERY);
        let pt = m.p.transpose();
        assert_eq!(m.p.max_abs_diff(&pt), 0.0, "P must stay exactly symmetric");
    }

    #[test]
    fn predict_batch_matches_per_sample_bitwise() {
        let mut rng = Rng64::new(17);
        // 70 rows: two full 32-blocks + a 6-row tail
        let (xs, labels) = toy_data(&mut rng, 70, 12);
        let mut m = OsElm::new(small_cfg(AlphaKind::Hash), &mut rng, 4);
        m.init_batch(&xs, &labels).unwrap();
        let batch = m.predict_batch(&xs);
        assert_eq!(batch.len(), 70);
        for r in 0..xs.rows {
            let single = m.predict(xs.row(r));
            assert_eq!(batch[r].class, single.class, "row {r}");
            assert_eq!(batch[r].p1.to_bits(), single.p1.to_bits(), "row {r}");
            assert_eq!(batch[r].p2.to_bits(), single.p2.to_bits(), "row {r}");
        }
    }

    #[test]
    fn accuracy_par_bitwise_matches_accuracy() {
        let mut rng = Rng64::new(29);
        let (train_xs, train_labels) = toy_data(&mut rng, 120, 12);
        let mut m = OsElm::new(small_cfg(AlphaKind::Hash), &mut rng, 9);
        m.init_batch(&train_xs, &train_labels).unwrap();
        // row counts straddling block boundaries: sub-block, exact blocks,
        // blocks + tail
        for rows in [5usize, 32, 64, 70, 97, 120] {
            let xs = Mat::from_vec(rows, 12, train_xs.data[..rows * 12].to_vec());
            let labels = &train_labels[..rows];
            let serial = m.accuracy(&xs, labels);
            for workers in [1usize, 2, 3, 4, 16] {
                let par = m.accuracy_par(&xs, labels, workers);
                assert_eq!(
                    par.to_bits(),
                    serial.to_bits(),
                    "rows {rows} workers {workers}"
                );
            }
        }
    }

    #[test]
    fn accuracy_matches_manual_predict_loop() {
        let mut rng = Rng64::new(19);
        let (xs, labels) = toy_data(&mut rng, 90, 12);
        let mut m = OsElm::new(small_cfg(AlphaKind::Stored), &mut rng, 0);
        m.init_batch(&xs, &labels).unwrap();
        let batched = m.accuracy(&xs, &labels);
        let manual = (0..xs.rows)
            .filter(|&r| m.predict(xs.row(r)).class == labels[r])
            .count() as f64
            / xs.rows as f64;
        assert_eq!(batched, manual, "batched accuracy must equal the loop");
    }

    #[test]
    fn logits_ref_matches_owned_and_last_logits() {
        let mut rng = Rng64::new(23);
        let (xs, labels) = toy_data(&mut rng, 60, 12);
        let mut m = OsElm::new(small_cfg(AlphaKind::Hash), &mut rng, 6);
        m.init_batch(&xs, &labels).unwrap();
        let owned = m.logits(xs.row(0));
        let borrowed = m.logits_ref(xs.row(0)).to_vec();
        assert_eq!(owned, borrowed);
        let owned1 = m.logits(xs.row(1));
        assert_eq!(m.last_logits(), owned1.as_slice());
    }

    #[test]
    fn init_requires_enough_samples() {
        let mut rng = Rng64::new(1);
        let cfg = small_cfg(AlphaKind::Hash);
        let mut m = OsElm::new(cfg, &mut rng, 1);
        let xs = Mat::zeros(10, 12); // < n_hidden = 24
        let labels = vec![0usize; 10];
        assert!(m.init_batch(&xs, &labels).is_err());
    }

    #[test]
    fn init_rejects_bad_labels() {
        let mut rng = Rng64::new(1);
        let cfg = small_cfg(AlphaKind::Hash);
        let mut m = OsElm::new(cfg, &mut rng, 1);
        let (xs, mut labels) = toy_data(&mut rng, 40, 12);
        labels[5] = 99;
        assert!(m.init_batch(&xs, &labels).is_err());
    }

    #[test]
    fn hash_models_identical_across_instances() {
        // ODLHash with same seed ⇒ identical α ⇒ identical trained model.
        let mut rng_data = Rng64::new(21);
        let (xs, labels) = toy_data(&mut rng_data, 80, 12);
        let cfg = small_cfg(AlphaKind::Hash);
        let mut m1 = OsElm::new(cfg, &mut Rng64::new(100), 42);
        let mut m2 = OsElm::new(cfg, &mut Rng64::new(200), 42);
        m1.init_batch(&xs, &labels).unwrap();
        m2.init_batch(&xs, &labels).unwrap();
        assert_eq!(m1.beta.data, m2.beta.data);
    }

    #[test]
    fn prediction_probabilities_valid() {
        let mut rng = Rng64::new(31);
        let (xs, labels) = toy_data(&mut rng, 80, 12);
        let mut m = OsElm::new(small_cfg(AlphaKind::Stored), &mut rng, 0);
        m.init_batch(&xs, &labels).unwrap();
        let x = gen::vec_normal(&mut rng, 12, 1.0);
        let p = m.predict(&x);
        assert!(p.class < 3);
        assert!(p.p1 >= p.p2 && p.p2 >= 0.0 && p.p1 <= 1.0);
    }

    #[test]
    fn steps_counter_tracks() {
        let mut rng = Rng64::new(41);
        let (xs, labels) = toy_data(&mut rng, 60, 12);
        let mut m = OsElm::new(small_cfg(AlphaKind::Hash), &mut rng, 5);
        m.init_batch(&xs, &labels).unwrap();
        assert_eq!(m.steps, 0);
        for r in 0..10 {
            m.train_step(xs.row(r), labels[r]);
        }
        assert_eq!(m.steps, 10);
    }
}
