//! Bit-level Q16.16 model of the ASIC's OS-ELM datapath (§2.3, §3.3).
//!
//! This is the *hardware golden model*: the same prediction / sequential-
//! training schedule the Verilog state machine executes, in the same
//! 32-bit fixed-point format, with wide-accumulator MACs and one divider.
//! [`crate::hw::cycles`] charges cycles for exactly the operation sequence
//! this model performs; tests compare its outputs against the f32 golden
//! model to bound quantization loss.
//!
//! ODLHash on the ASIC regenerates α with the *sequential* Xorshift stream
//! (one value per MAC in row-major order), which is what `hidden()` does —
//! unlike the float/kernel path, which uses the counter-based variant. Both
//! satisfy the "no stored α" property; the accuracy experiments (Table 3)
//! show the two PRNG schedules are statistically interchangeable.

use super::activation::Prediction;
use super::xorshift::Xorshift16;
use crate::fixed::{acc_to_fx, fx_dot, fx_sigmoid, Fx};
use anyhow::{ensure, Result};

/// Fixed-point OS-ELM state (ODLHash layout: no α storage).
#[derive(Clone, Debug)]
pub struct FixedOsElm {
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_out: usize,
    /// Xorshift seed for α regeneration.
    pub seed: u16,
    /// α scale in fixed point (1/√n by default).
    pub scale: Fx,
    /// β ∈ Q16.16^{N×m}, row-major.
    pub beta: Vec<Fx>,
    /// P ∈ Q16.16^{N×N}, row-major.
    pub p: Vec<Fx>,
    // scratch (SRAM-resident temporaries on the ASIC)
    h: Vec<Fx>,
    ph: Vec<Fx>,
    err: Vec<Fx>,
    logits: Vec<Fx>,
}

impl FixedOsElm {
    pub fn new(n_in: usize, n_hidden: usize, n_out: usize, seed: u16) -> Self {
        let scale = Fx::from_f32(1.0 / (n_in as f32).sqrt());
        Self {
            n_in,
            n_hidden,
            n_out,
            seed,
            scale,
            beta: vec![Fx::ZERO; n_hidden * n_out],
            p: vec![Fx::ZERO; n_hidden * n_hidden],
            h: vec![Fx::ZERO; n_hidden],
            ph: vec![Fx::ZERO; n_hidden],
            err: vec![Fx::ZERO; n_out],
            logits: vec![Fx::ZERO; n_out],
        }
    }

    /// Load β and P from the float golden model (the ASIC is provisioned
    /// with an offline-initialized model, then trains on-device).
    pub fn load_from_float(&mut self, beta: &[f32], p: &[f32]) -> Result<()> {
        ensure!(beta.len() == self.n_hidden * self.n_out, "beta size");
        ensure!(p.len() == self.n_hidden * self.n_hidden, "P size");
        for (dst, &src) in self.beta.iter_mut().zip(beta) {
            *dst = Fx::from_f32(src);
        }
        for (dst, &src) in self.p.iter_mut().zip(p) {
            *dst = Fx::from_f32(src);
        }
        Ok(())
    }

    /// Hidden layer: sequential-Xorshift α regeneration fused with the MAC
    /// loop — the exact ASIC schedule (outer loop j over hidden nodes…
    /// no: row-major over inputs, matching the weight-stream order).
    ///
    /// The stream yields α[0,0], α[0,1], …, α[0,N−1], α[1,0], … so the
    /// accumulators for all N hidden nodes are live simultaneously — this
    /// is why the ASIC keeps H in SRAM (the memory model counts it).
    pub fn hidden(&mut self, x: &[Fx]) {
        assert_eq!(x.len(), self.n_in);
        let mut acc = vec![0i64; self.n_hidden];
        let mut stream = Xorshift16::new(self.seed);
        for &xi in x.iter() {
            let xi_scaled = xi.mul(self.scale);
            for a in acc.iter_mut() {
                let w = Fx::from_f32(stream.next_weight());
                *a += xi_scaled.mac_raw(w);
            }
        }
        for (hj, &a) in self.h.iter_mut().zip(&acc) {
            *hj = fx_sigmoid(acc_to_fx(a));
        }
    }

    /// Output logits from the current H: `O = H·β`.
    fn output(&mut self) {
        for j in 0..self.n_out {
            let mut acc: i64 = 0;
            for i in 0..self.n_hidden {
                acc += self.h[i].mac_raw(self.beta[i * self.n_out + j]);
            }
            self.logits[j] = acc_to_fx(acc);
        }
    }

    /// Predict one sample (fixed-point end to end; softmax for the P1P2
    /// metric is computed in float from the fixed logits, as the confidence
    /// comparison `p1 − p2 > θ` is done by the host-side comparator).
    pub fn predict(&mut self, x: &[Fx]) -> Prediction {
        self.hidden(x);
        self.output();
        let logits_f: Vec<f32> = self.logits.iter().map(|l| l.to_f32()).collect();
        Prediction::from_logits(&logits_f)
    }

    /// One sequential training step — the Figure 2(d) schedule in Q16.16.
    pub fn train_step(&mut self, x: &[Fx], label: usize) {
        assert!(label < self.n_out);
        let nh = self.n_hidden;
        let m = self.n_out;
        self.hidden(x);

        // Ph = P·h (wide accumulator per row)
        for i in 0..nh {
            self.ph[i] = fx_dot(&self.p[i * nh..(i + 1) * nh], &self.h);
        }
        // denom = 1 + hᵀPh
        let denom = Fx::ONE.add(fx_dot(&self.h, &self.ph));

        // err = y − hᵀβ
        self.output();
        for j in 0..m {
            let y = if j == label { Fx::ONE } else { Fx::ZERO };
            self.err[j] = y.sub(self.logits[j]);
        }

        // P ← P − Ph·Phᵀ/denom : one divide per row (scale = Ph[i]/denom),
        // then a multiply-subtract sweep — the ASIC's divider schedule.
        for i in 0..nh {
            let scale = self.ph[i].div(denom);
            if scale == Fx::ZERO {
                continue;
            }
            let row = &mut self.p[i * nh..(i + 1) * nh];
            crate::fixed::fx_scale_sub_outer(row, &self.ph, scale);
        }

        // β ← β + Ph·errᵀ/denom
        for i in 0..nh {
            let scale = self.ph[i].div(denom);
            if scale == Fx::ZERO {
                continue;
            }
            let row = &mut self.beta[i * m..(i + 1) * m];
            for (b, &e) in row.iter_mut().zip(self.err.iter()) {
                *b = b.add(scale.mul(e));
            }
        }
    }

    /// Accuracy over a fixed-point dataset.
    pub fn accuracy(&mut self, xs: &[Vec<Fx>], labels: &[usize]) -> f64 {
        assert_eq!(xs.len(), labels.len());
        if xs.is_empty() {
            return 0.0;
        }
        let correct = xs
            .iter()
            .zip(labels)
            .filter(|(x, &l)| self.predict(x).class == l)
            .count();
        correct as f64 / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::fx_vec_from_f32;
    use crate::util::rng::Rng64;

    fn toy(rng: &mut Rng64, rows: usize, n_in: usize) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut xs = Vec::with_capacity(rows);
        let mut labels = Vec::with_capacity(rows);
        for _ in 0..rows {
            let c = rng.below(3);
            labels.push(c);
            xs.push(
                (0..n_in)
                    .map(|j| {
                        let mean = if j < 3 {
                            if j == c {
                                2.0
                            } else {
                                -1.0
                            }
                        } else {
                            0.0
                        };
                        rng.normal_ms(mean, 0.5) as f32
                    })
                    .collect(),
            );
        }
        (xs, labels)
    }

    #[test]
    fn fixed_training_learns() {
        let mut rng = Rng64::new(3);
        let (xs, labels) = toy(&mut rng, 300, 12);
        let mut m = FixedOsElm::new(12, 24, 3, 7);
        // Give P a reasonable RLS prior: P = (1/λ)·I with λ=0.1 → 10·I.
        for i in 0..24 {
            m.p[i * 24 + i] = Fx::from_f32(10.0);
        }
        let fx_xs: Vec<Vec<Fx>> = xs.iter().map(|x| fx_vec_from_f32(x)).collect();
        for (x, &l) in fx_xs.iter().zip(&labels).take(250) {
            m.train_step(x, l);
        }
        let acc = m.accuracy(&fx_xs[250..], &labels[250..]);
        assert!(acc > 0.8, "fixed-point OS-ELM accuracy {acc}");
    }

    #[test]
    fn fixed_matches_float_hidden_statistics() {
        // The fixed path uses the *sequential* stream, the float golden
        // model the counter-based one — they can't match elementwise, but
        // the hidden activation distribution must agree (mean near 0.5,
        // similar spread) for the same input.
        let mut rng = Rng64::new(5);
        let x: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let mut fx_model = FixedOsElm::new(64, 128, 3, 9);
        fx_model.hidden(&fx_vec_from_f32(&x));
        let h_fx: Vec<f32> = fx_model.h.iter().map(|v| v.to_f32()).collect();
        let mean: f32 = h_fx.iter().sum::<f32>() / h_fx.len() as f32;
        assert!((mean - 0.5).abs() < 0.08, "hidden mean {mean}");
        assert!(h_fx.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn load_from_float_roundtrip() {
        let mut m = FixedOsElm::new(4, 8, 2, 1);
        let beta: Vec<f32> = (0..16).map(|i| i as f32 * 0.125 - 1.0).collect();
        let p: Vec<f32> = (0..64).map(|i| (i as f32 * 0.01).sin()).collect();
        m.load_from_float(&beta, &p).unwrap();
        for (fx, &fl) in m.beta.iter().zip(&beta) {
            assert!((fx.to_f32() - fl).abs() < 1e-4);
        }
    }

    #[test]
    fn load_rejects_wrong_sizes() {
        let mut m = FixedOsElm::new(4, 8, 2, 1);
        assert!(m.load_from_float(&[0.0; 5], &[0.0; 64]).is_err());
        assert!(m.load_from_float(&[0.0; 16], &[0.0; 3]).is_err());
    }

    #[test]
    fn sequential_stream_alpha_is_deterministic() {
        let mut a = FixedOsElm::new(8, 4, 2, 33);
        let mut b = FixedOsElm::new(8, 4, 2, 33);
        let x = fx_vec_from_f32(&[0.5; 8]);
        a.hidden(&x);
        b.hidden(&x);
        assert_eq!(a.h, b.h);
    }
}
