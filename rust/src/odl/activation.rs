//! Activation functions and the P1P2 confidence metric (§2.2).

use crate::util::stats;

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// In-place sigmoid over a slice (hidden layer G1).
pub fn sigmoid_inplace(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = sigmoid(*x);
    }
}

/// Numerically stable softmax (output layer G2 → class probabilities).
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let mut out = logits.to_vec();
    softmax_inplace(&mut out);
    out
}

pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

/// Prediction summary for one sample: class, top-2 class scores, and the
/// paper's P1P2 confidence (p1 − p2).
///
/// G2 (the output activation of Figure 2(b)) is the **identity**: the
/// OS-ELM output layer is trained by least squares against one-hot
/// targets, so the raw outputs O_{i,j} already estimate class posterior
/// probabilities (≈ E[y_j | x]), and the ASIC has no exp unit for a
/// softmax. p1/p2 are therefore the top-2 *raw* outputs, clamped to
/// [0, 1] (the hardware comparator saturates), which gives the P1P2
/// metric the dynamic range the θ ladder {1, 0.64, …, 0.08} assumes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Prediction {
    pub class: usize,
    pub p1: f32,
    pub p2: f32,
}

impl Prediction {
    /// Build from the raw output-layer values (G2 = identity).
    pub fn from_logits(logits: &[f32]) -> Prediction {
        let ((i1, p1), (_i2, p2)) = stats::top2(logits);
        Prediction {
            class: i1,
            p1: p1.clamp(0.0, 1.0),
            p2: p2.clamp(0.0, 1.0),
        }
    }

    /// Build with softmax-normalized probabilities (used by the DNN
    /// baseline, whose cross-entropy training makes softmax the right
    /// posterior estimate).
    pub fn from_logits_softmax(logits: &[f32]) -> Prediction {
        let probs = softmax(logits);
        let ((i1, p1), (_i2, p2)) = stats::top2(&probs);
        Prediction { class: i1, p1, p2 }
    }

    /// The paper's "P1P2" confidence metric.
    #[inline]
    pub fn confidence(&self) -> f32 {
        (self.p1 - self.p2).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn sigmoid_range_and_midpoint() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(10.0) > 0.9999);
        assert!(sigmoid(-10.0) < 0.0001);
    }

    #[test]
    fn softmax_sums_to_one() {
        forall(
            "softmax-sum",
            |r| {
                let n = gen::usize_in(r, 2, 10);
                gen::vec_f32(r, n, -50.0, 50.0)
            },
            |logits| {
                let p = softmax(logits);
                let s: f32 = p.iter().sum();
                (s - 1.0).abs() < 1e-5 && p.iter().all(|&x| x >= 0.0)
            },
        );
    }

    #[test]
    fn softmax_shift_invariant() {
        let a = softmax(&[1.0, 2.0, 3.0]);
        let b = softmax(&[101.0, 102.0, 103.0]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_handles_extremes() {
        let p = softmax(&[1000.0, 0.0, -1000.0]);
        assert!((p[0] - 1.0).abs() < 1e-6);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prediction_from_logits() {
        // Raw OS-ELM outputs live near [0, 1] (one-hot regression).
        let p = Prediction::from_logits(&[0.05, 0.85, 0.25, -0.1]);
        assert_eq!(p.class, 1);
        assert!(p.p1 > p.p2);
        assert!((p.confidence() - 0.6).abs() < 1e-6);
    }

    #[test]
    fn prediction_clamps_out_of_range_outputs() {
        let p = Prediction::from_logits(&[3.0, -2.0]);
        assert_eq!((p.p1, p.p2), (1.0, 0.0));
        let q = Prediction::from_logits_softmax(&[0.0, 3.0, 1.0, -1.0]);
        assert_eq!(q.class, 1);
        assert!(q.p1 > q.p2 && q.p1 <= 1.0);
    }

    #[test]
    fn confidence_bounds() {
        forall(
            "p1p2-bounds",
            |r| {
                let n = gen::usize_in(r, 2, 8);
                gen::vec_f32(r, n, -10.0, 10.0)
            },
            |logits| {
                let c = Prediction::from_logits(logits).confidence();
                (0.0..=1.0).contains(&c)
            },
        );
    }

    #[test]
    fn uniform_logits_zero_confidence() {
        let p = Prediction::from_logits(&[2.0, 2.0, 2.0]);
        assert!(p.confidence().abs() < 1e-6);
    }
}
