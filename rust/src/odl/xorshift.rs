//! The paper's 16-bit Xorshift weight generator (ODLHash, §2.3) and the
//! counter-based variant used by the Pallas kernel.
//!
//! §2.3: *"ODLHash: α are replaced with a 16-bit Xorshift function, where
//! coefficients are 7, 9, and 8."* — i.e. `s ^= s<<7; s ^= s>>9; s ^= s<<8`
//! (the full-period (7,9,8) triple from Marsaglia's "Xorshift RNGs",
//! adapted to 16 bits; period 2¹⁶−1, state 0 is the fixed point and is
//! remapped).
//!
//! The ASIC walks this stream **sequentially**, one value per MAC, in
//! lock-step with the weight index (row-major over α ∈ R^{n×N}). A
//! sequential stream cannot be generated in parallel on a vector unit, so
//! the Pallas kernel uses a **counter-based** derivation (`counter_alpha`)
//! that hashes the flat weight index into an independent 16-bit state and
//! applies `ROUNDS` xorshift rounds. Both variants share the value mapping
//! `(s as i16)/32768 ∈ [−1, 1)` and both are "memory-free": no α storage.
//!
//! This file is the **normative spec**; `python/compile/kernels/ref.py`
//! implements the same functions and `aot.py` emits golden vectors that
//! both test suites check (`rust/tests/golden_xorshift.rs`,
//! `python/tests/test_golden.py`).

/// State-0 remap constant (any nonzero value works; fixed for the spec).
pub const SEED_REMAP: u16 = 0x2A6D;
/// Xorshift rounds applied to the hashed counter in the counter-based mode.
pub const ROUNDS: u32 = 4;
/// 32-bit golden-ratio multiplier for the counter mix.
pub const MIX_MUL: u32 = 0x9E37_79B9;
/// Murmur3-finalizer multiplier for the counter mix avalanche.
pub const MIX_MUL2: u32 = 0x85EB_CA6B;

/// Sequential 16-bit Xorshift stream with the paper's (7, 9, 8) triple.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Xorshift16 {
    state: u16,
}

impl Xorshift16 {
    /// Create from a seed; seed 0 is remapped to [`SEED_REMAP`].
    pub fn new(seed: u16) -> Self {
        Self {
            state: if seed == 0 { SEED_REMAP } else { seed },
        }
    }

    /// One xorshift step (7, 9, 8), returning the new state.
    #[inline]
    pub fn next_u16(&mut self) -> u16 {
        let mut s = self.state;
        s ^= s << 7;
        s ^= s >> 9;
        s ^= s << 8;
        self.state = s;
        s
    }

    /// Next weight value in [−1, 1): interpret the state as i16 / 32768.
    #[inline]
    pub fn next_weight(&mut self) -> f32 {
        let s = self.next_u16();
        (s as i16) as f32 / 32768.0
    }

    pub fn state(&self) -> u16 {
        self.state
    }
}

/// One stateless xorshift(7,9,8) application to a value.
#[inline]
pub fn xs16_round(mut s: u16) -> u16 {
    s ^= s << 7;
    s ^= s >> 9;
    s ^= s << 8;
    s
}

/// Counter-based α: the Pallas-kernel-identical derivation of weight
/// `α[i, j]` for a flat index `k = i·N + j` and a 16-bit seed.
///
/// Mix (murmur3-style finalizer for avalanche across strides — lag-1/-64/
/// -128/-561 autocorrelations all < 0.01, verified in tests):
/// `m = k·MIX_MUL; m ^= m≫15; m ·= MIX_MUL2; m ^= m≫13` (u32 wrapping),
/// then `state = seed ⊕ hi16(m) ⊕ lo16(m)`, remap 0 → SEED_REMAP, then
/// `ROUNDS` xorshift(7,9,8) rounds, then value = i16(state)/32768.
#[inline]
pub fn counter_alpha_value(seed: u16, k: u32) -> f32 {
    let mut m = k.wrapping_mul(MIX_MUL);
    m ^= m >> 15;
    m = m.wrapping_mul(MIX_MUL2);
    m ^= m >> 13;
    let mut s = seed ^ (m & 0xFFFF) as u16 ^ (m >> 16) as u16;
    if s == 0 {
        s = SEED_REMAP;
    }
    for _ in 0..ROUNDS {
        s = xs16_round(s);
    }
    (s as i16) as f32 / 32768.0
}

/// Materialize the full counter-based α matrix (n × cols, row-major),
/// scaled by `scale` (the golden model uses 1/√n — see `OsElmConfig`).
pub fn counter_alpha(seed: u16, n: usize, cols: usize, scale: f32) -> Vec<f32> {
    let mut a = Vec::with_capacity(n * cols);
    for k in 0..(n * cols) as u32 {
        a.push(counter_alpha_value(seed, k) * scale);
    }
    a
}

/// Materialize the ASIC's *sequential*-stream α (n × cols, row-major) —
/// the exact weights [`crate::odl::fixed_oselm::FixedOsElm`] regenerates
/// in its MAC loop. Used to provision a float model that is
/// feature-compatible with the hardware core (co-simulation handoff).
pub fn sequential_alpha(seed: u16, n: usize, cols: usize, scale: f32) -> Vec<f32> {
    let mut stream = Xorshift16::new(seed);
    (0..n * cols).map(|_| stream.next_weight() * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn full_period() {
        // (7,9,8) is a full-period triple: the orbit of any nonzero state
        // visits all 2^16 - 1 nonzero states.
        let mut s = Xorshift16::new(1);
        let mut seen = HashSet::new();
        for _ in 0..65535 {
            assert!(seen.insert(s.next_u16()), "cycle shorter than 2^16-1");
        }
        assert_eq!(s.next_u16(), {
            let mut t = Xorshift16::new(1);
            t.next_u16()
        });
    }

    #[test]
    fn zero_state_remapped() {
        let mut a = Xorshift16::new(0);
        let mut b = Xorshift16::new(SEED_REMAP);
        assert_eq!(a.next_u16(), b.next_u16());
        // and the stream never reaches 0
        let mut s = Xorshift16::new(123);
        for _ in 0..65535 {
            assert_ne!(s.next_u16(), 0);
        }
    }

    #[test]
    fn first_values_pinned() {
        // Golden values for the spec (also emitted by aot.py for python):
        // state 1: 1 -> (1^(1<<7))=0x81, ... compute explicitly once and pin.
        let mut s = Xorshift16::new(1);
        let vals: Vec<u16> = (0..4).map(|_| s.next_u16()).collect();
        // hand-computed: s=1: s^=s<<7 -> 0x0081; s^=s>>9 -> 0x0081; s^=s<<8 -> 0x8181
        assert_eq!(vals[0], 0x8181);
        // regression-pin the rest (stability of the spec, not hand-derived)
        assert_eq!(vals[1], xs16_round(0x8181));
        let mut t = 0x8181;
        for _ in 0..3 {
            t = xs16_round(t);
        }
        assert_eq!(vals[3], t);
    }

    #[test]
    fn weights_in_unit_interval() {
        let mut s = Xorshift16::new(42);
        for _ in 0..10_000 {
            let w = s.next_weight();
            assert!((-1.0..1.0).contains(&w));
        }
    }

    #[test]
    fn weights_roughly_centered() {
        let mut s = Xorshift16::new(7);
        let n = 65535;
        let mean: f64 = (0..n).map(|_| s.next_weight() as f64).sum::<f64>() / n as f64;
        // over the full period the i16 values sum to -1 exactly (all u16 minus 0)
        assert!(mean.abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn counter_alpha_deterministic_and_distinct_seeds() {
        let a1 = counter_alpha(1, 8, 8, 1.0);
        let a2 = counter_alpha(1, 8, 8, 1.0);
        let b = counter_alpha(2, 8, 8, 1.0);
        assert_eq!(a1, a2);
        assert_ne!(a1, b);
    }

    #[test]
    fn counter_alpha_no_stuck_values() {
        // Adjacent counters must decorrelate: check no constant runs and a
        // near-zero lag-1 autocorrelation.
        let a = counter_alpha(3, 64, 64, 1.0);
        let n = a.len();
        let mean: f32 = a.iter().sum::<f32>() / n as f32;
        let var: f32 = a.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(var > 0.2, "variance too small: {var}"); // uniform[-1,1) var = 1/3
        let lag1: f32 = a
            .windows(2)
            .map(|w| (w[0] - mean) * (w[1] - mean))
            .sum::<f32>()
            / ((n - 1) as f32 * var);
        assert!(lag1.abs() < 0.05, "lag-1 autocorrelation {lag1}");
    }

    #[test]
    fn counter_alpha_scale_applied() {
        let a = counter_alpha(5, 4, 4, 0.5);
        let b = counter_alpha(5, 4, 4, 1.0);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y * 0.5).abs() < 1e-7);
        }
    }

    #[test]
    fn counter_matches_value_fn() {
        let n = 16;
        let cols = 8;
        let a = counter_alpha(9, n, cols, 1.0);
        for i in 0..n {
            for j in 0..cols {
                let k = (i * cols + j) as u32;
                assert_eq!(a[i * cols + j], counter_alpha_value(9, k));
            }
        }
    }
}
