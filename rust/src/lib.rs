//! # odl-har
//!
//! Full-system reproduction of *"A Tiny Supervised ODL Core with Auto Data
//! Pruning for Human Activity Recognition"* (Matsutani & Marculescu, 2024)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the edge/teacher coordinator: Algorithm 1's
//!   device state machine, BLE channel + teacher service, the auto-θ data
//!   pruning controller, drift detectors, a discrete-event fleet simulator
//!   with power accounting, and the hardware co-design models (SRAM size,
//!   cycle-level latency, core power, BLE transaction energy).
//! * **L2/L1 (python, build-time)** — the OS-ELM compute graphs and Pallas
//!   kernels, AOT-lowered to HLO text in `artifacts/` and executed from
//!   rust through PJRT ([`runtime`]).
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod drift;
pub mod exp;
pub mod fixed;
pub mod hw;
pub mod linalg;
pub mod odl;
pub mod pruning;
pub mod runtime;
pub mod storage;
pub mod util;
