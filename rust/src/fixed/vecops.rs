//! Vector operations over Q16.16, mirroring the ASIC datapath: long dot
//! products accumulate in a wide (64-bit) register before renormalizing,
//! exactly like the hardware MAC's extended accumulator.
//!
//! The loops themselves route through the shared fixed-width kernels in
//! [`crate::linalg::kernels`] (`fx_dot_raw`, `fx_scale_sub`), so the
//! Q16.16 hardware model autovectorizes the same way the f32 golden model
//! does. i64 accumulation is associative, so the 8-lane split is bitwise
//! identical to the sequential walk — the hardware semantics are
//! unchanged.

use super::{acc_to_fx, Fx};
use crate::linalg::kernels;

/// Convert an f32 slice into fixed point.
pub fn fx_vec_from_f32(xs: &[f32]) -> Vec<Fx> {
    xs.iter().map(|&x| Fx::from_f32(x)).collect()
}

/// Convert back to f32.
pub fn fx_vec_to_f32(xs: &[Fx]) -> Vec<f32> {
    xs.iter().map(|x| x.to_f32()).collect()
}

/// Dot product with a wide accumulator (one renormalization at the end).
#[inline]
pub fn fx_dot(a: &[Fx], b: &[Fx]) -> Fx {
    acc_to_fx(kernels::fx_dot_raw(a, b))
}

/// `row[j] -= (ph_i * ph[j]) / denom` for a whole row — the inner loop of
/// the OS-ELM P-update in fixed point. `scale = ph_i / denom` is computed
/// once by the caller (one divide per row, like the ASIC schedule).
#[inline]
pub fn fx_scale_sub_outer(row: &mut [Fx], ph: &[Fx], scale: Fx) {
    kernels::fx_scale_sub(row, ph, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn dot_matches_float() {
        forall(
            "fx-dot",
            |r| {
                let n = gen::usize_in(r, 1, 64);
                let a = gen::vec_f32(r, n, -2.0, 2.0);
                let b = gen::vec_f32(r, n, -2.0, 2.0);
                (a, b)
            },
            |(a, b)| {
                let fa = fx_vec_from_f32(a);
                let fb = fx_vec_from_f32(b);
                let fx = fx_dot(&fa, &fb).to_f32();
                let fl: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
                (fx - fl).abs() < 0.01
            },
        );
    }

    #[test]
    fn dot_empty_is_zero() {
        assert_eq!(fx_dot(&[], &[]), Fx::ZERO);
    }

    #[test]
    fn scale_sub_outer_matches_float() {
        forall(
            "fx-scale-sub",
            |r| {
                let n = gen::usize_in(r, 1, 32);
                let row = gen::vec_f32(r, n, -4.0, 4.0);
                let ph = gen::vec_f32(r, n, -2.0, 2.0);
                let scale = gen::f32_in(r, -1.0, 1.0);
                (row, ph, scale)
            },
            |(row, ph, scale)| {
                let mut frow = fx_vec_from_f32(row);
                let fph = fx_vec_from_f32(ph);
                fx_scale_sub_outer(&mut frow, &fph, Fx::from_f32(*scale));
                row.iter()
                    .zip(ph)
                    .zip(&frow)
                    .all(|((r, p), f)| ((r - scale * p) - f.to_f32()).abs() < 0.005)
            },
        );
    }

    #[test]
    fn roundtrip_vec() {
        let xs = vec![0.5f32, -1.25, 3.0];
        let back = fx_vec_to_f32(&fx_vec_from_f32(&xs));
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
