//! Vector operations over Q16.16, mirroring the ASIC datapath: long dot
//! products accumulate in a wide (64-bit) register before renormalizing,
//! exactly like the hardware MAC's extended accumulator.

use super::{acc_to_fx, Fx};

/// Convert an f32 slice into fixed point.
pub fn fx_vec_from_f32(xs: &[f32]) -> Vec<Fx> {
    xs.iter().map(|&x| Fx::from_f32(x)).collect()
}

/// Convert back to f32.
pub fn fx_vec_to_f32(xs: &[Fx]) -> Vec<f32> {
    xs.iter().map(|x| x.to_f32()).collect()
}

/// Dot product with a wide accumulator (one renormalization at the end).
#[inline]
pub fn fx_dot(a: &[Fx], b: &[Fx]) -> Fx {
    debug_assert_eq!(a.len(), b.len());
    let mut acc: i64 = 0;
    for (x, y) in a.iter().zip(b) {
        acc += x.mac_raw(*y);
    }
    acc_to_fx(acc)
}

/// `row[j] -= (ph_i * ph[j]) / denom` for a whole row — the inner loop of
/// the OS-ELM P-update in fixed point. `scale = ph_i / denom` is computed
/// once by the caller (one divide per row, like the ASIC schedule).
#[inline]
pub fn fx_scale_sub_outer(row: &mut [Fx], ph: &[Fx], scale: Fx) {
    debug_assert_eq!(row.len(), ph.len());
    for (r, &p) in row.iter_mut().zip(ph) {
        *r = r.sub(scale.mul(p));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn dot_matches_float() {
        forall(
            "fx-dot",
            |r| {
                let n = gen::usize_in(r, 1, 64);
                let a = gen::vec_f32(r, n, -2.0, 2.0);
                let b = gen::vec_f32(r, n, -2.0, 2.0);
                (a, b)
            },
            |(a, b)| {
                let fa = fx_vec_from_f32(a);
                let fb = fx_vec_from_f32(b);
                let fx = fx_dot(&fa, &fb).to_f32();
                let fl: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
                (fx - fl).abs() < 0.01
            },
        );
    }

    #[test]
    fn dot_empty_is_zero() {
        assert_eq!(fx_dot(&[], &[]), Fx::ZERO);
    }

    #[test]
    fn scale_sub_outer_matches_float() {
        forall(
            "fx-scale-sub",
            |r| {
                let n = gen::usize_in(r, 1, 32);
                let row = gen::vec_f32(r, n, -4.0, 4.0);
                let ph = gen::vec_f32(r, n, -2.0, 2.0);
                let scale = gen::f32_in(r, -1.0, 1.0);
                (row, ph, scale)
            },
            |(row, ph, scale)| {
                let mut frow = fx_vec_from_f32(row);
                let fph = fx_vec_from_f32(ph);
                fx_scale_sub_outer(&mut frow, &fph, Fx::from_f32(*scale));
                row.iter()
                    .zip(ph)
                    .zip(&frow)
                    .all(|((r, p), f)| ((r - scale * p) - f.to_f32()).abs() < 0.005)
            },
        );
    }

    #[test]
    fn roundtrip_vec() {
        let xs = vec![0.5f32, -1.25, 3.0];
        let back = fx_vec_to_f32(&fx_vec_from_f32(&xs));
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
