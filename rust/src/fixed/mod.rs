//! 32-bit fixed-point arithmetic — the number format of the paper's ASIC.
//!
//! §3.3: "Numbers are represented by 32-bit fixed-point format." The paper
//! does not name the Q-split; we use **Q16.16** (16 integer bits incl.
//! sign, 16 fractional bits), which covers the dynamic range the OS-ELM
//! datapath needs (features standardized to ≈N(0,1), hidden activations in
//! (0,1), P entries bounded by the ridge init) while keeping quantization
//! noise ≈ 2⁻¹⁶. All operations **saturate** instead of wrapping — what a
//! sane hardware datapath does — and division rounds toward zero (matching
//! the iterative divider the cycle model in [`crate::hw::cycles`] charges
//! for).
//!
//! [`crate::odl::fixed_oselm`] runs the full OS-ELM pipeline in this
//! format to provide the bit-level golden model of the hardware core and to
//! quantify fixed-vs-float accuracy loss (tests assert it stays small).

mod vecops;
pub use vecops::{fx_dot, fx_scale_sub_outer, fx_vec_from_f32, fx_vec_to_f32};

/// Number of fractional bits.
pub const FRAC_BITS: u32 = 16;
/// Scale factor 2^16.
pub const ONE_RAW: i32 = 1 << FRAC_BITS;

/// Q16.16 fixed-point value.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Fx(pub i32);

impl std::fmt::Debug for Fx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Fx({})", self.to_f32())
    }
}

impl Fx {
    pub const ZERO: Fx = Fx(0);
    pub const ONE: Fx = Fx(ONE_RAW);
    pub const MAX: Fx = Fx(i32::MAX);
    pub const MIN: Fx = Fx(i32::MIN);

    /// Convert from f32 with saturation and round-to-nearest.
    pub fn from_f32(x: f32) -> Fx {
        let scaled = (x as f64) * ONE_RAW as f64;
        if scaled >= i32::MAX as f64 {
            Fx(i32::MAX)
        } else if scaled <= i32::MIN as f64 {
            Fx(i32::MIN)
        } else {
            Fx(scaled.round() as i32)
        }
    }

    pub fn to_f32(self) -> f32 {
        self.0 as f32 / ONE_RAW as f32
    }

    pub fn to_f64(self) -> f64 {
        self.0 as f64 / ONE_RAW as f64
    }

    /// Saturating add.
    #[inline]
    pub fn add(self, rhs: Fx) -> Fx {
        Fx(self.0.saturating_add(rhs.0))
    }

    /// Saturating subtract.
    #[inline]
    pub fn sub(self, rhs: Fx) -> Fx {
        Fx(self.0.saturating_sub(rhs.0))
    }

    /// Saturating multiply: (a·b) >> 16 computed in 64-bit.
    #[inline]
    pub fn mul(self, rhs: Fx) -> Fx {
        let wide = (self.0 as i64 * rhs.0 as i64) >> FRAC_BITS;
        Fx(clamp_i64(wide))
    }

    /// Saturating divide, rounding toward zero. Division by zero saturates
    /// to ±MAX (hardware flags it; the datapath clamps).
    #[inline]
    pub fn div(self, rhs: Fx) -> Fx {
        if rhs.0 == 0 {
            return if self.0 >= 0 { Fx::MAX } else { Fx::MIN };
        }
        let wide = ((self.0 as i64) << FRAC_BITS) / rhs.0 as i64;
        Fx(clamp_i64(wide))
    }

    pub fn neg(self) -> Fx {
        Fx(self.0.saturating_neg())
    }

    pub fn abs(self) -> Fx {
        Fx(self.0.saturating_abs())
    }

    /// Multiply-accumulate in a 64-bit accumulator domain: callers that
    /// need long dot products should accumulate raw i64 (see `fx_dot`)
    /// rather than chaining saturating `add`s — this mirrors the ASIC's
    /// wide accumulator register.
    #[inline]
    pub fn mac_raw(self, rhs: Fx) -> i64 {
        self.0 as i64 * rhs.0 as i64
    }
}

#[inline]
fn clamp_i64(x: i64) -> i32 {
    if x > i32::MAX as i64 {
        i32::MAX
    } else if x < i32::MIN as i64 {
        i32::MIN
    } else {
        x as i32
    }
}

/// Reduce a raw 64-bit accumulator (sum of 32.32 products) back to Q16.16.
#[inline]
pub fn acc_to_fx(acc: i64) -> Fx {
    Fx(clamp_i64(acc >> FRAC_BITS))
}

/// Fixed-point sigmoid via a 3-segment piecewise-quadratic approximation —
/// the standard tinyML hardware trick (no exp unit on the ASIC).
///
/// For |x| ≥ 8 the output saturates to 0/1; in between we use the
/// well-known approximation σ(x) ≈ 0.5·(1 + x/(1+|x|)·c) refined to a
/// quadratic that keeps max error < 0.02 — small against the Q16.16 grid
/// and the OS-ELM tolerance (tests quantify end-to-end agreement).
pub fn fx_sigmoid(x: Fx) -> Fx {
    const EIGHT: i32 = 8 * ONE_RAW;
    if x.0 >= EIGHT {
        return Fx::ONE;
    }
    if x.0 <= -EIGHT {
        return Fx::ZERO;
    }
    // PLAN-style piecewise linear approximation (Amin, Curtis, Hayes-Gill
    // 1997) — the classic LUT-less sigmoid circuit (shifts + adds only).
    // Segment boundary moved from 2.375 to 7/3 so adjacent segments meet
    // exactly (the published PLAN has a 0.004 jump there); the last segment
    // reaches exactly 1.0 at |x| = 5. Continuous + monotone, max err < 0.02.
    let ax = x.abs().0 as i64; // Q16.16 positive
    let one = ONE_RAW as i64;
    let y = if ax < one {
        (ax >> 2) + (one >> 1) // 0.25|x| + 0.5
    } else if ax < (7 * one) / 3 {
        (ax >> 3) + (5 * one) / 8 // 0.125|x| + 0.625
    } else if ax < 5 * one {
        (ax >> 5) + (27 * one) / 32 // 0.03125|x| + 0.84375
    } else {
        one
    };
    let y = y.min(one);
    if x.0 >= 0 {
        Fx(y as i32)
    } else {
        Fx((one - y) as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn roundtrip_grid() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, -0.25, 1234.0625, -32767.9] {
            let fx = Fx::from_f32(x);
            assert!((fx.to_f32() - x).abs() <= 1.0 / ONE_RAW as f32, "{x}");
        }
    }

    #[test]
    fn saturation_bounds() {
        assert_eq!(Fx::from_f32(1e9), Fx::MAX);
        assert_eq!(Fx::from_f32(-1e9), Fx::MIN);
        assert_eq!(Fx::MAX.add(Fx::ONE), Fx::MAX);
        assert_eq!(Fx::MIN.sub(Fx::ONE), Fx::MIN);
        assert_eq!(Fx::MAX.mul(Fx::from_f32(2.0)), Fx::MAX);
        assert_eq!(Fx::MIN.mul(Fx::from_f32(2.0)), Fx::MIN);
    }

    #[test]
    fn div_by_zero_saturates() {
        assert_eq!(Fx::ONE.div(Fx::ZERO), Fx::MAX);
        assert_eq!(Fx::ONE.neg().div(Fx::ZERO), Fx::MIN);
    }

    #[test]
    fn mul_matches_float_within_grid() {
        forall(
            "fx-mul",
            |r| (gen::f32_in(r, -100.0, 100.0), gen::f32_in(r, -100.0, 100.0)),
            |&(a, b)| {
                let fx = Fx::from_f32(a).mul(Fx::from_f32(b)).to_f32();
                // error bound: input quantization (each ≤ 2⁻¹⁷ relative-ish)
                // + product truncation 2⁻¹⁶
                (fx - a * b).abs() <= (a.abs() + b.abs()) * 2.0 / ONE_RAW as f32 + 2.0 / ONE_RAW as f32
            },
        );
    }

    #[test]
    fn div_matches_float() {
        forall(
            "fx-div",
            |r| {
                let a = gen::f32_in(r, -100.0, 100.0);
                let mut b = gen::f32_in(r, 0.1, 50.0);
                if a < 0.0 {
                    b = -b; // exercise both sign combinations
                }
                (a, b)
            },
            |&(a, b)| {
                let fx = Fx::from_f32(a).div(Fx::from_f32(b)).to_f32();
                (fx - a / b).abs() <= 0.01 + (a / b).abs() * 1e-3
            },
        );
    }

    #[test]
    fn mul_commutes_and_one_is_neutral() {
        forall(
            "fx-mul-commutes",
            |r| (gen::f32_in(r, -50.0, 50.0), gen::f32_in(r, -50.0, 50.0)),
            |&(a, b)| {
                let (fa, fb) = (Fx::from_f32(a), Fx::from_f32(b));
                fa.mul(fb) == fb.mul(fa) && fa.mul(Fx::ONE).0 - fa.0 <= 1
            },
        );
    }

    #[test]
    fn sigmoid_limits_and_monotone() {
        assert_eq!(fx_sigmoid(Fx::from_f32(20.0)), Fx::ONE);
        assert_eq!(fx_sigmoid(Fx::from_f32(-20.0)), Fx::ZERO);
        let mid = fx_sigmoid(Fx::ZERO).to_f32();
        assert!((mid - 0.5).abs() < 1e-3, "sigmoid(0) = {mid}");
        let mut prev = -1.0f32;
        for i in -160..=160 {
            let y = fx_sigmoid(Fx::from_f32(i as f32 / 20.0)).to_f32();
            assert!(y + 1e-6 >= prev, "not monotone at {}", i);
            prev = y;
        }
    }

    #[test]
    fn sigmoid_close_to_real() {
        for i in -80..=80 {
            let x = i as f32 / 10.0;
            let approx = fx_sigmoid(Fx::from_f32(x)).to_f32();
            let exact = 1.0 / (1.0 + (-x).exp());
            assert!(
                (approx - exact).abs() < 0.025,
                "x={x} approx={approx} exact={exact}"
            );
        }
    }

    #[test]
    fn sigmoid_symmetry() {
        forall(
            "fx-sigmoid-symmetry",
            |r| gen::f32_in(r, -8.0, 8.0),
            |&x| {
                let a = fx_sigmoid(Fx::from_f32(x)).to_f32();
                let b = fx_sigmoid(Fx::from_f32(-x)).to_f32();
                (a + b - 1.0).abs() < 2.0 / ONE_RAW as f32 * 4.0
            },
        );
    }
}
