//! Experiment harnesses — one per paper table/figure (see DESIGN.md §5 for
//! the experiment → module → bench index).

pub mod ablations;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod protocol;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
