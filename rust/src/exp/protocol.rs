//! The paper's §3 evaluation protocol, shared by Tables 2/3 and Figures
//! 3/4:
//!
//! 1. **Initial training** on the training dataset (in-distribution
//!    subjects): OS-ELM batch-init on the first k₀ samples, sequential
//!    training on the rest (equivalent to batch ridge by RLS exactness,
//!    and it exercises the on-device path).
//! 2. **Test before drift** on test0.
//! 3. **ODL** on ≈60 % of test1 (held-out subjects) with teacher label
//!    acquisition and, optionally, data pruning. NoODL/DNN skip this.
//! 4. **Test after drift** on the rest of test1.
//!
//! Each configuration runs `trials` times with independent seeds (paper:
//! 20) and reports mean ± std. Trials run on worker threads.

use crate::data::{synth::SynthHar, DriftSplit, Dataset, Standardizer, SynthConfig};
use crate::odl::dnn::{Dnn, DnnConfig};
use crate::odl::{AlphaKind, OsElm, OsElmConfig};
use crate::pruning::{Decision, Metric, Pruner, ThetaPolicy};
use crate::util::parallel;
use crate::util::rng::Rng64;
use crate::util::stats::RunningStats;
use anyhow::Result;

/// Which model a trial evaluates.
#[derive(Clone, Debug)]
pub enum Variant {
    /// OS-ELM without the ODL phase (Table 3's "NoODL").
    NoOdl(AlphaKind),
    /// OS-ELM with the ODL phase ("ODLBase"/"ODLHash").
    Odl(AlphaKind),
    /// Backprop MLP baseline, no ODL ("DNN (561,512,256,6)").
    Dnn(Vec<usize>),
}

impl Variant {
    pub fn label(&self, n_hidden: usize) -> String {
        match self {
            Variant::NoOdl(_) => format!("NoODL (N = {n_hidden})"),
            Variant::Odl(k) => format!("{} (N = {n_hidden})", k.label()),
            Variant::Dnn(layers) => {
                let dims: Vec<String> = layers.iter().map(|d| d.to_string()).collect();
                format!("DNN ({})", dims.join(","))
            }
        }
    }
}

/// Pruning setup for the ODL phase.
#[derive(Clone, Debug)]
pub enum PruningSpec {
    /// Always query (θ = 1; communication volume = 100 %).
    Off,
    /// Fixed θ from Figure 3's sweep.
    Fixed(f32),
    /// The auto-θ ladder with parameter X.
    Auto { x: u32 },
}

impl PruningSpec {
    fn build(&self, n_hidden: usize) -> Pruner {
        let warmup = crate::pruning::warmup_for(n_hidden);
        match self {
            PruningSpec::Off => Pruner::disabled(),
            PruningSpec::Fixed(theta) => {
                Pruner::new(ThetaPolicy::Fixed(*theta), Metric::P1P2, warmup)
            }
            PruningSpec::Auto { x } => Pruner::new(
                ThetaPolicy::Auto(crate::pruning::AutoTheta::new(*x)),
                Metric::P1P2,
                warmup,
            ),
        }
    }
}

/// One experiment configuration.
#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    pub variant: Variant,
    pub n_hidden: usize,
    pub pruning: PruningSpec,
    pub synth: SynthConfig,
    /// Train share of in-distribution data (UCI HAR is ≈ 70/30).
    pub train_frac: f64,
    pub trials: usize,
    pub master_seed: u64,
    /// Teacher label error rate (0 = paper's ground-truth oracle).
    pub teacher_error: f64,
    /// Dataset seed: the pool is FIXED across trials (like the paper's
    /// real dataset); per-trial randomness covers splits, shuffles, and
    /// model initialization only.
    pub dataset_seed: u64,
    /// Optional pruning metric override (P1P2 default).
    pub metric: Metric,
    /// Warmup override (None = paper's max(N, 288) rule).
    pub warmup: Option<usize>,
}

impl ProtocolConfig {
    pub fn new(variant: Variant, n_hidden: usize) -> Self {
        Self {
            variant,
            n_hidden,
            pruning: PruningSpec::Off,
            synth: SynthConfig::default(),
            train_frac: 0.7,
            trials: 20,
            master_seed: 0x0D1_5EED,
            teacher_error: 0.0,
            dataset_seed: 0xDA7A_5EED,
            metric: Metric::P1P2,
            warmup: None,
        }
    }
}

/// Per-trial outcome.
#[derive(Clone, Debug)]
pub struct TrialOutcome {
    pub acc_before: f64,
    pub acc_after: f64,
    /// Teacher queries made during the ODL phase.
    pub queries: usize,
    /// Total ODL-phase events (denominator for communication volume).
    pub odl_events: usize,
    /// Sequential train steps executed in the ODL phase.
    pub trained: usize,
    /// Final θ (auto mode telemetry).
    pub final_theta: f32,
}

impl TrialOutcome {
    /// Communication volume relative to no pruning (θ = 1 ⇒ 100 %).
    pub fn comm_fraction(&self) -> f64 {
        if self.odl_events == 0 {
            0.0
        } else {
            self.queries as f64 / self.odl_events as f64
        }
    }
}

/// Aggregated outcome over trials.
#[derive(Clone, Debug)]
pub struct Aggregate {
    pub label: String,
    pub before: RunningStats,
    pub after: RunningStats,
    pub comm: RunningStats,
    pub queries: RunningStats,
    pub outcomes: Vec<TrialOutcome>,
}

/// Run one trial (deterministic in `trial_seed`).
pub fn run_trial(cfg: &ProtocolConfig, trial_seed: u64) -> Result<TrialOutcome> {
    let mut rng = Rng64::new(trial_seed);

    // Data: fixed pool, per-trial split/model randomness.
    let (split, _std) = build_split(cfg, &mut rng)?;

    match &cfg.variant {
        Variant::Dnn(layers) => run_dnn_trial(cfg, layers, split, &mut rng),
        Variant::NoOdl(kind) | Variant::Odl(kind) => {
            let with_odl = matches!(cfg.variant, Variant::Odl(_));
            let pruner = build_pruner(cfg);
            run_oselm_trial(cfg, *kind, with_odl, split, &mut rng, pruner)
        }
    }
}

/// Trial with an externally constructed pruner (ablation hook: custom
/// auto-θ hysteresis etc.). Only meaningful for ODL variants.
pub fn run_trial_with_pruner(
    cfg: &ProtocolConfig,
    trial_seed: u64,
    pruner: Pruner,
) -> Result<TrialOutcome> {
    let mut rng = Rng64::new(trial_seed);
    let (split, _std) = build_split(cfg, &mut rng)?;
    match &cfg.variant {
        Variant::Dnn(layers) => run_dnn_trial(cfg, layers, split, &mut rng),
        Variant::NoOdl(kind) | Variant::Odl(kind) => {
            let with_odl = matches!(cfg.variant, Variant::Odl(_));
            run_oselm_trial(cfg, *kind, with_odl, split, &mut rng, pruner)
        }
    }
}

fn build_pruner(cfg: &ProtocolConfig) -> Pruner {
    match &cfg.pruning {
        PruningSpec::Off => Pruner::disabled(),
        other => {
            let mut p = other.build(cfg.n_hidden);
            p.metric = cfg.metric;
            if let Some(w) = cfg.warmup {
                p.warmup = w;
            }
            p
        }
    }
}

/// Build the drift split (synthetic by default, real UCI when
/// `$HAR_DATASET_DIR` is set), standardized on the training set.
pub fn build_split(
    cfg: &ProtocolConfig,
    rng: &mut Rng64,
) -> Result<(DriftSplit, Standardizer)> {
    let pool: Dataset = match crate::data::uci::load_from_env()? {
        Some(real) => real,
        None => {
            // Fixed pool across trials (the paper's dataset is fixed; only
            // splits and model init differ per trial).
            let mut data_rng = Rng64::new(cfg.dataset_seed);
            let gen = SynthHar::new(cfg.synth.clone(), &mut data_rng);
            gen.generate(&mut data_rng)
        }
    };
    let mut split = DriftSplit::build(&pool, cfg.train_frac, rng);
    let std = Standardizer::fit(&split.train.xs);
    std.apply(&mut split.train.xs);
    std.apply(&mut split.test0.xs);
    std.apply(&mut split.odl_stream.xs);
    std.apply(&mut split.test1.xs);
    Ok((split, std))
}

fn teacher_label(true_label: usize, n_classes: usize, err: f64, rng: &mut Rng64) -> usize {
    if err > 0.0 && rng.bernoulli(err) {
        // uniformly wrong label
        let mut l = rng.below(n_classes - 1);
        if l >= true_label {
            l += 1;
        }
        l
    } else {
        true_label
    }
}

fn run_oselm_trial(
    cfg: &ProtocolConfig,
    kind: AlphaKind,
    with_odl: bool,
    split: DriftSplit,
    rng: &mut Rng64,
    mut pruner: Pruner,
) -> Result<TrialOutcome> {
    let model_cfg = OsElmConfig {
        n_in: split.train.n_features(),
        n_hidden: cfg.n_hidden,
        n_out: split.train.n_classes,
        alpha: kind,
        ..Default::default()
    };
    let hash_seed = (rng.next_u32() & 0xFFFF) as u16;
    let mut model = OsElm::new(model_cfg, rng, hash_seed);

    // 1. Initial training: batch init on k0, sequential on the rest.
    let k0 = (2 * cfg.n_hidden).max(300).min(split.train.len());
    let (init, rest) = split.train.split_at(k0);
    model.init_batch(&init.xs, &init.labels)?;
    for r in 0..rest.len() {
        model.train_step(rest.xs.row(r), rest.labels[r]);
    }

    // 2. Test before drift.
    let acc_before = model.accuracy(&split.test0.xs, &split.test0.labels) * 100.0;

    // 3. ODL phase (skipped for NoODL).
    let mut queries = 0usize;
    let mut trained = 0usize;
    let mut odl_events = 0usize;
    if with_odl {
        odl_events = split.odl_stream.len();
        for r in 0..split.odl_stream.len() {
            let x = split.odl_stream.xs.row(r);
            let pred = model.predict(x);
            // Condition 2: drift "currently detected" until the warmup
            // count has been trained (protocol-oracle semantics: the drift
            // event is the stream switch itself; it is considered over
            // once the model has re-trained on warmup samples).
            let drift_now = false;
            // Borrow-based metric path: `last_logits` reuses the workspace
            // logits the predict above just produced — the Error-L2 metric
            // gets the exact EL2N with zero allocation per event.
            match pruner.decide_with_logits(&pred, model.last_logits(), trained, drift_now) {
                Decision::Skip => {
                    pruner.observe(Decision::Skip, None);
                }
                Decision::Query => {
                    queries += 1;
                    let t = teacher_label(
                        split.odl_stream.labels[r],
                        split.odl_stream.n_classes,
                        cfg.teacher_error,
                        rng,
                    );
                    pruner.observe(Decision::Query, Some(pred.class == t));
                    model.train_step(x, t);
                    trained += 1;
                }
            }
        }
    }

    // 4. Test after drift.
    let acc_after = model.accuracy(&split.test1.xs, &split.test1.labels) * 100.0;

    Ok(TrialOutcome {
        acc_before,
        acc_after,
        queries,
        odl_events,
        trained,
        final_theta: pruner.policy.theta(),
    })
}

fn run_dnn_trial(
    _cfg: &ProtocolConfig,
    layers: &[usize],
    split: DriftSplit,
    rng: &mut Rng64,
) -> Result<TrialOutcome> {
    let mut full_layers = layers.to_vec();
    // ensure input/output dims match the data
    if full_layers.first() != Some(&split.train.n_features()) {
        full_layers.insert(0, split.train.n_features());
    }
    if full_layers.last() != Some(&split.train.n_classes) {
        full_layers.push(split.train.n_classes);
    }
    let dnn_cfg = DnnConfig {
        layers: full_layers,
        epochs: 8,
        ..Default::default()
    };
    let mut dnn = Dnn::new(dnn_cfg, rng);
    dnn.fit(&split.train.xs, &split.train.labels, rng);
    let acc_before = dnn.accuracy(&split.test0.xs, &split.test0.labels) * 100.0;
    let acc_after = dnn.accuracy(&split.test1.xs, &split.test1.labels) * 100.0;
    Ok(TrialOutcome {
        acc_before,
        acc_after,
        queries: 0,
        odl_events: 0,
        trained: 0,
        final_theta: 1.0,
    })
}

/// Run all trials (parallel across worker threads) and aggregate.
pub fn run(cfg: &ProtocolConfig) -> Result<Aggregate> {
    let mut seeds = Vec::with_capacity(cfg.trials);
    let mut master = Rng64::new(cfg.master_seed);
    for t in 0..cfg.trials {
        seeds.push(master.fork(t as u64).next_u64());
    }

    // one trial per executor item; the ordered result vector keeps the
    // aggregation walking outcomes in seed order for every worker count
    let n_workers = parallel::resolve_workers(0, cfg.trials);
    let outcomes: Vec<TrialOutcome> =
        parallel::parallel_map(n_workers, &seeds, |_, &s| run_trial(cfg, s))
            .into_iter()
            .collect::<Result<Vec<_>>>()?;

    let mut agg = Aggregate {
        label: cfg.variant.label(cfg.n_hidden),
        before: RunningStats::new(),
        after: RunningStats::new(),
        comm: RunningStats::new(),
        queries: RunningStats::new(),
        outcomes: Vec::new(),
    };
    for o in &outcomes {
        agg.before.push(o.acc_before);
        agg.after.push(o.acc_after);
        agg.comm.push(o.comm_fraction() * 100.0);
        agg.queries.push(o.queries as f64);
    }
    agg.outcomes = outcomes;
    Ok(agg)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reduced-size config for fast tests.
    pub fn tiny_cfg(variant: Variant) -> ProtocolConfig {
        let mut cfg = ProtocolConfig::new(variant, 32);
        cfg.synth = SynthConfig {
            n_features: 40,
            n_classes: 4,
            n_subjects: 30,
            samples_per_cell: 10,
            // 40 features aggregate far less signal than 561 — rescale the
            // class separation so the tiny problem is learnable (~90 %).
            proto_sigma: 1.1,
            confuse_frac: 0.04,
            ..Default::default()
        };
        cfg.trials = 2;
        cfg
    }

    #[test]
    fn odl_recovers_accuracy_after_drift() {
        let no_odl = run(&tiny_cfg(Variant::NoOdl(AlphaKind::Hash))).unwrap();
        let odl = run(&tiny_cfg(Variant::Odl(AlphaKind::Hash))).unwrap();
        // drift must hurt the frozen model...
        assert!(
            no_odl.after.mean() < no_odl.before.mean() - 3.0,
            "drift too mild: before {} after {}",
            no_odl.before.mean(),
            no_odl.after.mean()
        );
        // ...and ODL must recover a substantial part of the drop
        assert!(
            odl.after.mean() > no_odl.after.mean() + 3.0,
            "ODL did not recover: odl {} vs noodl {}",
            odl.after.mean(),
            no_odl.after.mean()
        );
    }

    #[test]
    fn pruning_reduces_queries() {
        let mut with = tiny_cfg(Variant::Odl(AlphaKind::Hash));
        with.pruning = PruningSpec::Fixed(0.16);
        with.warmup = Some(30); // tiny stream; paper's 288 would never engage
        let pruned = run(&with).unwrap();
        let unpruned = run(&tiny_cfg(Variant::Odl(AlphaKind::Hash))).unwrap();
        assert!(
            pruned.queries.mean() < unpruned.queries.mean(),
            "pruning must reduce queries: {} vs {}",
            pruned.queries.mean(),
            unpruned.queries.mean()
        );
        // unpruned = 100 % communication volume
        assert!((unpruned.comm.mean() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn trials_are_deterministic() {
        let cfg = tiny_cfg(Variant::Odl(AlphaKind::Hash));
        let a = run(&cfg).unwrap();
        let b = run(&cfg).unwrap();
        assert_eq!(a.before.mean(), b.before.mean());
        assert_eq!(a.after.mean(), b.after.mean());
    }

    #[test]
    fn teacher_errors_hurt_early_training() {
        // Note: a *late*-stream noisy teacher barely moves OS-ELM (P decays
        // as 1/t — RLS damping), which is itself a meaningful property.
        // The damage shows when the teacher is wrong from a fresh init,
        // while P is still large; that is what this test pins.
        use crate::data::synth::SynthHar;
        use crate::linalg::Mat;
        let mut rng = Rng64::new(77);
        let synth = tiny_cfg(Variant::Odl(AlphaKind::Hash)).synth;
        let gen = SynthHar::new(synth, &mut rng);
        let pool = gen.generate(&mut rng);
        let model_cfg = crate::odl::OsElmConfig {
            n_in: pool.n_features(),
            n_hidden: 32,
            n_out: pool.n_classes,
            ..Default::default()
        };
        let k0 = 64;
        let (init, rest) = pool.split_at(k0);
        let (stream, test) = rest.split_at(400);

        let run_with = |err: f64| -> f64 {
            let mut rng = Rng64::new(5);
            let mut m = crate::odl::OsElm::new(model_cfg, &mut rng, 3);
            m.init_batch(&init.xs, &init.labels).unwrap();
            for r in 0..stream.len() {
                let t = teacher_label(stream.labels[r], pool.n_classes, err, &mut rng);
                m.train_step(stream.xs.row(r), t);
            }
            let test_xs: &Mat = &test.xs;
            m.accuracy(test_xs, &test.labels)
        };
        let clean = run_with(0.0);
        let noisy = run_with(0.6);
        assert!(
            noisy < clean - 0.05,
            "60% wrong labels from fresh init must hurt: clean {clean} noisy {noisy}"
        );
    }
}
