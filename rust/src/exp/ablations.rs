//! Ablations beyond the paper's published grid:
//!
//! * **X sweep** — §3.2/§3.3: "A smaller X saves more power while it
//!   affects the accuracy." The paper states this without a table; we
//!   measure it.
//! * **Hysteresis sweep** — our DESIGN.md §3 adaptation (mismatch
//!   hysteresis M); M = 1 is the paper's literal rule 3.
//! * **Detector comparison** — oracle vs CUSUM-centroid vs confidence
//!   detectors on the fleet scenario (the paper defers detection to [6]).

use super::protocol::{run, ProtocolConfig, PruningSpec, Variant};
use crate::odl::AlphaKind;
use crate::pruning::{AutoTheta, Metric, Pruner, ThetaPolicy};
use crate::util::table::{pm, Table};
use anyhow::Result;

/// Sweep the consecutive-success requirement X of the auto-θ controller.
pub fn x_sweep(trials: usize, xs: &[u32]) -> Result<Table> {
    let mut t = Table::new(
        &format!("Ablation: auto-theta X sweep (ODLHash N=128, {trials} trials)"),
        &["X", "Af [%]", "comm volume [%]"],
    );
    for &x in xs {
        let mut cfg = ProtocolConfig::new(Variant::Odl(AlphaKind::Hash), 128);
        cfg.trials = trials;
        cfg.pruning = PruningSpec::Auto { x };
        let agg = run(&cfg)?;
        t.row(&[
            x.to_string(),
            pm(agg.after.mean(), agg.after.std()),
            format!("{:.1}", agg.comm.mean()),
        ]);
    }
    Ok(t)
}

/// Sweep the mismatch hysteresis M (M = 1 = the paper's literal rule 3).
/// Returns (table, comm% per M) so tests can assert the Markov-chain
/// argument from the pruning module docs.
pub fn hysteresis_sweep(trials: usize, ms: &[u32]) -> Result<(Table, Vec<f64>)> {
    let mut t = Table::new(
        &format!("Ablation: mismatch hysteresis M (M=1 is the paper's literal rule; {trials} trials)"),
        &["M", "Af [%]", "comm volume [%]", "final theta (mean)"],
    );
    let mut comms = Vec::new();
    for &m in ms {
        let mut cfg = ProtocolConfig::new(Variant::Odl(AlphaKind::Hash), 128);
        cfg.trials = trials;
        // PruningSpec::Auto hard-codes default hysteresis; build by hand.
        cfg.pruning = PruningSpec::Off; // placeholder; overridden per-trial below
        let agg = run_with_custom_auto(&cfg, m)?;
        t.row(&[
            m.to_string(),
            pm(agg.after.mean(), agg.after.std()),
            format!("{:.1}", agg.comm.mean()),
            format!(
                "{:.2}",
                agg.outcomes.iter().map(|o| o.final_theta as f64).sum::<f64>()
                    / agg.outcomes.len() as f64
            ),
        ]);
        comms.push(agg.comm.mean());
    }
    Ok((t, comms))
}

/// Protocol run with a hand-built auto-θ pruner (hysteresis override).
fn run_with_custom_auto(
    cfg: &ProtocolConfig,
    hysteresis: u32,
) -> Result<super::protocol::Aggregate> {
    // The protocol module exposes pruner construction through PruningSpec;
    // for the ablation we rebuild per-trial with the custom controller.
    use super::protocol::run_trial_with_pruner;
    use crate::util::rng::Rng64;
    use crate::util::stats::RunningStats;

    let mut master = Rng64::new(cfg.master_seed);
    let mut agg = super::protocol::Aggregate {
        label: format!("auto(M={hysteresis})"),
        before: RunningStats::new(),
        after: RunningStats::new(),
        comm: RunningStats::new(),
        queries: RunningStats::new(),
        outcomes: Vec::new(),
    };
    for t in 0..cfg.trials {
        let seed = master.fork(t as u64).next_u64();
        let mk = || {
            Pruner::new(
                ThetaPolicy::Auto(AutoTheta::new(10).with_hysteresis(hysteresis)),
                Metric::P1P2,
                crate::pruning::warmup_for(cfg.n_hidden),
            )
        };
        let o = run_trial_with_pruner(cfg, seed, mk())?;
        agg.before.push(o.acc_before);
        agg.after.push(o.acc_after);
        agg.comm.push(o.comm_fraction() * 100.0);
        agg.queries.push(o.queries as f64);
        agg.outcomes.push(o);
    }
    Ok(agg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smaller_x_prunes_more() {
        // §3.3: "A smaller X saves more power" — X=3 must cut comm at
        // least as much as X=30.
        let t3 = x_sweep(2, &[3]).unwrap();
        let t30 = x_sweep(2, &[30]).unwrap();
        let comm = |t: &Table| -> f64 {
            t.to_csv()
                .lines()
                .nth(1)
                .unwrap()
                .split(',')
                .nth(2)
                .unwrap()
                .parse()
                .unwrap()
        };
        assert!(
            comm(&t3) <= comm(&t30) + 1.0,
            "X=3 comm {} vs X=30 comm {}",
            comm(&t3),
            comm(&t30)
        );
    }

    #[test]
    fn literal_rule_cannot_settle() {
        // The DESIGN.md §3 claim behind the hysteresis adaptation: with
        // M = 1 (the paper's literal rule 3) and ~10 % stream error, the
        // ladder pins near θ = 1 and communication stays high; M = 2
        // unlocks the published low-comm regime.
        let (_, comms) = hysteresis_sweep(2, &[1, 2]).unwrap();
        assert!(
            comms[0] > comms[1] + 20.0,
            "M=1 comm {} must stay far above M=2 comm {}",
            comms[0],
            comms[1]
        );
    }
}
