//! Figure 3: accuracy (before/after drift) and communication volume vs
//! the confidence threshold θ, plus the auto-tuned controller.
//!
//! Protocol (§3.2): ODLHash N = 128, warmup max(N, 288), θ from 0.01 to 1
//! (θ = 1 ⇒ no pruning ⇒ 100 % communication volume), X = 10 for Auto,
//! `trials` runs per configuration.

use super::protocol::{run, Aggregate, ProtocolConfig, PruningSpec, Variant};
use crate::odl::AlphaKind;
use crate::util::table::{pm, Table};
use anyhow::Result;

/// The θ sweep (paper: "varied from 0.01 to 1"; bars at the ladder points).
pub const THETA_SWEEP: [f32; 8] = [0.01, 0.02, 0.04, 0.08, 0.16, 0.32, 0.64, 1.0];

/// One sweep point result.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub label: String,
    pub agg: Aggregate,
}

pub fn sweep(trials: usize, metric: crate::pruning::Metric) -> Result<Vec<SweepPoint>> {
    let mut points = Vec::new();
    for &theta in THETA_SWEEP.iter() {
        let mut cfg = ProtocolConfig::new(Variant::Odl(AlphaKind::Hash), 128);
        cfg.trials = trials;
        cfg.metric = metric;
        cfg.pruning = if theta >= 1.0 {
            PruningSpec::Off
        } else {
            PruningSpec::Fixed(theta)
        };
        points.push(SweepPoint {
            label: format!("{theta}"),
            agg: run(&cfg)?,
        });
    }
    let mut cfg = ProtocolConfig::new(Variant::Odl(AlphaKind::Hash), 128);
    cfg.trials = trials;
    cfg.metric = metric;
    cfg.pruning = PruningSpec::Auto { x: 10 };
    points.push(SweepPoint {
        label: "Auto".into(),
        agg: run(&cfg)?,
    });
    Ok(points)
}

/// Render the figure as a table + CSV (bars: Be/Af accuracy; line: comm %).
pub fn run_fig(trials: usize, metric: crate::pruning::Metric) -> Result<(Table, String)> {
    let points = sweep(trials, metric)?;
    render(&points, trials, metric)
}

/// Render from precomputed sweep points (lets callers reuse the sweep).
pub fn render(
    points: &[SweepPoint],
    trials: usize,
    metric: crate::pruning::Metric,
) -> Result<(Table, String)> {
    let mut t = Table::new(
        &format!(
            "Figure 3: accuracy & communication volume vs theta (ODLHash N=128, {trials} trials, metric {metric:?})"
        ),
        &["theta", "Be [%]", "Af [%]", "comm volume [%]"],
    );
    let mut csv = String::from("theta,acc_before,acc_before_std,acc_after,acc_after_std,comm_pct\n");
    for p in points {
        t.row(&[
            p.label.clone(),
            pm(p.agg.before.mean(), p.agg.before.std()),
            pm(p.agg.after.mean(), p.agg.after.std()),
            format!("{:.1}", p.agg.comm.mean()),
        ]);
        csv.push_str(&format!(
            "{},{:.2},{:.2},{:.2},{:.2},{:.2}\n",
            p.label,
            p.agg.before.mean(),
            p.agg.before.std(),
            p.agg.after.mean(),
            p.agg.after.std(),
            p.agg.comm.mean()
        ));
    }
    Ok((t, csv))
}

/// The headline numbers the paper quotes for Auto (§3.2): communication
/// reduction vs θ=1 and the accuracy drop.
pub fn auto_headline(points: &[SweepPoint]) -> Option<(f64, f64)> {
    let full = points.iter().find(|p| p.label == "1")?;
    let auto = points.iter().find(|p| p.label == "Auto")?;
    let comm_reduction = 100.0 - auto.agg.comm.mean();
    let acc_drop = full.agg.after.mean() - auto.agg.after.mean();
    Some((comm_reduction, acc_drop))
}

/// Shared reduced-trial sweep for the fig3/fig4 test modules (the sweep
/// costs ~10 s at full 561-dim size; compute it once per test binary).
#[cfg(test)]
pub(crate) fn test_sweep() -> &'static [SweepPoint] {
    use std::sync::OnceLock;
    static SWEEP: OnceLock<Vec<SweepPoint>> = OnceLock::new();
    SWEEP.get_or_init(|| sweep(2, crate::pruning::Metric::P1P2).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reduced-trial smoke: monotone comm volume + bounded accuracy loss.
    /// (The full 20-trial run is the bench / CLI path.)
    #[test]
    fn sweep_shape_holds() {
        let points = test_sweep();
        assert_eq!(points.len(), THETA_SWEEP.len() + 1);
        // comm volume decreases as theta decreases
        let comm: Vec<f64> = points[..THETA_SWEEP.len()]
            .iter()
            .map(|p| p.agg.comm.mean())
            .collect();
        assert!((comm.last().unwrap() - 100.0).abs() < 1e-9, "theta=1 ⇒ 100%");
        assert!(comm[0] < comm[7] - 30.0, "theta=0.01 must prune a lot");
        for w in comm.windows(2) {
            assert!(w[0] <= w[1] + 3.0, "comm roughly monotone: {comm:?}");
        }
        // paper: accuracy loss small for theta >= 0.08
        let full = points[7].agg.after.mean();
        let t008 = points[3].agg.after.mean();
        assert!(full - t008 < 2.5, "theta=0.08 loss too big");
        let (red, drop) = auto_headline(points).unwrap();
        assert!(red > 30.0, "auto reduction {red}");
        assert!(drop < 2.5, "auto drop {drop}");
    }
}
