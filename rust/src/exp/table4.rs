//! Table 4: "Execution time and power consumption of ODL core at 10 MHz"
//! plus the Figure-5 layout summary — regenerated from the cycle, power,
//! and area models.

use crate::hw::area::AreaReport;
use crate::hw::cycles::{CycleCosts, CycleModel};
use crate::hw::memory::{memory_bytes, CoreVariant};
use crate::hw::{PowerModel, PowerState};
use crate::util::table::Table;

/// Build Table 4 (+ layout lines when `with_area`).
pub fn run(with_area: bool) -> Table {
    let cyc = CycleModel::prototype();
    let pow = PowerModel::default();
    let area = AreaReport::prototype();
    let mut t = Table::new(
        "Table 4: execution time and power of the ODL core at 10 MHz (n=561, N=128, m=6)",
        &["quantity", "measured", "paper"],
    );
    t.row(&[
        "Core size".into(),
        format!("{:.2} mm x {:.2} mm (est.)", area.die_w_mm, area.die_h_mm),
        "2.25 mm x 2.25 mm".into(),
    ]);
    t.row(&[
        "Prediction time".into(),
        format!("{:.2} ms ({} cycles)", cyc.predict_time_s() * 1e3, cyc.predict_cycles()),
        "36.40 ms".into(),
    ]);
    t.row(&[
        "Seq. train time".into(),
        format!("{:.2} ms ({} cycles)", cyc.train_time_s() * 1e3, cyc.train_cycles()),
        "171.28 ms".into(),
    ]);
    t.row(&[
        "Prediction power".into(),
        format!("{:.2} mW", pow.power_mw(PowerState::Predict)),
        "3.39 mW".into(),
    ]);
    t.row(&[
        "Seq. train power".into(),
        format!("{:.2} mW", pow.power_mw(PowerState::Train)),
        "3.37 mW".into(),
    ]);
    t.row(&[
        "Idle power".into(),
        format!("{:.2} mW", pow.power_mw(PowerState::Idle)),
        "3.06 mW".into(),
    ]);
    t.row(&[
        "Sleep power".into(),
        format!("{:.2} mW", pow.power_mw(PowerState::Sleep)),
        "1.33 mW".into(),
    ]);
    if with_area {
        let bytes = memory_bytes(CoreVariant::OdlHash, 561, 128, 6);
        t.row(&[
            "SRAM".into(),
            format!(
                "{:.2} kB in {} x 8 kB macros, {:.2} mm²",
                bytes as f64 / 1000.0,
                area.n_sram_macros,
                area.sram_area_mm2
            ),
            "136.39 kB, 17 macros (Fig 5)".into(),
        ]);
        t.row(&[
            "Logic".into(),
            format!("{:.2} mm² (MAC + divider + FSM)", area.logic_area_mm2),
            "-".into(),
        ]);
    }
    t
}

/// The divider ablation (DESIGN.md: per-element vs hoisted division).
pub fn divider_ablation() -> Table {
    let base = CycleModel::prototype();
    let hoisted = CycleModel {
        costs: CycleCosts::hoisted_divider(),
        ..base
    };
    let mut t = Table::new(
        "Ablation: per-element divider (published core) vs hoisted reciprocal (our kernel schedule)",
        &["schedule", "train cycles", "train time @10MHz", "speedup"],
    );
    t.row(&[
        "per-element divide".into(),
        base.train_cycles().to_string(),
        format!("{:.2} ms", base.train_time_s() * 1e3),
        "1.00x".into(),
    ]);
    t.row(&[
        "hoisted reciprocal".into(),
        hoisted.train_cycles().to_string(),
        format!("{:.2} ms", hoisted.train_time_s() * 1e3),
        format!(
            "{:.2}x",
            base.train_cycles() as f64 / hoisted.train_cycles() as f64
        ),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_contains_paper_matched_values() {
        let r = run(true).render();
        assert!(r.contains("36.40 ms"));
        assert!(r.contains("171.28 ms"));
        assert!(r.contains("3.39 mW"));
        assert!(r.contains("17 x 8 kB"));
    }

    #[test]
    fn ablation_shows_speedup() {
        let r = divider_ablation().render();
        assert!(r.contains("per-element divide"));
        assert!(r.contains("hoisted reciprocal"));
    }
}
