//! Figure 1: 2-D visualization of the HAR dataset per activity class,
//! colored by human subject — the motivation figure showing per-subject
//! clusters.
//!
//! For each class we fit a 2-component PCA on that class's samples and
//! emit (pc1, pc2, subject, held_out) rows as CSV, one file per class,
//! plus a cluster-separation summary (mean silhouette-style score of
//! subject clusters) that quantifies what the paper shows visually.

use crate::data::pca::Pca;
use crate::data::{Dataset, HELD_OUT_SUBJECTS};
use crate::util::rng::Rng64;
use crate::util::table::Table;
use anyhow::Result;
use std::path::Path;

/// Per-class PCA projections: returns (class, csv-text, subject-cluster score).
pub fn project(pool: &Dataset, rng: &mut Rng64) -> Vec<(usize, String, f64)> {
    let mut out = Vec::new();
    for class in 0..pool.n_classes {
        let subset = pool.filter(|l, _| l == class);
        let pca = Pca::fit(&subset.xs, 2, rng);
        let proj = pca.transform(&subset.xs);
        let mut csv = String::from("pc1,pc2,subject,held_out\n");
        for r in 0..proj.rows {
            let s = subset.subjects[r];
            csv.push_str(&format!(
                "{:.4},{:.4},{},{}\n",
                proj.at(r, 0),
                proj.at(r, 1),
                s,
                HELD_OUT_SUBJECTS.contains(&s) as u8
            ));
        }
        out.push((class, csv, subject_cluster_score(&proj, &subset.subjects)));
    }
    out
}

/// How clustered are subjects in the 2-D projection? Ratio of mean
/// between-subject centroid distance to mean within-subject spread
/// (> 1 ⇒ visible clusters, the paper's qualitative claim).
pub fn subject_cluster_score(proj: &crate::linalg::Mat, subjects: &[usize]) -> f64 {
    use std::collections::HashMap;
    let mut groups: HashMap<usize, Vec<(f32, f32)>> = HashMap::new();
    for r in 0..proj.rows {
        groups
            .entry(subjects[r])
            .or_default()
            .push((proj.at(r, 0), proj.at(r, 1)));
    }
    let centroids: Vec<(f32, f32)> = groups
        .values()
        .map(|pts| {
            let n = pts.len() as f32;
            (
                pts.iter().map(|p| p.0).sum::<f32>() / n,
                pts.iter().map(|p| p.1).sum::<f32>() / n,
            )
        })
        .collect();
    let within: f64 = groups
        .values()
        .zip(&centroids)
        .map(|(pts, c)| {
            pts.iter()
                .map(|p| (((p.0 - c.0).powi(2) + (p.1 - c.1).powi(2)) as f64).sqrt())
                .sum::<f64>()
                / pts.len() as f64
        })
        .sum::<f64>()
        / groups.len() as f64;
    let mut between = 0.0;
    let mut pairs = 0usize;
    for i in 0..centroids.len() {
        for j in i + 1..centroids.len() {
            let (a, b) = (centroids[i], centroids[j]);
            between += (((a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)) as f64).sqrt();
            pairs += 1;
        }
    }
    if pairs == 0 || within <= 0.0 {
        return 0.0;
    }
    (between / pairs as f64) / within
}

/// Run the harness: write CSVs under `out_dir`, return the summary table.
pub fn run(pool: &Dataset, out_dir: &Path, seed: u64) -> Result<Table> {
    std::fs::create_dir_all(out_dir)?;
    let mut rng = Rng64::new(seed);
    let mut t = Table::new(
        "Figure 1: per-class 2-D projections (CSV per class) + subject-cluster scores",
        &["class", "samples", "cluster score", "csv"],
    );
    for (class, csv, score) in project(pool, &mut rng) {
        let path = out_dir.join(format!("fig1_class{class}.csv"));
        std::fs::write(&path, &csv)?;
        t.row(&[
            class.to_string(),
            (csv.lines().count() - 1).to_string(),
            format!("{score:.2}"),
            path.display().to_string(),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthConfig, SynthHar};

    #[test]
    fn projections_show_subject_clusters() {
        let mut rng = Rng64::new(5);
        // 60 features aggregate less subject signal than 561, so scale the
        // subject offsets up to match the full-size clustering strength.
        let cfg = SynthConfig {
            n_features: 60,
            n_classes: 3,
            n_subjects: 12,
            samples_per_cell: 15,
            subject_sigma: 1.2,
            ..Default::default()
        };
        let pool = SynthHar::new(cfg, &mut rng).generate(&mut rng);
        let projections = project(&pool, &mut rng);
        assert_eq!(projections.len(), 3);
        for (class, csv, score) in &projections {
            assert!(csv.lines().count() > 100, "class {class} csv too small");
            // the paper's Figure-1 claim: same-subject samples cluster
            assert!(
                *score > 0.8,
                "class {class}: subject clusters not visible (score {score})"
            );
        }
    }

    #[test]
    fn csv_format_parses() {
        let mut rng = Rng64::new(6);
        let cfg = SynthConfig {
            n_features: 30,
            n_classes: 2,
            n_subjects: 6,
            samples_per_cell: 5,
            ..Default::default()
        };
        let pool = SynthHar::new(cfg, &mut rng).generate(&mut rng);
        let (_, csv, _) = &project(&pool, &mut rng)[0];
        for line in csv.lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            assert_eq!(cells.len(), 4);
            cells[0].parse::<f32>().unwrap();
            cells[2].parse::<usize>().unwrap();
        }
    }
}
