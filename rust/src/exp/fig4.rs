//! Figure 4: total power of the ODLHash core during the training mode vs
//! θ, for three event frequencies (1 / 0.2 / 0.1 Hz), split into
//! computation (dark bars) and communication (light bars).
//!
//! Power = (core event energy + BLE query energy × measured query rate)
//! / event period; query rates come from the same runs as Figure 3, so
//! `run_fig` takes the Fig-3 sweep as input.

use super::fig3::SweepPoint;
use crate::hw::ble::{training_mode_power_split_mw, BleModel};
use crate::hw::{CycleModel, PowerModel};
use crate::util::table::Table;
use anyhow::Result;

/// Event periods the paper evaluates [s].
pub const PERIODS: [f64; 3] = [1.0, 5.0, 10.0];

/// Paper's quoted reductions for Auto at the three periods [%].
pub const PAPER_AUTO_REDUCTION: [f64; 3] = [49.4, 34.7, 25.2];

/// One bar of the figure.
#[derive(Clone, Debug)]
pub struct PowerBar {
    pub theta: String,
    pub period_s: f64,
    pub compute_mw: f64,
    pub comm_mw: f64,
}

impl PowerBar {
    pub fn total(&self) -> f64 {
        self.compute_mw + self.comm_mw
    }
}

/// Compute the full figure from Fig-3 sweep points.
pub fn bars(points: &[SweepPoint]) -> Vec<PowerBar> {
    let core = PowerModel::default();
    let cyc = CycleModel::prototype();
    let ble = BleModel::default();
    let mut out = Vec::new();
    for &period in PERIODS.iter() {
        for p in points {
            let query_rate = p.agg.comm.mean() / 100.0;
            let (compute, comm) =
                training_mode_power_split_mw(&core, &cyc, &ble, period, query_rate);
            out.push(PowerBar {
                theta: p.label.clone(),
                period_s: period,
                compute_mw: compute,
                comm_mw: comm,
            });
        }
    }
    out
}

/// Render the figure as a table + CSV.
pub fn run_fig(points: &[SweepPoint]) -> Result<(Table, String)> {
    let all = bars(points);
    let mut t = Table::new(
        "Figure 4: training-mode power vs theta (compute + comm), three event rates",
        &[
            "period",
            "theta",
            "compute [mW]",
            "comm [mW]",
            "total [mW]",
            "reduction vs theta=1",
        ],
    );
    let mut csv = String::from("period_s,theta,compute_mw,comm_mw,total_mw,reduction_pct\n");
    for &period in PERIODS.iter() {
        let at_period: Vec<&PowerBar> =
            all.iter().filter(|b| b.period_s == period).collect();
        let full = at_period
            .iter()
            .find(|b| b.theta == "1")
            .map(|b| b.total())
            .unwrap_or(f64::NAN);
        for b in at_period {
            let reduction = 100.0 * (1.0 - b.total() / full);
            t.row(&[
                format!("1/{period:.0}s"),
                b.theta.clone(),
                format!("{:.3}", b.compute_mw),
                format!("{:.3}", b.comm_mw),
                format!("{:.3}", b.total()),
                format!("{reduction:.1} %"),
            ]);
            csv.push_str(&format!(
                "{},{},{:.4},{:.4},{:.4},{:.2}\n",
                period,
                b.theta,
                b.compute_mw,
                b.comm_mw,
                b.total(),
                reduction
            ));
        }
    }
    Ok((t, csv))
}

/// Auto-θ reductions at the three event rates (the §3.3 headline).
pub fn auto_reductions(points: &[SweepPoint]) -> Vec<(f64, f64)> {
    let all = bars(points);
    PERIODS
        .iter()
        .map(|&period| {
            let full = all
                .iter()
                .find(|b| b.period_s == period && b.theta == "1")
                .map(|b| b.total())
                .unwrap();
            let auto = all
                .iter()
                .find(|b| b.period_s == period && b.theta == "Auto")
                .map(|b| b.total())
                .unwrap();
            (period, 100.0 * (1.0 - auto / full))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::fig3::test_sweep;

    #[test]
    fn power_reductions_match_paper_shape() {
        let points = test_sweep();
        let reductions = auto_reductions(points);
        // reductions shrink with the event period (comm amortized less)…
        assert!(reductions[0].1 > reductions[1].1);
        assert!(reductions[1].1 > reductions[2].1);
        // …and land in the paper's regime at 1 Hz (49.4 % published; our
        // auto settles one ladder rung lower, so allow a band)
        assert!(
            (35.0..75.0).contains(&reductions[0].1),
            "1 Hz reduction {reductions:?}"
        );
    }

    #[test]
    fn comm_power_dominates_at_1hz_without_pruning() {
        let points = test_sweep();
        let all = bars(points);
        let full = all
            .iter()
            .find(|b| b.period_s == 1.0 && b.theta == "1")
            .unwrap();
        assert!(
            full.comm_mw > full.compute_mw * 2.0,
            "BLE must dominate: {full:?}"
        );
    }

    #[test]
    fn csv_row_count() {
        let points = test_sweep();
        let (_, csv) = run_fig(points).unwrap();
        // header + 3 periods × (8 thetas + auto)
        assert_eq!(csv.lines().count(), 1 + 3 * 9);
    }
}
