//! Table 1: "Memory size of ODL cores [kB] (n = 561 and m = 6)."
//!
//! Regenerated from the exact SRAM model in [`crate::hw::memory`]; the
//! PAPER column values are asserted equal by the model's unit tests, so
//! this harness simply prints both.

use crate::hw::memory::{CoreVariant, MemoryBreakdown};
use crate::util::table::Table;

pub const N_SWEEP: [usize; 5] = [32, 64, 128, 256, 512];
pub const N_IN: usize = 561;
pub const M_OUT: usize = 6;

/// Published Table 1 (for side-by-side printing).
pub const PAPER: [(usize, f64, f64, f64); 5] = [
    (32, 74.82, 83.01, 11.20),
    (64, 147.40, 180.16, 36.55),
    (128, 292.55, 423.62, 136.39),
    (256, 582.85, 1107.14, 532.68),
    (512, 1163.46, 3260.61, 2111.68),
];

/// Build the table (measured values; identical to the paper's).
pub fn run() -> Table {
    let mut t = Table::new(
        "Table 1: Memory size of ODL cores [kB] (n = 561, m = 6)",
        &["N", "NoODL", "ODLBase", "ODLHash", "paper(NoODL/Base/Hash)"],
    );
    for (i, &n_hidden) in N_SWEEP.iter().enumerate() {
        let kb = |v: CoreVariant| MemoryBreakdown::new(v, N_IN, n_hidden, M_OUT).kb();
        let (_, p_no, p_base, p_hash) = PAPER[i];
        t.row(&[
            n_hidden.to_string(),
            format!("{:.2}", kb(CoreVariant::NoOdl)),
            format!("{:.2}", kb(CoreVariant::OdlBase)),
            format!("{:.2}", kb(CoreVariant::OdlHash)),
            format!("{p_no}/{p_base}/{p_hash}"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_emits_all_rows() {
        let t = run();
        assert_eq!(t.n_rows(), 5);
        let rendered = t.render();
        // measured == paper for a few spot cells
        assert!(rendered.contains("136.39"));
        assert!(rendered.contains("3260.61"));
    }
}
