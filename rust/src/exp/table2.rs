//! Table 2: "Comparisons with reported results" — parameter count and
//! pre-drift accuracy of ODLHash (N = 128, 256) against the published
//! SOTA rows ([9] Teng et al., [10] Huang et al.).
//!
//! The SOTA rows are literature constants (their systems are CNNs trained
//! on the real UCI data); our rows are measured on the calibrated
//! workload via the §3 protocol's steps 1–2.

use super::protocol::{run, ProtocolConfig, Variant};
use crate::hw::memory::odl_param_count;
use crate::odl::AlphaKind;
use crate::util::table::Table;
use anyhow::Result;

/// Published comparison rows: (label, params, accuracy %).
pub const PAPER_SOTA: [(&str, &str, f64); 2] = [
    ("Q. Teng et al., [9]", "0.35M", 96.98),
    ("W. Huang et al., [10]", "0.84M", 97.28),
];

/// Paper's own rows for reference.
pub const PAPER_SELF: [(usize, &str, f64); 2] = [(128, "34k", 93.67), (256, "133k", 95.51)];

pub fn run_table(trials: usize) -> Result<Table> {
    let mut t = Table::new(
        "Table 2: parameters vs accuracy (ODLHash rows measured; SOTA rows from the literature)",
        &["", "# of parameters", "Accuracy [%]", "paper"],
    );
    for (n_hidden, paper_params, paper_acc) in PAPER_SELF {
        let mut cfg = ProtocolConfig::new(Variant::Odl(AlphaKind::Hash), n_hidden);
        cfg.trials = trials;
        let agg = run(&cfg)?;
        t.row(&[
            format!("ODLHash (N = {n_hidden})"),
            format!("{} ({paper_params})", odl_param_count(n_hidden, 6)),
            format!("{:.2}", agg.before.mean()),
            format!("{paper_acc}"),
        ]);
    }
    for (label, params, acc) in PAPER_SOTA {
        t.row(&[
            label.to_string(),
            params.to_string(),
            format!("{acc}"),
            format!("{acc} (literature)"),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_cells_match_paper() {
        assert_eq!(odl_param_count(128, 6), 33_536);
        assert_eq!(odl_param_count(256, 6), 132_608);
    }
}
