//! Table 3: "Accuracy of ODL approaches and counterparts before and after
//! drift" — the paper's central accuracy experiment.
//!
//! Grid: {NoODL, ODLBase, ODLHash} × N ∈ {128, 256} + the DNN
//! (561,512,256,6) baseline; `trials` independent runs (paper: 20),
//! mean ± std, no pruning during the ODL phase.

use super::protocol::{run, Aggregate, ProtocolConfig, Variant};
use crate::odl::AlphaKind;
use crate::util::table::{pm, Table};
use anyhow::Result;

/// Published Table 3 for side-by-side printing: (label, before, after).
pub const PAPER: [(&str, &str, &str); 7] = [
    ("NoODL (N = 128)", "92.9±0.8", "82.9±1.4"),
    ("ODLBase (N = 128)", "93.4±0.6", "90.8±1.7"),
    ("ODLHash (N = 128)", "93.1±0.8", "90.7±1.0"),
    ("NoODL (N = 256)", "95.1±0.3", "83.7±1.0"),
    ("ODLBase (N = 256)", "95.2±0.3", "92.5±0.6"),
    ("ODLHash (N = 256)", "95.1±0.4", "92.3±0.7"),
    ("DNN (561,512,256,6)", "94.1±1.0", "85.2±1.3"),
];

/// The experiment grid in paper order.
pub fn grid() -> Vec<(Variant, usize)> {
    vec![
        (Variant::NoOdl(AlphaKind::Hash), 128),
        (Variant::Odl(AlphaKind::Stored), 128),
        (Variant::Odl(AlphaKind::Hash), 128),
        (Variant::NoOdl(AlphaKind::Hash), 256),
        (Variant::Odl(AlphaKind::Stored), 256),
        (Variant::Odl(AlphaKind::Hash), 256),
        (Variant::Dnn(vec![561, 512, 256, 6]), 0),
    ]
}

/// Run the full grid; returns (table, per-row aggregates).
pub fn run_table(trials: usize) -> Result<(Table, Vec<Aggregate>)> {
    let mut t = Table::new(
        &format!("Table 3: accuracy before/after drift (mean±std over {trials} trials)"),
        &["", "Before [%]", "After [%]", "paper (Before / After)"],
    );
    let mut aggs = Vec::new();
    for (i, (variant, n_hidden)) in grid().into_iter().enumerate() {
        let mut cfg = ProtocolConfig::new(variant, n_hidden);
        cfg.trials = trials;
        let agg = run(&cfg)?;
        let (_, p_before, p_after) = PAPER[i];
        t.row(&[
            agg.label.clone(),
            pm(agg.before.mean(), agg.before.std()),
            pm(agg.after.mean(), agg.after.std()),
            format!("{p_before} / {p_after}"),
        ]);
        aggs.push(agg);
    }
    Ok((t, aggs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_matches_paper_rows() {
        let g = grid();
        assert_eq!(g.len(), PAPER.len());
        assert_eq!(g[0].0.label(128), "NoODL (N = 128)");
        assert_eq!(g[2].0.label(128), "ODLHash (N = 128)");
        assert_eq!(g[6].0.label(0), "DNN (561,512,256,6)");
    }
}
