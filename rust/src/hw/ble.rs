//! BLE communication energy model — the nRF52840 link of §3.3.
//!
//! The paper: "edge devices use BLE to send 561 features to a teacher
//! device and receive the corresponding label … Data rate is 1 Mbps, TX
//! power is 0 dBm, and supply voltage is 3.0 V. The power values are
//! estimated by Nordic Semiconductor online tool."
//!
//! A label-acquisition transaction is modelled as: radio/link setup
//! (connection establishment + stack wakeup — the dominant term for a
//! sporadic, disconnect-between-queries duty cycle, which is what a
//! multi-edge single-teacher BLE star must do), payload TX at 1 Mbps with
//! L2CAP/ATT framing, label RX, and MCU stack overhead.
//!
//! Calibration: the per-query energy is fit so Figure 4's published
//! training-mode power reductions under auto-θ — **49.4 % @ 1 event/s,
//! 34.7 % @ 1/5 s, 25.2 % @ 1/10 s** — reproduce against the core power
//! model (the fit across all three rates lands at ≈ 12 mJ/query; the Fig-4
//! test asserts the reductions within a few points).

use super::cycles::CycleModel;
use super::power::PowerModel;

/// BLE transaction energy model (all energies mJ, times s).
#[derive(Clone, Copy, Debug)]
pub struct BleModel {
    /// Payload bytes per query: 561 features × 4 B (32-bit fixed point).
    pub payload_bytes: usize,
    /// PHY data rate, bits/s.
    pub data_rate_bps: f64,
    /// TX current at 0 dBm [mA] (nRF52840 datasheet: ≈ 4.8 mA with DC/DC).
    pub tx_current_ma: f64,
    /// RX current [mA] (≈ 4.6 mA).
    pub rx_current_ma: f64,
    /// Supply voltage [V].
    pub supply_v: f64,
    /// Connection-establishment + stack energy per sporadic query [mJ]
    /// (advertising/scan window + connection events + MCU wakeup — the
    /// calibrated dominant term).
    pub setup_mj: f64,
    /// Protocol framing overhead factor on the raw payload time.
    pub framing_overhead: f64,
    /// Label RX time [s] (one connection event holding the 1-byte label).
    pub rx_time_s: f64,
}

impl Default for BleModel {
    fn default() -> Self {
        Self {
            payload_bytes: 561 * 4,
            data_rate_bps: 1e6,
            tx_current_ma: 4.8,
            rx_current_ma: 4.6,
            supply_v: 3.0,
            setup_mj: 11.2,
            framing_overhead: 1.35,
            rx_time_s: 0.005,
        }
    }
}

impl BleModel {
    /// Time on air for the feature payload [s].
    pub fn tx_time_s(&self) -> f64 {
        self.payload_bytes as f64 * 8.0 / self.data_rate_bps * self.framing_overhead
    }

    /// Energy of one label-acquisition query [mJ].
    pub fn query_energy_mj(&self) -> f64 {
        let tx = self.tx_time_s() * self.tx_current_ma * self.supply_v;
        let rx = self.rx_time_s * self.rx_current_ma * self.supply_v;
        self.setup_mj + tx + rx
    }

    /// Latency of one query round-trip [s] (setup + TX + RX turnaround);
    /// used by the fleet simulator's channel model.
    pub fn query_latency_s(&self) -> f64 {
        // connection setup latency (advertising interval dominated)
        let setup_latency = 0.06;
        setup_latency + self.tx_time_s() + self.rx_time_s
    }
}

/// Mean training-mode power [mW] for an edge running one event per
/// `period_s`, querying the teacher on a fraction `query_rate` of events
/// (Figure 4's quantity; the non-query events still predict, then sleep).
pub fn training_mode_power_mw(
    core: &PowerModel,
    cycles: &CycleModel,
    ble: &BleModel,
    period_s: f64,
    query_rate: f64,
) -> f64 {
    assert!((0.0..=1.0).contains(&query_rate));
    let e_query_event = core.event_energy_mj(cycles, period_s, true) + ble.query_energy_mj();
    let e_skip_event = core.event_energy_mj(cycles, period_s, false);
    let e = query_rate * e_query_event + (1.0 - query_rate) * e_skip_event;
    e / period_s
}

/// The compute/communication split of the same quantity (Fig 4's dark vs
/// light bars): returns (compute_mw, comm_mw).
pub fn training_mode_power_split_mw(
    core: &PowerModel,
    cycles: &CycleModel,
    ble: &BleModel,
    period_s: f64,
    query_rate: f64,
) -> (f64, f64) {
    let comp = query_rate * core.event_energy_mj(cycles, period_s, true)
        + (1.0 - query_rate) * core.event_energy_mj(cycles, period_s, false);
    let comm = query_rate * ble.query_energy_mj();
    (comp / period_s, comm / period_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tx_time_matches_rate() {
        let b = BleModel::default();
        // 2244 B ≈ 18 ms raw at 1 Mbps; ×1.35 framing ≈ 24 ms
        assert!((b.tx_time_s() - 0.02423).abs() < 5e-4, "{}", b.tx_time_s());
    }

    #[test]
    fn query_energy_dominated_by_setup() {
        let b = BleModel::default();
        let e = b.query_energy_mj();
        assert!(e > 11.0 && e < 13.0, "query energy {e} mJ");
        assert!(b.setup_mj / e > 0.8, "setup must dominate sporadic queries");
    }

    /// Figure 4's headline: auto-θ (query rate 0.443 per the paper) cuts
    /// training-mode power by ≈ 49.4 / 34.7 / 25.2 % at 1 / 5 / 10 s
    /// event periods. Our calibration must land within a few points.
    #[test]
    fn fig4_reductions_reproduce() {
        let core = PowerModel::default();
        let cyc = CycleModel::prototype();
        let ble = BleModel::default();
        let paper = [(1.0, 49.4), (5.0, 34.7), (10.0, 25.2)];
        for (period, want) in paper {
            let p_full = training_mode_power_mw(&core, &cyc, &ble, period, 1.0);
            let p_auto = training_mode_power_mw(&core, &cyc, &ble, period, 0.443);
            let reduction = 100.0 * (1.0 - p_auto / p_full);
            assert!(
                (reduction - want).abs() < 6.0,
                "period {period}s: reduction {reduction:.1} vs paper {want}"
            );
        }
    }

    #[test]
    fn split_sums_to_total() {
        let core = PowerModel::default();
        let cyc = CycleModel::prototype();
        let ble = BleModel::default();
        for rate in [0.0, 0.3, 1.0] {
            let total = training_mode_power_mw(&core, &cyc, &ble, 1.0, rate);
            let (comp, comm) = training_mode_power_split_mw(&core, &cyc, &ble, 1.0, rate);
            assert!((comp + comm - total).abs() < 1e-9);
        }
    }

    #[test]
    fn no_queries_means_no_comm_power() {
        let core = PowerModel::default();
        let cyc = CycleModel::prototype();
        let ble = BleModel::default();
        let (_, comm) = training_mode_power_split_mw(&core, &cyc, &ble, 1.0, 0.0);
        assert_eq!(comm, 0.0);
    }

    #[test]
    fn latency_sane_for_fleet_sim() {
        let b = BleModel::default();
        let l = b.query_latency_s();
        assert!(l > 0.05 && l < 0.2, "query latency {l}s");
    }
}
