//! Core power model — Table 4's four operating points, decomposed the way
//! §3.3 describes them:
//!
//! * the **memory part** retains weights/state and can never power off →
//!   its retention power is the **sleep** floor (1.33 mW);
//! * waking the core adds SRAM standby + clock tree + logic leakage →
//!   **idle** (3.06 mW);
//! * switching activity of the MAC/divider datapath adds the small active
//!   deltas → **predict** (3.39 mW) / **train** (3.37 mW; slightly lower
//!   activity than predict because divider cycles toggle less logic than
//!   the fully pipelined MAC+PRNG path).

use super::cycles::CycleModel;

/// Operating state of the core.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PowerState {
    /// Logic powered off; SRAM retention only.
    Sleep,
    /// Clocked but no datapath activity.
    Idle,
    Predict,
    Train,
}

/// State-based power model (milliwatts), calibrated to Table 4.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// SRAM retention (sleep floor).
    pub mem_retention_mw: f64,
    /// Additional power when awake (SRAM standby + clock + leakage).
    pub awake_extra_mw: f64,
    /// Additional switching power while predicting.
    pub predict_extra_mw: f64,
    /// Additional switching power while training.
    pub train_extra_mw: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        // Solves exactly to Table 4's four numbers.
        Self {
            mem_retention_mw: 1.33,
            awake_extra_mw: 1.73,
            predict_extra_mw: 0.33,
            train_extra_mw: 0.31,
        }
    }
}

impl PowerModel {
    /// Power draw in a state [mW].
    pub fn power_mw(&self, state: PowerState) -> f64 {
        match state {
            PowerState::Sleep => self.mem_retention_mw,
            PowerState::Idle => self.mem_retention_mw + self.awake_extra_mw,
            PowerState::Predict => {
                self.mem_retention_mw + self.awake_extra_mw + self.predict_extra_mw
            }
            PowerState::Train => {
                self.mem_retention_mw + self.awake_extra_mw + self.train_extra_mw
            }
        }
    }

    /// Energy for `secs` in a state [mJ].
    pub fn energy_mj(&self, state: PowerState, secs: f64) -> f64 {
        self.power_mw(state) * secs
    }

    /// Computation energy of one training-mode event [mJ]: predict, then
    /// (if the query was made) a sequential train step, then sleep for the
    /// remainder of the event period. §3.3: "the logic part is stateless
    /// and can be powered off when it is not used".
    pub fn event_energy_mj(&self, cycles: &CycleModel, period_s: f64, trained: bool) -> f64 {
        let t_pred = cycles.predict_time_s();
        let t_train = if trained { cycles.train_time_s() } else { 0.0 };
        let active = t_pred + t_train;
        debug_assert!(active <= period_s, "event longer than its period");
        self.energy_mj(PowerState::Predict, t_pred)
            + self.energy_mj(PowerState::Train, t_train)
            + self.energy_mj(PowerState::Sleep, (period_s - active).max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_power_exact() {
        let p = PowerModel::default();
        assert!((p.power_mw(PowerState::Predict) - 3.39).abs() < 1e-9);
        assert!((p.power_mw(PowerState::Train) - 3.37).abs() < 1e-9);
        assert!((p.power_mw(PowerState::Idle) - 3.06).abs() < 1e-9);
        assert!((p.power_mw(PowerState::Sleep) - 1.33).abs() < 1e-9);
    }

    #[test]
    fn state_ordering() {
        let p = PowerModel::default();
        assert!(p.power_mw(PowerState::Predict) > p.power_mw(PowerState::Train));
        assert!(p.power_mw(PowerState::Train) > p.power_mw(PowerState::Idle));
        assert!(p.power_mw(PowerState::Idle) > p.power_mw(PowerState::Sleep));
    }

    #[test]
    fn event_energy_composition() {
        let p = PowerModel::default();
        let c = CycleModel::prototype();
        let with_train = p.event_energy_mj(&c, 1.0, true);
        let without = p.event_energy_mj(&c, 1.0, false);
        // training adds (P_train − P_sleep)·t_train
        let expect_delta = (3.37 - 1.33) * c.train_time_s();
        assert!(
            ((with_train - without) - expect_delta).abs() < 1e-9,
            "delta {}",
            with_train - without
        );
        // a skipped event (predict + sleep) is ≈ sleep-dominated at 1 Hz
        assert!(without < 1.5 * p.energy_mj(PowerState::Sleep, 1.0));
    }

    #[test]
    fn longer_period_costs_more_sleep_energy_but_less_average_power() {
        let p = PowerModel::default();
        let c = CycleModel::prototype();
        let e1 = p.event_energy_mj(&c, 1.0, true);
        let e10 = p.event_energy_mj(&c, 10.0, true);
        assert!(e10 > e1);
        assert!(e10 / 10.0 < e1 / 1.0, "average power must drop with period");
    }
}
