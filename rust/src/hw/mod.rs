//! Hardware co-design models of the paper's 45 nm ODL core (§2.3, §3.3).
//!
//! The paper's evaluation consumes four hardware quantities; each has a
//! model here, calibrated against the published numbers and asserted by
//! tests:
//!
//! | Model | Paper source | Calibration |
//! |---|---|---|
//! | [`memory`] | Table 1 (SRAM size vs N) | **exact** on all 15 cells |
//! | [`cycles`] | Table 4 (36.40 ms predict / 171.28 ms train @ 10 MHz) | exact at the prototype point, scales with (n, N, m) |
//! | [`power`]  | Table 4 (3.39 / 3.37 / 3.06 / 1.33 mW) | exact at the four states |
//! | [`ble`]    | §3.3 nRF52840, 1 Mbps, 0 dBm, 3.0 V + Fig 4 reductions | per-transaction energy fit to Fig 4's auto-θ reductions |
//! | [`area`]   | Fig 5 (2.25 × 2.25 mm, 17 × 8 kB SRAM macros) | macro count exact, area split plausible for 45 nm |

pub mod area;
pub mod ble;
pub mod cycles;
pub mod memory;
pub mod power;

pub use ble::BleModel;
pub use cycles::CycleModel;
pub use memory::{memory_bytes, sram_macros, CoreVariant, MemoryBreakdown};
pub use power::{PowerModel, PowerState};
