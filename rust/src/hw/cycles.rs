//! Cycle-level model of the ODL core's state machine (§2.3: "multiply-add
//! and division units controlled by a state machine").
//!
//! The schedule walks the same operation sequence as the fixed-point
//! golden model ([`crate::odl::fixed_oselm`]):
//!
//! **Predict**: hidden MAC loop (n·N MACs, Xorshift fused), sigmoid per
//! hidden node, output MAC loop (N·m), argmax/top-2 sweep.
//!
//! **Sequential train**: the predict datapath (H and error need it), then
//! `Ph = P·h` (N² MACs), `hᵀPh` (N MACs), and the rank-1 update of P and β
//! — per element a multiply + **division** + read-modify-write. The
//! divider is iterative (64 cycles for 32-bit fixed point) and, per the
//! calibration below, the prototype divides *per element* rather than
//! hoisting `Ph_i/denom` per row — exactly what the published 171.28 ms
//! implies (hoisted division would cut training time ≈ 9×; see
//! `bench_table4_core --ablate-divider`).
//!
//! Calibration (n = 561, N = 128, m = 6 at 10 MHz):
//! * predict: 364 000 cycles = **36.40 ms** (Table 4, exact)
//! * train: 1 712 800 cycles = **171.28 ms** (Table 4, exact)

/// Per-operation cycle costs (defaults calibrated to Table 4).
#[derive(Clone, Copy, Debug)]
pub struct CycleCosts {
    /// One MAC including SRAM operand fetch (and PRNG step for ODLHash).
    pub mac: u64,
    /// Sigmoid evaluation per hidden node (PLAN piecewise circuit).
    pub sigmoid: u64,
    /// Per-element rank-1 update: multiply + iterative divide + RMW.
    pub update_elem: u64,
    /// Per-row overhead in the update sweep (address gen, Ph_i fetch).
    pub update_row: u64,
    /// Fixed predict-path overhead (mode switch, argmax sweep).
    pub predict_fixed: u64,
    /// Fixed train-path overhead.
    pub train_fixed: u64,
}

impl Default for CycleCosts {
    fn default() -> Self {
        Self {
            mac: 5,
            sigmoid: 8,
            update_elem: 73, // 64-cycle divider + multiply + RMW
            update_row: 111,
            predict_fixed: 96,
            train_fixed: 32,
        }
    }
}

impl CycleCosts {
    /// Divider-hoisted variant (one division per row, multiply by the
    /// reciprocal inside) — the optimization the Pallas kernel performs;
    /// used by the Table-4 ablation bench.
    pub fn hoisted_divider() -> Self {
        Self {
            update_elem: 9, // multiply + RMW only
            ..Self::default()
        }
    }
}

/// The cycle model for a core configuration.
#[derive(Clone, Copy, Debug)]
pub struct CycleModel {
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_out: usize,
    pub freq_hz: f64,
    pub costs: CycleCosts,
}

impl CycleModel {
    /// Paper prototype: 561/128/6 at 10 MHz.
    pub fn prototype() -> Self {
        Self {
            n_in: 561,
            n_hidden: 128,
            n_out: 6,
            freq_hz: 10e6,
            costs: CycleCosts::default(),
        }
    }

    pub fn with_dims(mut self, n_in: usize, n_hidden: usize, n_out: usize) -> Self {
        self.n_in = n_in;
        self.n_hidden = n_hidden;
        self.n_out = n_out;
        self
    }

    /// Cycles for one prediction.
    pub fn predict_cycles(&self) -> u64 {
        let (n, nh, m) = (self.n_in as u64, self.n_hidden as u64, self.n_out as u64);
        let c = &self.costs;
        c.mac * n * nh            // hidden layer MACs (α regenerated in-line)
            + c.sigmoid * nh      // G1
            + c.mac * nh * m      // output layer MACs
            + c.predict_fixed // argmax/top-2 + control
    }

    /// Cycles for one sequential training step (includes the forward pass).
    pub fn train_cycles(&self) -> u64 {
        let (n, nh, m) = (self.n_in as u64, self.n_hidden as u64, self.n_out as u64);
        let c = &self.costs;
        let forward = c.mac * n * nh + c.sigmoid * nh; // H
        let ph = c.mac * nh * nh; // Ph = P·h
        let hph = c.mac * nh; // denom = 1 + hᵀPh
        let err = c.mac * nh * m; // e = y − hᵀβ
        let rank1 = c.update_elem * (nh * nh + nh * m) // P and β sweeps
            + c.update_row * nh;
        forward + ph + hph + err + rank1 + c.train_fixed
    }

    pub fn predict_time_s(&self) -> f64 {
        self.predict_cycles() as f64 / self.freq_hz
    }

    pub fn train_time_s(&self) -> f64 {
        self.train_cycles() as f64 / self.freq_hz
    }

    /// Can the core sustain one (sense → predict → train) event per
    /// `period_s`? (§3.3: 171 ms ≪ 1 s ⇒ per-second operation is fine.)
    pub fn sustains_event_period(&self, period_s: f64) -> bool {
        self.predict_time_s() + self.train_time_s() < period_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_predict_exact() {
        let m = CycleModel::prototype();
        assert_eq!(m.predict_cycles(), 364_000);
        assert!((m.predict_time_s() - 0.03640).abs() < 1e-9);
    }

    #[test]
    fn table4_train_exact() {
        let m = CycleModel::prototype();
        assert_eq!(m.train_cycles(), 1_712_800);
        assert!((m.train_time_s() - 0.17128).abs() < 1e-9);
    }

    #[test]
    fn per_second_operation_feasible() {
        // §3.3: "171 msec … fast enough for a per-second operation"
        assert!(CycleModel::prototype().sustains_event_period(1.0));
    }

    #[test]
    fn scales_quadratically_in_hidden() {
        let small = CycleModel::prototype().with_dims(561, 128, 6);
        let big = CycleModel::prototype().with_dims(561, 256, 6);
        let ratio = big.train_cycles() as f64 / small.train_cycles() as f64;
        // train is dominated by N² terms → ratio between 2× and 4×
        assert!(ratio > 2.0 && ratio < 4.0, "ratio {ratio}");
    }

    #[test]
    fn hoisted_divider_cuts_train_time() {
        let base = CycleModel::prototype();
        let hoisted = CycleModel {
            costs: CycleCosts::hoisted_divider(),
            ..base
        };
        let speedup = base.train_cycles() as f64 / hoisted.train_cycles() as f64;
        assert!(
            speedup > 2.5,
            "hoisting the divider must help a lot: {speedup}"
        );
        // …but prediction is untouched
        assert_eq!(base.predict_cycles(), hoisted.predict_cycles());
    }

    #[test]
    fn n256_still_sub_second() {
        // The paper's "N=256 saturates accuracy" variant must still run at
        // 1 Hz on the same clock for the comparison to be fair.
        let m = CycleModel::prototype().with_dims(561, 256, 6);
        assert!(m.sustains_event_period(1.0), "train {}", m.train_time_s());
    }
}
