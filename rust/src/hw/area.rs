//! Floorplan proxy for Figure 5 — the 2.25 mm × 2.25 mm 45 nm core layout.
//!
//! The paper reports the die edge and "17 8 kB SRAM cells". We model the
//! area split with typical Nangate-45 numbers: an 8 kB single-port SRAM
//! macro ≈ 0.145 mm² (bitcell ≈ 0.9 µm² plus periphery), with the rest
//! logic (MAC + divider + FSM) and routing/IO margin.

use super::memory::{memory_bytes, sram_macros, CoreVariant};

/// Area report for a core configuration.
#[derive(Clone, Copy, Debug)]
pub struct AreaReport {
    pub die_w_mm: f64,
    pub die_h_mm: f64,
    pub n_sram_macros: usize,
    pub sram_area_mm2: f64,
    pub logic_area_mm2: f64,
}

/// Per-macro area for an 8 kB SRAM in 45 nm [mm²].
pub const SRAM_MACRO_MM2: f64 = 0.145;
/// Datapath + FSM logic area estimate [mm²] (MAC, 64-cycle divider, PRNG,
/// control — a few tens of kGE at ~1 kGE/0.0005 mm²).
pub const LOGIC_MM2: f64 = 0.35;
/// Placement/routing utilization (fraction of die actually occupied).
pub const UTILIZATION: f64 = 0.65;

impl AreaReport {
    /// Prototype report (ODLHash, n = 561, N = 128, m = 6).
    pub fn prototype() -> AreaReport {
        Self::for_config(CoreVariant::OdlHash, 561, 128, 6)
    }

    pub fn for_config(variant: CoreVariant, n: usize, n_hidden: usize, m: usize) -> AreaReport {
        let bytes = memory_bytes(variant, n, n_hidden, m);
        let macros = sram_macros(bytes);
        let sram = macros as f64 * SRAM_MACRO_MM2;
        let occupied = sram + LOGIC_MM2;
        let die = (occupied / UTILIZATION).sqrt();
        AreaReport {
            die_w_mm: die,
            die_h_mm: die,
            n_sram_macros: macros,
            sram_area_mm2: sram,
            logic_area_mm2: LOGIC_MM2,
        }
    }

    pub fn die_area_mm2(&self) -> f64 {
        self.die_w_mm * self.die_h_mm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototype_matches_fig5() {
        let a = AreaReport::prototype();
        assert_eq!(a.n_sram_macros, 17, "Fig 5: 17 8kB macros");
        // paper: 2.25 mm × 2.25 mm = 5.06 mm²; our utilization-based
        // estimate must land in the same regime (±25 %)
        let die = a.die_area_mm2();
        assert!(
            (die - 5.0625).abs() / 5.0625 < 0.25,
            "die estimate {die:.2} mm² vs paper 5.06 mm²"
        );
    }

    #[test]
    fn sram_dominates_prototype() {
        let a = AreaReport::prototype();
        assert!(a.sram_area_mm2 > a.logic_area_mm2 * 3.0);
    }

    #[test]
    fn bigger_n_needs_bigger_die() {
        let small = AreaReport::for_config(CoreVariant::OdlHash, 561, 128, 6);
        let big = AreaReport::for_config(CoreVariant::OdlHash, 561, 256, 6);
        assert!(big.die_area_mm2() > small.die_area_mm2() * 2.0);
    }
}
