//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the boundary of the three-layer architecture: everything below
//! this module is XLA-compiled code authored in JAX/Pallas at build time;
//! everything above is the rust coordinator. Python never runs at request
//! time — the HLO text is compiled here, once per artifact, and cached.
//!
//! Interchange is HLO **text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

//! The XLA-bound half of this module (executable loading/compilation and
//! the [`PjrtOsElm`] backend) requires the external `xla` crate, which is
//! not in the offline vendor set — it is gated behind the `pjrt` cargo
//! feature. Without the feature, [`stub`] provides the same API surface
//! with every entry point returning a descriptive error, so callers that
//! probe for `artifacts/manifest.json` before opening the runtime (all
//! benches/tests do) degrade to a clean skip.

#[cfg(feature = "pjrt")]
pub mod backend;
#[cfg(not(feature = "pjrt"))]
mod stub;

#[cfg(feature = "pjrt")]
pub use backend::PjrtOsElm;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Exe, PjrtOsElm, Runtime};

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json` entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: String,
    pub variant: String,
    pub n_hidden: Option<usize>,
    pub batch: Option<usize>,
    pub k0: Option<usize>,
    pub arg_shapes: Vec<Vec<usize>>,
    pub arg_dtypes: Vec<String>,
}

/// The artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub n_in: usize,
    pub n_out: usize,
    pub artifacts: HashMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let format = json
            .get("format")
            .and_then(|f| f.as_str())
            .unwrap_or_default();
        if format != "hlo-text" {
            bail!("unsupported artifact format '{format}'");
        }
        let mut artifacts = HashMap::new();
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        for (name, meta) in arts {
            let get_usize = |k: &str| meta.get(k).and_then(|v| v.as_usize());
            let arg_shapes = meta
                .get("arg_shapes")
                .and_then(|v| v.as_arr())
                .map(|rows| {
                    rows.iter()
                        .map(|r| {
                            r.as_arr()
                                .unwrap_or(&[])
                                .iter()
                                .filter_map(|d| d.as_usize())
                                .collect()
                        })
                        .collect()
                })
                .unwrap_or_default();
            let arg_dtypes = meta
                .get("arg_dtypes")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|d| d.as_str().map(|s| s.to_string()))
                        .collect()
                })
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    path: meta
                        .get("path")
                        .and_then(|p| p.as_str())
                        .ok_or_else(|| anyhow!("artifact {name} missing path"))?
                        .to_string(),
                    variant: meta
                        .get("variant")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                    n_hidden: get_usize("n_hidden"),
                    batch: get_usize("batch"),
                    k0: get_usize("k0"),
                    arg_shapes,
                    arg_dtypes,
                },
            );
        }
        Ok(Manifest {
            n_in: json
                .get("n_in")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest missing n_in"))?,
            n_out: json
                .get("n_out")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest missing n_out"))?,
            artifacts,
        })
    }
}

/// A compiled artifact, ready to execute.
#[cfg(feature = "pjrt")]
pub struct Exe {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Exe {
    /// Execute returning raw device buffers (one entry per device, then
    /// per output) — the zero-copy path for device-resident state.
    pub fn execute_raw(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        Ok(self.exe.execute::<xla::Literal>(inputs)?)
    }

    /// Execute with device-buffer inputs (state stays on device).
    pub fn execute_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        Ok(self.exe.execute_b(inputs)?)
    }

    /// Execute with positional literal inputs; returns the flattened tuple
    /// outputs (aot.py lowers everything with `return_tuple=True`).
    pub fn call(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact {}", self.meta.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(out.to_tuple().context("untupling result")?)
    }
}

/// The PJRT runtime: CPU client + compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<Exe>>>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open the artifact directory (default `artifacts/`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::util::logging::info(
            "runtime",
            &format!(
                "PJRT runtime: platform={} devices={} artifacts={}",
                client.platform_name(),
                client.device_count(),
                manifest.artifacts.len()
            ),
        );
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: Default::default(),
        })
    }

    /// Open `./artifacts` relative to the repo root.
    pub fn open_default() -> Result<Runtime> {
        Self::open(default_artifact_dir())
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn load(&self, name: &str) -> Result<std::rc::Rc<Exe>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| {
                let mut names: Vec<&String> = self.manifest.artifacts.keys().collect();
                names.sort();
                anyhow!("unknown artifact '{name}' (have: {names:?})")
            })?
            .clone();
        let path = self.dir.join(&meta.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let exe = std::rc::Rc::new(Exe { meta, exe });
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

/// `<repo>/artifacts` (works from the crate root and from target/ binaries).
pub fn default_artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

// --- literal helpers ---------------------------------------------------------

/// f32 literal with the given dimensions.
#[cfg(feature = "pjrt")]
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape/product mismatch");
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// One-element u32 literal (seed plumbing; scalars travel as shape-(1,)).
#[cfg(feature = "pjrt")]
pub fn lit_u32_vec1(v: u32) -> xla::Literal {
    xla::Literal::vec1(&[v])
}

/// Extract an f32 vector from a literal.
#[cfg(feature = "pjrt")]
pub fn lit_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = default_artifact_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn manifest_parses() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n_in, 561);
        assert_eq!(m.n_out, 6);
        assert!(m.artifacts.contains_key("train_step_hash_n128"));
        let meta = &m.artifacts["train_step_hash_n128"];
        assert_eq!(meta.n_hidden, Some(128));
        assert_eq!(meta.arg_shapes[2], vec![128, 128]);
        assert_eq!(meta.arg_dtypes.last().map(|s| s.as_str()), Some("uint32"));
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::open(dir).unwrap();
        assert!(rt.load("no_such_artifact").is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn lit_f32_shape_checked() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit_to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
