//! API-compatible stand-in for the XLA-bound runtime, compiled when the
//! `pjrt` cargo feature is off (the `xla` crate is not in the offline
//! vendor set — see `Cargo.toml`).
//!
//! Every entry point returns the same descriptive error. Callers across
//! the repo probe for `artifacts/manifest.json` before opening the
//! runtime, so in practice these paths are never reached in a default
//! build; the stub exists so `main.rs`, the benches, and the integration
//! tests compile (and skip) without the feature.

use super::{ArtifactMeta, Manifest};
use crate::odl::activation::Prediction;
use anyhow::{bail, Result};
use std::path::Path;
use std::rc::Rc;

const UNAVAILABLE: &str =
    "PJRT backend unavailable: odl_har was built without the `pjrt` feature \
     (the `xla` crate is not in the offline vendor set; see rust/Cargo.toml)";

/// Stub of a compiled artifact (never constructed).
pub struct Exe {
    pub meta: ArtifactMeta,
    _no_backend: (),
}

/// Stub runtime (never constructed; `open` always errors).
pub struct Runtime {
    pub manifest: Manifest,
    _no_backend: (),
}

impl Runtime {
    pub fn open(_dir: impl AsRef<Path>) -> Result<Runtime> {
        bail!(UNAVAILABLE);
    }

    pub fn open_default() -> Result<Runtime> {
        Self::open(super::default_artifact_dir())
    }

    pub fn load(&self, _name: &str) -> Result<Rc<Exe>> {
        bail!(UNAVAILABLE);
    }
}

/// Stub of the PJRT-backed OS-ELM (never constructed; `new` always errors).
pub struct PjrtOsElm {
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_out: usize,
    pub seed: u32,
    pub beta: Vec<f32>,
    pub p: Vec<f32>,
    _no_backend: (),
}

impl PjrtOsElm {
    pub fn new(_rt: &Runtime, _n_hidden: usize, _seed: u32) -> Result<PjrtOsElm> {
        bail!(UNAVAILABLE);
    }

    pub fn init_batch(&mut self, _xs: &crate::linalg::Mat, _labels: &[usize]) -> Result<()> {
        bail!(UNAVAILABLE);
    }

    pub fn train_step(&mut self, _x: &[f32], _label: usize) -> Result<()> {
        bail!(UNAVAILABLE);
    }

    pub fn train_stream(&mut self, _xs: &crate::linalg::Mat, _labels: &[usize]) -> Result<()> {
        bail!(UNAVAILABLE);
    }

    pub fn predict(&self, _x: &[f32]) -> Result<Prediction> {
        bail!(UNAVAILABLE);
    }

    pub fn logits(&self, _x: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE);
    }

    pub fn accuracy(&self, _xs: &crate::linalg::Mat, _labels: &[usize]) -> Result<f64> {
        bail!(UNAVAILABLE);
    }

    pub fn load_state(&mut self, _beta: &[f32], _p: &[f32]) -> Result<()> {
        bail!(UNAVAILABLE);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_errors_descriptively() {
        let err = Runtime::open_default().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "error should name the feature: {err}");
    }
}
