//! An OS-ELM whose compute runs entirely through the PJRT artifacts — the
//! "full three-layer stack" twin of [`crate::odl::OsElm`].
//!
//! Model state (P, β) lives on the host between calls; every predict /
//! train step round-trips through the XLA executables compiled from the
//! JAX/Pallas graphs. Integration tests assert numeric agreement with the
//! native golden model; `examples/e2e_drift_pjrt.rs` runs the paper's
//! drift protocol end to end on this backend.

use super::{lit_f32, lit_to_f32, lit_u32_vec1, Exe, Runtime};
use crate::odl::activation::Prediction;
use anyhow::{ensure, Context, Result};
use std::rc::Rc;

/// PJRT-backed ODLHash OS-ELM.
pub struct PjrtOsElm {
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_out: usize,
    pub seed: u32,
    /// β (N × m) row-major.
    pub beta: Vec<f32>,
    /// P (N × N) row-major.
    pub p: Vec<f32>,
    eval_batch: usize,
    init_k0: usize,
    stream_k: usize,
    exe_train: Rc<Exe>,
    exe_train_stream: Rc<Exe>,
    exe_predict_one: Rc<Exe>,
    exe_predict_batch: Rc<Exe>,
    exe_init: Rc<Exe>,
}

impl PjrtOsElm {
    /// Bind the artifacts for hidden size `n_hidden` (must exist in the
    /// manifest: aot.py lowers N ∈ {128, 256}).
    pub fn new(rt: &Runtime, n_hidden: usize, seed: u32) -> Result<PjrtOsElm> {
        let exe_train = rt.load(&format!("train_step_hash_n{n_hidden}"))?;
        let exe_train_stream = rt.load(&format!("train_stream_hash_n{n_hidden}"))?;
        let exe_predict_one = rt.load(&format!("predict_one_hash_n{n_hidden}"))?;
        let exe_predict_batch = rt.load(&format!("predict_batch_hash_n{n_hidden}"))?;
        let exe_init = rt.load(&format!("init_batch_hash_n{n_hidden}"))?;
        let eval_batch = exe_predict_batch
            .meta
            .batch
            .context("predict_batch artifact missing batch size")?;
        let init_k0 = exe_init.meta.k0.context("init artifact missing k0")?;
        let stream_k = exe_train_stream.meta.arg_shapes[0][0];
        let (n_in, n_out) = (rt.manifest.n_in, rt.manifest.n_out);
        Ok(PjrtOsElm {
            n_in,
            n_hidden,
            n_out,
            seed,
            beta: vec![0.0; n_hidden * n_out],
            p: vec![0.0; n_hidden * n_hidden],
            eval_batch,
            init_k0,
            stream_k,
            exe_train,
            exe_train_stream,
            exe_predict_one,
            exe_predict_batch,
            exe_init,
        })
    }

    /// Batch-initialize on exactly `k0` samples (the artifact's static
    /// shape; callers provide ≥ k0 and we take the first k0).
    pub fn init_batch(&mut self, xs: &crate::linalg::Mat, labels: &[usize]) -> Result<()> {
        ensure!(xs.cols == self.n_in, "feature dim mismatch");
        ensure!(
            xs.rows >= self.init_k0,
            "PJRT init needs ≥ {} samples, got {}",
            self.init_k0,
            xs.rows
        );
        let k0 = self.init_k0;
        let x0 = &xs.data[..k0 * self.n_in];
        let mut y0 = vec![0.0f32; k0 * self.n_out];
        for (r, &lbl) in labels.iter().take(k0).enumerate() {
            ensure!(lbl < self.n_out, "label out of range");
            y0[r * self.n_out + lbl] = 1.0;
        }
        let out = self.exe_init.call(&[
            lit_f32(x0, &[k0, self.n_in])?,
            lit_f32(&y0, &[k0, self.n_out])?,
            lit_u32_vec1(self.seed),
        ])?;
        self.p = lit_to_f32(&out[0])?;
        self.beta = lit_to_f32(&out[1])?;
        Ok(())
    }

    /// One sequential training step through the `train_step_hash` artifact.
    pub fn train_step(&mut self, x: &[f32], label: usize) -> Result<()> {
        ensure!(x.len() == self.n_in, "feature dim mismatch");
        ensure!(label < self.n_out, "label out of range");
        let mut y = vec![0.0f32; self.n_out];
        y[label] = 1.0;
        let out = self.exe_train.call(&[
            lit_f32(x, &[1, self.n_in])?,
            lit_f32(&y, &[self.n_out])?,
            lit_f32(&self.p, &[self.n_hidden, self.n_hidden])?,
            lit_f32(&self.beta, &[self.n_hidden, self.n_out])?,
            lit_u32_vec1(self.seed),
        ])?;
        self.p = lit_to_f32(&out[0])?;
        self.beta = lit_to_f32(&out[1])?;
        Ok(())
    }

    /// Streaming training: sequential updates over all rows of `xs`,
    /// executed in scan-fused chunks of `stream_k` (one XLA launch per
    /// chunk — the §Perf L2 optimization) with a per-sample tail.
    pub fn train_stream(&mut self, xs: &crate::linalg::Mat, labels: &[usize]) -> Result<()> {
        ensure!(xs.rows == labels.len(), "label count mismatch");
        ensure!(xs.cols == self.n_in, "feature dim mismatch");
        let k = self.stream_k;
        let mut row = 0usize;
        let mut ys = vec![0.0f32; k * self.n_out];
        while row + k <= xs.rows {
            ys.fill(0.0);
            for (i, &lbl) in labels[row..row + k].iter().enumerate() {
                ensure!(lbl < self.n_out, "label out of range");
                ys[i * self.n_out + lbl] = 1.0;
            }
            let out = self.exe_train_stream.call(&[
                lit_f32(&xs.data[row * self.n_in..(row + k) * self.n_in], &[k, self.n_in])?,
                lit_f32(&ys, &[k, self.n_out])?,
                lit_f32(&self.p, &[self.n_hidden, self.n_hidden])?,
                lit_f32(&self.beta, &[self.n_hidden, self.n_out])?,
                lit_u32_vec1(self.seed),
            ])?;
            self.p = lit_to_f32(&out[0])?;
            self.beta = lit_to_f32(&out[1])?;
            row += k;
        }
        for r in row..xs.rows {
            self.train_step(xs.row(r), labels[r])?;
        }
        Ok(())
    }

    /// Predict one sample.
    pub fn predict(&self, x: &[f32]) -> Result<Prediction> {
        ensure!(x.len() == self.n_in, "feature dim mismatch");
        let out = self.exe_predict_one.call(&[
            lit_f32(x, &[1, self.n_in])?,
            lit_f32(&self.beta, &[self.n_hidden, self.n_out])?,
            lit_u32_vec1(self.seed),
        ])?;
        let logits = lit_to_f32(&out[0])?;
        Ok(Prediction::from_logits(&logits))
    }

    /// Raw logits for one sample (tests).
    pub fn logits(&self, x: &[f32]) -> Result<Vec<f32>> {
        let out = self.exe_predict_one.call(&[
            lit_f32(x, &[1, self.n_in])?,
            lit_f32(&self.beta, &[self.n_hidden, self.n_out])?,
            lit_u32_vec1(self.seed),
        ])?;
        lit_to_f32(&out[0])
    }

    /// Batched accuracy over a labelled set (pads the tail batch).
    pub fn accuracy(&self, xs: &crate::linalg::Mat, labels: &[usize]) -> Result<f64> {
        ensure!(xs.rows == labels.len(), "label count mismatch");
        if xs.rows == 0 {
            return Ok(0.0);
        }
        let b = self.eval_batch;
        let mut correct = 0usize;
        let mut row = 0usize;
        let mut padded = vec![0.0f32; b * self.n_in];
        while row < xs.rows {
            let take = b.min(xs.rows - row);
            padded[..take * self.n_in]
                .copy_from_slice(&xs.data[row * self.n_in..(row + take) * self.n_in]);
            padded[take * self.n_in..].fill(0.0);
            let out = self.exe_predict_batch.call(&[
                lit_f32(&padded, &[b, self.n_in])?,
                lit_f32(&self.beta, &[self.n_hidden, self.n_out])?,
                lit_u32_vec1(self.seed),
            ])?;
            let logits = lit_to_f32(&out[0])?;
            for i in 0..take {
                let l = &logits[i * self.n_out..(i + 1) * self.n_out];
                if crate::util::stats::argmax(l) == labels[row + i] {
                    correct += 1;
                }
            }
            row += take;
        }
        Ok(correct as f64 / xs.rows as f64)
    }

    /// Copy state from (or compare against) the native golden model.
    pub fn load_state(&mut self, beta: &[f32], p: &[f32]) -> Result<()> {
        ensure!(beta.len() == self.beta.len() && p.len() == self.p.len());
        self.beta.copy_from_slice(beta);
        self.p.copy_from_slice(p);
        Ok(())
    }
}
