//! Automatic data pruning (§2.2) — the paper's main system contribution.
//!
//! During the training mode, an edge device may *skip* the teacher query
//! (and the sequential train step) when all three conditions hold:
//!
//! 1. a pre-specified number of samples has been trained (warmup,
//!    `max(N, 288)` in the paper's experiments),
//! 2. data drift is not currently detected,
//! 3. the confidence of the locally predicted label is high:
//!    `p1 − p2 > θ` ("P1P2" metric).
//!
//! θ is auto-tuned at runtime ([`AutoTheta`]): start high, decrease after
//! `X` consecutive successes (a skip, or a query whose local prediction
//! matched the teacher), increase on a query that reveals a mismatch.
//! The paper's broad ladder is {1, 0.64, 0.32, 0.16, 0.08} with X = 10.

use crate::odl::activation::Prediction;

/// Paper's auto-tuning ladder (θ values, high → low).
pub const THETA_LADDER: [f32; 5] = [1.0, 0.64, 0.32, 0.16, 0.08];
/// Paper's conservative consecutive-success requirement.
pub const DEFAULT_X: u32 = 10;
/// Paper's warmup rule: max(N, 288) samples before pruning engages.
pub fn warmup_for(n_hidden: usize) -> usize {
    n_hidden.max(288)
}

/// Confidence metric: the paper's P1P2, plus the Error-L2-Norm alternative
/// it mentions (comparisons "omitted due to page limitation" — included
/// here as an ablation — `odl-har fig3 --metric el2n` and the EL2N sweep in `bench_fig3_pruning`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// p1 − p2 (paper's default).
    P1P2,
    /// 1 − ‖softmax(o) − onehot(c)‖₂/√2 ∈ [0, 1]: EL2N (Paul et al. 2021)
    /// against the locally predicted class, folded so that *high = confident*
    /// and the same θ ladder applies.
    ErrorL2,
}

impl Metric {
    /// Confidence score in [0, 1] from a prediction.
    pub fn confidence(&self, pred: &Prediction) -> f32 {
        match self {
            Metric::P1P2 => pred.p1 - pred.p2,
            Metric::ErrorL2 => {
                // ‖p − e_c‖₂² = (1−p1)² + Σ_{j≠c} p_j².  We only carry the
                // top-2 probabilities; bound the tail by assigning the
                // remaining mass (1−p1−p2) to one pseudo-class — exact for
                // m = 3, a tight lower bound for m > 3 (monotone in p1, p2,
                // which is all thresholding needs).
                let rest = (1.0 - pred.p1 - pred.p2).max(0.0);
                let el2n =
                    ((1.0 - pred.p1).powi(2) + pred.p2.powi(2) + rest.powi(2)).sqrt();
                1.0 - el2n / std::f32::consts::SQRT_2
            }
        }
    }

    /// Confidence from the *full* output vector — the exact EL2N instead
    /// of the top-2 bound. Callers pass the model's workspace logits by
    /// borrow (`OsElm::last_logits`), so the per-event cost is m clamped
    /// multiply-adds and zero allocation. For `P1P2` this is identical to
    /// [`Self::confidence`].
    pub fn confidence_from_logits(&self, pred: &Prediction, logits: &[f32]) -> f32 {
        match self {
            Metric::P1P2 => self.confidence(pred),
            Metric::ErrorL2 => {
                let mut sum = 0.0f32;
                for (j, &o) in logits.iter().enumerate() {
                    // host comparator clamps like the P1P2 path
                    let p = o.clamp(0.0, 1.0);
                    let t = if j == pred.class { 1.0 } else { 0.0 };
                    sum += (p - t) * (p - t);
                }
                1.0 - sum.sqrt() / std::f32::consts::SQRT_2
            }
        }
    }
}

/// θ selection policy.
#[derive(Clone, Debug)]
pub enum ThetaPolicy {
    /// Fixed θ (Figure 3's sweep). θ = 1 disables pruning (p1−p2 ≤ 1 always).
    Fixed(f32),
    /// The paper's auto-tuner.
    Auto(AutoTheta),
}

impl ThetaPolicy {
    pub fn auto() -> ThetaPolicy {
        ThetaPolicy::Auto(AutoTheta::new(DEFAULT_X))
    }

    pub fn theta(&self) -> f32 {
        match self {
            ThetaPolicy::Fixed(t) => *t,
            ThetaPolicy::Auto(a) => a.theta(),
        }
    }
}

/// The auto-θ ladder controller (§2.2's three tuning rules).
///
/// **Hysteresis adaptation** (documented in DESIGN.md §3): the paper's
/// rule 3 as written ascends on *every* mismatched query. A Markov-chain
/// argument shows the ladder then cannot settle whenever the stream error
/// rate ε satisfies ε > 1/E[wait for an X-streak] (≈ 1/19 for X = 10 and
/// ≈90 % stream accuracy) — ascents simply outpace descents and θ pins at
/// 1.0, which contradicts the paper's measured 55.7 % query reduction.
/// The minimal damping that restores the published behaviour is to require
/// `mismatch_hysteresis` (default 2) *consecutive* mismatched queries
/// before ascending; `with_hysteresis(1)` recovers the literal text.
#[derive(Clone, Debug)]
pub struct AutoTheta {
    /// Index into [`THETA_LADDER`] (0 = highest θ = most conservative).
    idx: usize,
    /// Consecutive-success counter.
    streak: u32,
    /// Successes required to decrease θ.
    x_required: u32,
    /// Consecutive mismatched queries required to increase θ.
    mismatch_hysteresis: u32,
    /// Current consecutive-mismatch counter.
    mismatch_streak: u32,
    /// Telemetry: number of decreases / increases performed.
    pub decreases: u32,
    pub increases: u32,
}

/// Default mismatch hysteresis (see [`AutoTheta`] docs).
pub const DEFAULT_HYSTERESIS: u32 = 2;

/// A plain-data snapshot of [`AutoTheta`]'s internal state — what the
/// serve coordinator persists per client across drain/restart (the ladder
/// must resume mid-streak for the restored trajectory to match an
/// uninterrupted one bit for bit).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AutoThetaState {
    pub idx: usize,
    pub streak: u32,
    pub x_required: u32,
    pub mismatch_hysteresis: u32,
    pub mismatch_streak: u32,
    pub decreases: u32,
    pub increases: u32,
}

impl AutoTheta {
    pub fn new(x_required: u32) -> Self {
        assert!(x_required > 0);
        Self {
            idx: 0,
            streak: 0,
            x_required,
            mismatch_hysteresis: DEFAULT_HYSTERESIS,
            mismatch_streak: 0,
            decreases: 0,
            increases: 0,
        }
    }

    /// Override the ascent damping; `1` = the paper's literal rule 3.
    pub fn with_hysteresis(mut self, m: u32) -> Self {
        assert!(m > 0);
        self.mismatch_hysteresis = m;
        self
    }

    pub fn theta(&self) -> f32 {
        THETA_LADDER[self.idx]
    }

    pub fn streak(&self) -> u32 {
        self.streak
    }

    /// Rule 2 success path: confident skip (`p1−p2 > θ`) or correct query
    /// (`c = t` when `p1−p2 ≤ θ`). After X consecutive successes, θ steps
    /// down the ladder.
    pub fn on_success(&mut self) {
        self.mismatch_streak = 0;
        self.streak += 1;
        if self.streak >= self.x_required {
            self.streak = 0;
            if self.idx + 1 < THETA_LADDER.len() {
                self.idx += 1;
                self.decreases += 1;
            }
        }
    }

    /// The complete ladder state, for crash-consistent serve snapshots.
    /// Round-trips exactly through [`Self::restore`].
    pub fn snapshot(&self) -> AutoThetaState {
        AutoThetaState {
            idx: self.idx,
            streak: self.streak,
            x_required: self.x_required,
            mismatch_hysteresis: self.mismatch_hysteresis,
            mismatch_streak: self.mismatch_streak,
            decreases: self.decreases,
            increases: self.increases,
        }
    }

    /// Rebuild a ladder mid-run from a [`Self::snapshot`]; the restored
    /// policy continues exactly where the original left off.
    pub fn restore(s: AutoThetaState) -> Self {
        assert!(s.idx < THETA_LADDER.len(), "snapshot ladder index {} out of range", s.idx);
        assert!(s.x_required > 0 && s.mismatch_hysteresis > 0);
        Self {
            idx: s.idx,
            streak: s.streak,
            x_required: s.x_required,
            mismatch_hysteresis: s.mismatch_hysteresis,
            mismatch_streak: s.mismatch_streak,
            decreases: s.decreases,
            increases: s.increases,
        }
    }

    /// Rule 3: a query revealed `c ≠ t` — step θ back up (after
    /// `mismatch_hysteresis` consecutive mismatches), reset the streak.
    pub fn on_mismatch(&mut self) {
        self.streak = 0;
        self.mismatch_streak += 1;
        if self.mismatch_streak >= self.mismatch_hysteresis {
            self.mismatch_streak = 0;
            if self.idx > 0 {
                self.idx -= 1;
                self.increases += 1;
            }
        }
    }
}

/// Outcome of one training-mode event under the pruning policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Query the teacher (and sequentially train).
    Query,
    /// Skip: confident enough, warmed up, no drift.
    Skip,
}

/// The full §2.2 gate. Stateless w.r.t. the model; state lives in the policy.
pub struct Pruner {
    pub policy: ThetaPolicy,
    pub metric: Metric,
    pub warmup: usize,
}

impl Pruner {
    pub fn new(policy: ThetaPolicy, metric: Metric, warmup: usize) -> Self {
        Self {
            policy,
            metric,
            warmup,
        }
    }

    /// No pruning at all (θ = 1 — the paper's "communication volume 100 %"
    /// reference configuration).
    pub fn disabled() -> Self {
        Self::new(ThetaPolicy::Fixed(1.0), Metric::P1P2, usize::MAX)
    }

    /// The §2.2 gate shared by both decide paths: query during warmup or
    /// while drift is flagged; otherwise skip iff confident beyond θ.
    fn gate(&self, confidence: f32, trained: usize, drift_now: bool) -> Decision {
        if trained < self.warmup || drift_now {
            return Decision::Query;
        }
        if confidence > self.policy.theta() {
            Decision::Skip
        } else {
            Decision::Query
        }
    }

    /// Decide for one sample. `trained` = sequential steps so far this
    /// training phase; `drift_now` = detector currently flags drift.
    pub fn decide(&self, pred: &Prediction, trained: usize, drift_now: bool) -> Decision {
        self.gate(self.metric.confidence(pred), trained, drift_now)
    }

    /// Like [`Self::decide`], but with the full output vector available
    /// (borrowed from the model workspace): the Error-L2 metric uses the
    /// exact EL2N rather than the top-2 bound. Identical to `decide` for
    /// P1P2.
    pub fn decide_with_logits(
        &self,
        pred: &Prediction,
        logits: &[f32],
        trained: usize,
        drift_now: bool,
    ) -> Decision {
        self.gate(
            self.metric.confidence_from_logits(pred, logits),
            trained,
            drift_now,
        )
    }

    /// Feed back the outcome (drives the auto-tuner; no-op for fixed θ).
    /// `decision` is what [`Self::decide`] returned; `matched` is
    /// `Some(c == t)` when a query was made, `None` on skip or when the
    /// teacher was unreachable.
    pub fn observe(&mut self, decision: Decision, matched: Option<bool>) {
        if let ThetaPolicy::Auto(auto) = &mut self.policy {
            match (decision, matched) {
                (Decision::Skip, _) => auto.on_success(),
                (Decision::Query, Some(true)) => auto.on_success(),
                (Decision::Query, Some(false)) => auto.on_mismatch(),
                // query attempted but teacher unreachable: no signal
                (Decision::Query, None) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pred(p1: f32, p2: f32) -> Prediction {
        Prediction { class: 0, p1, p2 }
    }

    #[test]
    fn ladder_descends_after_x_successes() {
        let mut a = AutoTheta::new(3);
        assert_eq!(a.theta(), 1.0);
        for _ in 0..3 {
            a.on_success();
        }
        assert_eq!(a.theta(), 0.64);
        for _ in 0..6 {
            a.on_success();
        }
        assert_eq!(a.theta(), 0.16);
    }

    #[test]
    fn ladder_clamps_at_bottom() {
        let mut a = AutoTheta::new(1);
        for _ in 0..100 {
            a.on_success();
        }
        assert_eq!(a.theta(), *THETA_LADDER.last().unwrap());
        assert_eq!(a.decreases, (THETA_LADDER.len() - 1) as u32);
    }

    #[test]
    fn mismatch_climbs_and_resets_streak() {
        let mut a = AutoTheta::new(2).with_hysteresis(1); // literal paper rule
        a.on_success();
        a.on_success(); // -> 0.64
        a.on_success(); // streak 1
        a.on_mismatch(); // back to 1.0, streak 0
        assert_eq!(a.theta(), 1.0);
        assert_eq!(a.streak(), 0);
        a.on_mismatch(); // clamped at top
        assert_eq!(a.theta(), 1.0);
        assert_eq!(a.increases, 1); // clamped increase not counted
    }

    #[test]
    fn hysteresis_requires_consecutive_mismatches() {
        let mut a = AutoTheta::new(1).with_hysteresis(2);
        for _ in 0..4 {
            a.on_success(); // descend to the bottom (X = 1)
        }
        let bottom = a.theta();
        a.on_mismatch(); // 1 of 2 — no ascent yet
        assert_eq!(a.theta(), bottom);
        a.on_success(); // resets the mismatch streak
        a.on_mismatch();
        assert_eq!(a.theta(), bottom, "non-consecutive mismatches must not ascend");
        a.on_mismatch(); // 2 consecutive → ascend
        assert!(a.theta() > bottom);
        assert_eq!(a.increases, 1);
    }

    #[test]
    fn streak_requires_consecutive() {
        let mut a = AutoTheta::new(3);
        a.on_success();
        a.on_success();
        a.on_mismatch(); // reset
        a.on_success();
        a.on_success();
        assert_eq!(a.theta(), 1.0, "2 non-consecutive successes must not trigger");
        a.on_success();
        assert_eq!(a.theta(), 0.64);
    }

    #[test]
    fn theta_one_never_skips() {
        // p1 − p2 ≤ 1 always, so Fixed(1.0) = no pruning.
        let p = Pruner::new(ThetaPolicy::Fixed(1.0), Metric::P1P2, 0);
        let d = p.decide(&pred(1.0, 0.0), 10_000, false);
        assert_eq!(d, Decision::Query);
    }

    #[test]
    fn warmup_blocks_skipping() {
        let p = Pruner::new(ThetaPolicy::Fixed(0.1), Metric::P1P2, 288);
        assert_eq!(p.decide(&pred(0.9, 0.01), 287, false), Decision::Query);
        assert_eq!(p.decide(&pred(0.9, 0.01), 288, false), Decision::Skip);
    }

    #[test]
    fn drift_blocks_skipping() {
        let p = Pruner::new(ThetaPolicy::Fixed(0.1), Metric::P1P2, 0);
        assert_eq!(p.decide(&pred(0.9, 0.01), 1000, true), Decision::Query);
    }

    #[test]
    fn confident_skips_unconfident_queries() {
        let p = Pruner::new(ThetaPolicy::Fixed(0.3), Metric::P1P2, 0);
        assert_eq!(p.decide(&pred(0.8, 0.1), 500, false), Decision::Skip);
        assert_eq!(p.decide(&pred(0.5, 0.4), 500, false), Decision::Query);
    }

    #[test]
    fn auto_theta_snapshot_roundtrips_mid_streak() {
        let mut a = AutoTheta::new(3).with_hysteresis(2);
        // land mid-streak and mid-mismatch-streak
        a.on_success();
        a.on_success();
        a.on_success(); // idx 1
        a.on_success();
        a.on_mismatch();
        let mut b = AutoTheta::restore(a.snapshot());
        assert_eq!(a.snapshot(), b.snapshot());
        // the two ladders must stay in lockstep through every rule
        for i in 0..32 {
            if i % 5 == 0 {
                a.on_mismatch();
                b.on_mismatch();
            } else {
                a.on_success();
                b.on_success();
            }
            assert_eq!(a.snapshot(), b.snapshot(), "diverged at step {i}");
            assert_eq!(a.theta(), b.theta());
        }
    }

    #[test]
    fn observe_drives_auto() {
        let mut p = Pruner::new(ThetaPolicy::auto(), Metric::P1P2, 0);
        assert_eq!(p.policy.theta(), 1.0);
        for _ in 0..DEFAULT_X {
            p.observe(Decision::Query, Some(true));
        }
        assert_eq!(p.policy.theta(), 0.64);
        p.observe(Decision::Query, Some(false));
        p.observe(Decision::Query, Some(false)); // default hysteresis = 2
        assert_eq!(p.policy.theta(), 1.0);
        // unreachable teacher is signal-free
        for _ in 0..100 {
            p.observe(Decision::Query, None);
        }
        assert_eq!(p.policy.theta(), 1.0);
    }

    #[test]
    fn el2n_metric_monotone_in_confidence() {
        let m = Metric::ErrorL2;
        let hi = m.confidence(&pred(0.98, 0.01));
        let mid = m.confidence(&pred(0.6, 0.3));
        let lo = m.confidence(&pred(0.4, 0.35));
        assert!(hi > mid && mid > lo, "{hi} {mid} {lo}");
        assert!((0.0..=1.0).contains(&hi));
    }

    #[test]
    fn warmup_rule_matches_paper() {
        assert_eq!(warmup_for(128), 288);
        assert_eq!(warmup_for(256), 288);
        assert_eq!(warmup_for(512), 512);
    }

    #[test]
    fn logits_metric_path_is_exact_el2n() {
        use crate::odl::activation::Prediction;
        // P1P2 ignores the logits entirely
        let logits = [0.7f32, 0.2, 0.05, 0.05];
        let pred = Prediction::from_logits(&logits);
        assert_eq!(
            Metric::P1P2.confidence_from_logits(&pred, &logits),
            Metric::P1P2.confidence(&pred)
        );
        // m = 3: the top-2 bound is exact, so both paths must agree
        let l3 = [0.9f32, 0.05, 0.05];
        let p3 = Prediction::from_logits(&l3);
        let exact = Metric::ErrorL2.confidence_from_logits(&p3, &l3);
        let bound = Metric::ErrorL2.confidence(&p3);
        assert!((exact - bound).abs() < 1e-6, "exact {exact} vs bound {bound}");
        // m > 3: spreading the tail mass can only shrink Σp², so the
        // exact confidence dominates the lower-bound one
        let l6 = [0.6f32, 0.1, 0.08, 0.08, 0.07, 0.07];
        let p6 = Prediction::from_logits(&l6);
        let exact6 = Metric::ErrorL2.confidence_from_logits(&p6, &l6);
        let bound6 = Metric::ErrorL2.confidence(&p6);
        assert!(exact6 >= bound6 - 1e-6, "exact {exact6} < bound {bound6}");
    }
}
