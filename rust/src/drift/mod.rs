//! Data-drift detection (Algorithm 1, line 3).
//!
//! The paper treats the detector as pluggable ("Existing data drift
//! detection algorithms [6] can be used") and, in the experiments, the
//! drift moment is defined by the protocol itself (the switch to the
//! held-out-subject stream). Accordingly:
//!
//! * [`OracleDetector`] — protocol-exact: drift is signalled externally
//!   (used by the Table-3 / Figure-3 harnesses, and by a fleet scenario
//!   script that flips the stream).
//! * [`CentroidDetector`] — a lightweight runnable detector in the spirit
//!   of Yamada et al. 2023 [6]: EWMA of the distance between incoming
//!   features and a running centroid of recent inputs; flags drift when
//!   the normalized distance exceeds a threshold for `patience`
//!   consecutive samples.
//! * [`ConfidenceDetector`] — model-aware alternative: EWMA of the P1P2
//!   confidence; drift when confidence collapses (used in ablations).

use crate::linalg::kernels;
use crate::odl::activation::Prediction;

/// Common interface: feed one observation per event, query the flag.
pub trait DriftDetector {
    /// Update with the current input features and the local prediction.
    fn observe(&mut self, x: &[f32], pred: Option<&Prediction>);
    /// Is drift currently detected?
    fn is_drift(&self) -> bool;
    /// Reset after retraining completes (mode switches back to predicting).
    fn reset(&mut self);
}

/// Externally scripted drift (protocol-exact for the paper's evaluation).
#[derive(Clone, Debug, Default)]
pub struct OracleDetector {
    flag: bool,
}

impl OracleDetector {
    pub fn new() -> Self {
        Self { flag: false }
    }

    /// Script hook: raise/clear the drift flag.
    pub fn set(&mut self, drift: bool) {
        self.flag = drift;
    }
}

impl DriftDetector for OracleDetector {
    fn observe(&mut self, _x: &[f32], _pred: Option<&Prediction>) {}

    fn is_drift(&self) -> bool {
        self.flag
    }

    fn reset(&mut self) {
        self.flag = false;
    }
}

/// Centroid-distance detector (lightweight, feature-space) — a
/// Page–Hinkley/CUSUM test on the sample-to-centroid distance.
///
/// Tracks the EWMA centroid of inputs plus the mean/variance of the
/// sample-to-centroid distance, standardizes each new distance to a
/// z-score, and accumulates `S ← max(0, S + z − k)`. Drift is flagged
/// when `S > h`. (Instantaneous thresholds are too blunt in high
/// dimension: a subject shift worth detecting moves the distance by only
/// ~1–2σ per sample — persistent, but never extreme; CUSUM integrates
/// exactly that kind of evidence, and is what the lightweight literature
/// [6] builds on.)
#[derive(Clone, Debug)]
pub struct CentroidDetector {
    /// Running centroid of inputs (slow EWMA).
    centroid: Vec<f32>,
    /// Running mean / variance of the distance (EWMA).
    mean_dist: f32,
    var_dist: f32,
    /// EWMA rates.
    alpha_centroid: f32,
    alpha_dist: f32,
    /// CUSUM drift allowance (z-units tolerated per sample).
    k: f32,
    /// CUSUM decision threshold.
    h: f32,
    /// Accumulated evidence S.
    cusum: f32,
    warmup_left: u32,
    flag: bool,
}

impl CentroidDetector {
    pub fn new(n_features: usize) -> Self {
        Self {
            centroid: vec![0.0; n_features],
            mean_dist: 0.0,
            var_dist: 0.0,
            alpha_centroid: 0.02,
            alpha_dist: 0.02,
            k: 0.75,
            h: 12.0,
            cusum: 0.0,
            warmup_left: 50,
            flag: false,
        }
    }

    /// Override the CUSUM allowance/threshold and warmup.
    pub fn with_params(mut self, k: f32, h: f32, warmup: u32) -> Self {
        self.k = k;
        self.h = h;
        self.warmup_left = warmup;
        self
    }

    fn distance(&self, x: &[f32]) -> f32 {
        // kernel-layer squared distance: one call per sensed sample per
        // edge (561-wide at full scale) — the detector's hot loop
        kernels::dist2(x, &self.centroid).sqrt()
    }

    fn track(&mut self, x: &[f32], d: f32, rate_boost: f32) {
        let ac = self.alpha_centroid * rate_boost;
        kernels::ewma(&mut self.centroid, x, ac);
        let ad = self.alpha_dist * rate_boost;
        let delta = d - self.mean_dist;
        self.mean_dist += ad * delta;
        self.var_dist += ad * (delta * delta - self.var_dist);
    }
}

impl DriftDetector for CentroidDetector {
    fn observe(&mut self, x: &[f32], _pred: Option<&Prediction>) {
        assert_eq!(x.len(), self.centroid.len());
        let d = self.distance(x);
        if self.warmup_left > 0 {
            // learn the in-distribution geometry first (faster rates)
            self.warmup_left -= 1;
            self.track(x, d, 8.0);
            return;
        }
        let std = self.var_dist.max(1e-12).sqrt();
        // clip: a single extreme sample is an outlier, not drift evidence
        let z = ((d - self.mean_dist) / std).clamp(-3.0, 3.0);
        self.cusum = (self.cusum + z - self.k).max(0.0);
        if self.cusum > self.h {
            self.flag = true;
        }
        // Track the reference distribution only while no evidence is
        // accumulating (otherwise the EWMA would absorb the drift before
        // CUSUM can fire). Tuned by Monte-Carlo (see DESIGN.md): FP ≈ 0
        // over 3 000 stationary samples, median delay ≈ 14 events for a
        // subject-shift-sized change.
        if self.cusum < 2.0 {
            self.track(x, d, 1.0);
        }
    }

    fn is_drift(&self) -> bool {
        self.flag
    }

    fn reset(&mut self) {
        self.flag = false;
        self.cusum = 0.0;
        // re-learn geometry of the (new) distribution quickly
        self.warmup_left = 50;
    }
}

/// Confidence-collapse detector (uses the model's own P1P2).
#[derive(Clone, Debug)]
pub struct ConfidenceDetector {
    ewma: f32,
    alpha: f32,
    threshold: f32,
    warmup_left: u32,
    flag: bool,
}

impl ConfidenceDetector {
    pub fn new(threshold: f32) -> Self {
        Self {
            ewma: 1.0,
            alpha: 0.05,
            threshold,
            warmup_left: 30,
            flag: false,
        }
    }
}

impl DriftDetector for ConfidenceDetector {
    fn observe(&mut self, _x: &[f32], pred: Option<&Prediction>) {
        if let Some(p) = pred {
            self.ewma += self.alpha * (p.confidence() - self.ewma);
            if self.warmup_left > 0 {
                self.warmup_left -= 1;
                return;
            }
            if self.ewma < self.threshold {
                self.flag = true;
            }
        }
    }

    fn is_drift(&self) -> bool {
        self.flag
    }

    fn reset(&mut self) {
        self.flag = false;
        self.ewma = 1.0;
        self.warmup_left = 30;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng64;

    #[test]
    fn oracle_is_scripted() {
        let mut d = OracleDetector::new();
        assert!(!d.is_drift());
        d.set(true);
        assert!(d.is_drift());
        d.reset();
        assert!(!d.is_drift());
    }

    #[test]
    fn centroid_detects_mean_shift() {
        let mut rng = Rng64::new(3);
        let mut det = CentroidDetector::new(8);
        // in-distribution: N(0, 1)
        for _ in 0..300 {
            let x: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            det.observe(&x, None);
        }
        assert!(!det.is_drift(), "false positive on stationary data");
        // drift: mean jumps to 4
        for _ in 0..20 {
            let x: Vec<f32> = (0..8).map(|_| rng.normal_ms(4.0, 1.0) as f32).collect();
            det.observe(&x, None);
        }
        assert!(det.is_drift(), "missed a 4σ mean shift");
    }

    #[test]
    fn centroid_no_false_positive_on_noise() {
        let mut rng = Rng64::new(5);
        let mut det = CentroidDetector::new(4);
        for _ in 0..2000 {
            let x: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            det.observe(&x, None);
        }
        assert!(!det.is_drift());
    }

    #[test]
    fn centroid_reset_clears_and_relearns() {
        let mut rng = Rng64::new(7);
        let mut det = CentroidDetector::new(4);
        for _ in 0..200 {
            let x: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            det.observe(&x, None);
        }
        for _ in 0..20 {
            let x: Vec<f32> = (0..4).map(|_| rng.normal_ms(5.0, 1.0) as f32).collect();
            det.observe(&x, None);
        }
        assert!(det.is_drift());
        det.reset();
        assert!(!det.is_drift());
        // after reset it relearns the *new* distribution without re-flagging
        for _ in 0..300 {
            let x: Vec<f32> = (0..4).map(|_| rng.normal_ms(5.0, 1.0) as f32).collect();
            det.observe(&x, None);
        }
        assert!(!det.is_drift(), "should adapt to the new distribution");
    }

    #[test]
    fn confidence_detector_flags_collapse() {
        use crate::odl::activation::Prediction;
        let mut det = ConfidenceDetector::new(0.4);
        let confident = Prediction {
            class: 0,
            p1: 0.9,
            p2: 0.05,
        };
        for _ in 0..100 {
            det.observe(&[], Some(&confident));
        }
        assert!(!det.is_drift());
        let unsure = Prediction {
            class: 0,
            p1: 0.4,
            p2: 0.35,
        };
        for _ in 0..200 {
            det.observe(&[], Some(&unsure));
        }
        assert!(det.is_drift());
    }
}
