//! The edge-device state machine — Algorithm 1 around the tiny ODL core.
//!
//! ```text
//! x ← Sense()
//! if mode = predicting:
//!     if IsDrift(x): mode ← training
//!     return Predict(x)
//! else:                            # training
//!     y ← LabelAcquire(Predict(x)) # pruning gate may skip the query
//!     SequentialTrain(x, y)
//!     if IsTrainDone(): mode ← predicting
//! ```
//!
//! Query round-trips are asynchronous in the fleet simulator, so the FSM
//! is split into `on_sense` (returns what the device wants to do) and
//! `on_label` / `on_query_failed` (completions). While a query is in
//! flight the device buffers the sample; per §2.2 an unreachable teacher
//! means the query "will be retried later or skipped" — retry policy
//! lives in the channel; the FSM just skips training for that sample.

use crate::drift::DriftDetector;
use crate::odl::activation::Prediction;
use crate::odl::{OsElm, OsElmConfig};
use crate::pruning::{Decision, Pruner};
use crate::util::rng::Rng64;

/// Operating mode (Algorithm 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Predicting,
    Training,
}

/// What the device asks the coordinator to do after sensing.
#[derive(Clone, Debug, PartialEq)]
pub enum StepAction {
    /// Predicting mode (or pruned training event): no communication.
    None,
    /// Training mode and the pruning gate said "query the teacher".
    QueryTeacher,
}

/// Edge-device configuration.
pub struct EdgeConfig {
    pub model: OsElmConfig,
    pub hash_seed: u16,
    pub pruner: Pruner,
    pub detector: Box<dyn DriftDetector + Send>,
    /// IsTrainDone: training-mode samples *trained* before returning to
    /// predicting mode (the paper's "pre-specified condition").
    pub train_target: usize,
}

/// One edge device: ODL core + Algorithm-1 state.
pub struct EdgeDevice {
    pub id: usize,
    pub mode: Mode,
    pub model: OsElm,
    pub pruner: Pruner,
    pub detector: Box<dyn DriftDetector + Send>,
    pub train_target: usize,
    /// Samples trained in the current training phase.
    pub trained_this_phase: usize,
    /// Training-mode events seen this phase (trained + skipped + failed) —
    /// what IsTrainDone counts: the paper's "number of required training
    /// samples" is stream samples, not successful queries (otherwise
    /// pruning could never reduce the per-episode query count).
    pub events_this_phase: usize,
    /// Sample awaiting a teacher reply (x, local prediction).
    pending: Option<(Vec<f32>, Prediction)>,
    /// Lifetime counters.
    pub total_queries: u64,
    pub total_skips: u64,
    pub total_trained: u64,
    pub mode_switches: u64,
}

impl EdgeDevice {
    pub fn new(id: usize, cfg: EdgeConfig, rng: &mut Rng64) -> Self {
        let model = OsElm::new(cfg.model, rng, cfg.hash_seed);
        Self::from_parts(id, model, cfg.pruner, cfg.detector, cfg.train_target)
    }

    /// Assemble a device around an already-constructed (typically
    /// pre-provisioned) ODL core. The fleet's edge-state memo clones a
    /// provisioned `OsElm` across scenario cells that share it and hands
    /// it in here; everything else (FSM, pruner, detector, counters)
    /// starts fresh exactly as [`Self::new`] would.
    pub fn from_parts(
        id: usize,
        model: OsElm,
        pruner: Pruner,
        detector: Box<dyn DriftDetector + Send>,
        train_target: usize,
    ) -> Self {
        EdgeDevice {
            id,
            mode: Mode::Predicting,
            model,
            pruner,
            detector,
            train_target,
            trained_this_phase: 0,
            events_this_phase: 0,
            pending: None,
            total_queries: 0,
            total_skips: 0,
            total_trained: 0,
            mode_switches: 0,
        }
    }

    /// Provision the core with an offline-initialized model (the paper's
    /// step 1: initial training happens before deployment).
    pub fn provision(&mut self, xs: &crate::linalg::Mat, labels: &[usize]) -> anyhow::Result<()> {
        self.model.init_batch(xs, labels)?;
        Ok(())
    }

    /// Is a query currently in flight?
    pub fn busy(&self) -> bool {
        self.pending.is_some()
    }

    /// Algorithm 1, lines 1–9: sense one sample.
    /// Returns the local prediction and the requested action.
    pub fn on_sense(&mut self, x: &[f32]) -> (Prediction, StepAction) {
        let pred = self.model.predict(x);
        self.detector.observe(x, Some(&pred));

        match self.mode {
            Mode::Predicting => {
                if self.detector.is_drift() {
                    self.enter_training();
                }
                (pred, StepAction::None)
            }
            Mode::Training => {
                if self.pending.is_some() {
                    // still waiting for the teacher on a previous sample —
                    // sporadic BLE; this sample is skipped (paper §2.2).
                    return (pred, StepAction::None);
                }
                self.events_this_phase += 1;
                // Condition 2: drift "currently detected" keeps querying.
                let drift_now = self.detector.is_drift();
                // Borrow-based metric path (exact EL2N when configured;
                // identical to P1P2 otherwise) — zero allocation per event.
                match self.pruner.decide_with_logits(
                    &pred,
                    self.model.last_logits(),
                    self.trained_this_phase,
                    drift_now,
                ) {
                    Decision::Skip => {
                        self.total_skips += 1;
                        self.pruner.observe(Decision::Skip, None);
                        self.check_train_done();
                        (pred, StepAction::None)
                    }
                    Decision::Query => {
                        self.total_queries += 1;
                        self.pending = Some((x.to_vec(), pred));
                        (pred, StepAction::QueryTeacher)
                    }
                }
            }
        }
    }

    /// Teacher reply arrived: sequential-train on the buffered sample.
    pub fn on_label(&mut self, teacher_label: usize) {
        let Some((x, pred)) = self.pending.take() else {
            return; // stale reply (e.g. after a mode switch) — ignore
        };
        self.pruner
            .observe(Decision::Query, Some(pred.class == teacher_label));
        self.model.train_step(&x, teacher_label);
        self.trained_this_phase += 1;
        self.total_trained += 1;
        // Once enough samples are trained, the drift episode is considered
        // handled: clear the detector so condition 2 stops forcing queries.
        if self.trained_this_phase == self.pruner.warmup {
            self.detector.reset();
        }
        self.check_train_done();
    }

    /// Query lost / teacher unreachable: skip training for that sample.
    pub fn on_query_failed(&mut self) {
        if self.pending.take().is_some() {
            self.pruner.observe(Decision::Query, None);
        }
    }

    fn enter_training(&mut self) {
        self.mode = Mode::Training;
        self.mode_switches += 1;
        self.trained_this_phase = 0;
        self.events_this_phase = 0;
    }

    fn check_train_done(&mut self) {
        if self.events_this_phase >= self.train_target {
            self.mode = Mode::Predicting;
            self.mode_switches += 1;
            self.trained_this_phase = 0;
            self.events_this_phase = 0;
            self.detector.reset();
        }
    }

    /// Force training mode (scripted-drift scenarios with an oracle
    /// detector drive this from the fleet).
    pub fn force_training(&mut self) {
        if self.mode == Mode::Predicting {
            self.enter_training();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drift::OracleDetector;
    use crate::linalg::Mat;
    use crate::pruning::{Metric, ThetaPolicy};

    fn mk_edge(train_target: usize, warmup: usize) -> (EdgeDevice, Mat, Vec<usize>) {
        let mut rng = Rng64::new(3);
        let model = OsElmConfig {
            n_in: 12,
            n_hidden: 16,
            n_out: 3,
            ..Default::default()
        };
        let cfg = EdgeConfig {
            model,
            hash_seed: 5,
            pruner: Pruner::new(ThetaPolicy::Fixed(0.2), Metric::P1P2, warmup),
            detector: Box::new(OracleDetector::new()),
            train_target,
        };
        let mut edge = EdgeDevice::new(0, cfg, &mut rng);
        // provision with a toy problem
        let mut xs = Mat::zeros(60, 12);
        let mut labels = Vec::new();
        for r in 0..60 {
            let c = r % 3;
            labels.push(c);
            for j in 0..12 {
                *xs.at_mut(r, j) = if j == c { 2.0 } else { -0.5 }
                    + rng.normal_ms(0.0, 0.3) as f32;
            }
        }
        edge.provision(&xs, &labels).unwrap();
        (edge, xs, labels)
    }

    #[test]
    fn predicting_mode_never_queries() {
        let (mut edge, xs, _) = mk_edge(10, 0);
        for r in 0..20 {
            let (_, action) = edge.on_sense(xs.row(r));
            assert_eq!(action, StepAction::None);
        }
        assert_eq!(edge.total_queries, 0);
        assert_eq!(edge.mode, Mode::Predicting);
    }

    #[test]
    fn training_mode_queries_until_target() {
        // warmup ≥ target ⇒ every event queries (pruning never engages)
        let (mut edge, xs, labels) = mk_edge(5, 100);
        edge.force_training();
        assert_eq!(edge.mode, Mode::Training);
        let mut trained = 0;
        let mut r = 0;
        while edge.mode == Mode::Training && r < 60 {
            let (_, action) = edge.on_sense(xs.row(r));
            if action == StepAction::QueryTeacher {
                edge.on_label(labels[r]);
                trained += 1;
            }
            r += 1;
        }
        assert_eq!(trained, 5);
        assert_eq!(edge.mode, Mode::Predicting);
        assert_eq!(edge.trained_this_phase, 0);
    }

    #[test]
    fn pending_query_blocks_new_queries() {
        let (mut edge, xs, _) = mk_edge(10, 100);
        edge.force_training();
        let (_, a1) = edge.on_sense(xs.row(0));
        assert_eq!(a1, StepAction::QueryTeacher);
        assert!(edge.busy());
        let (_, a2) = edge.on_sense(xs.row(1));
        assert_eq!(a2, StepAction::None, "in-flight query must block");
        edge.on_label(0);
        assert!(!edge.busy());
    }

    #[test]
    fn failed_query_skips_training() {
        let (mut edge, xs, _) = mk_edge(10, 100);
        edge.force_training();
        let (_, a) = edge.on_sense(xs.row(0));
        assert_eq!(a, StepAction::QueryTeacher);
        edge.on_query_failed();
        assert!(!edge.busy());
        assert_eq!(edge.total_trained, 0);
        assert_eq!(edge.mode, Mode::Training, "stays in training mode");
    }

    #[test]
    fn stale_label_ignored() {
        let (mut edge, _, _) = mk_edge(10, 0);
        edge.on_label(2); // no pending query
        assert_eq!(edge.total_trained, 0);
    }

    #[test]
    fn warmup_forces_queries_then_pruning_engages() {
        let (mut edge, xs, labels) = mk_edge(40, 8);
        edge.force_training();
        let mut skips_before_warmup = 0;
        for r in 0..30 {
            let (_, action) = edge.on_sense(xs.row(r % 60));
            match action {
                StepAction::QueryTeacher => edge.on_label(labels[r % 60]),
                StepAction::None => {
                    if edge.trained_this_phase < 8 {
                        skips_before_warmup += 1;
                    }
                }
            }
        }
        assert_eq!(skips_before_warmup, 0, "no pruning before warmup");
        assert!(edge.total_skips > 0, "pruning engages after warmup");
    }
}
