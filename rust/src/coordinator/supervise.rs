//! Self-healing shard supervisor — `odl-har sweep --shard auto[:N]`.
//!
//! [`supervise`] turns the manual shard/merge workflow (PR 5) into an
//! unattended one: it launches one `sweep --shard I/N --resume` child per
//! shard (cost-weighted slices via
//! [`SweepPlan::cost_shard_ranges`]), watches each child's results file,
//! and recombines the finished shard set with the byte-identical merge.
//! The supervisor adds **zero** bytes of its own to any results stream —
//! children own their files end to end, so the merged output is
//! byte-identical to an undisturbed single-process run no matter how
//! many crashes, hangs, or retries happened along the way.
//!
//! # Failure handling
//!
//! - **Liveness**: the shard's streaming results rows double as its
//!   heartbeat — any byte growth of the shard file counts as progress.
//!   A child whose file stops growing for `heartbeat_timeout_s` is
//!   presumed hung, killed, and relaunched. Two deliberate asymmetries
//!   ([`Heartbeat`]): a *failed* length probe (transient stat error,
//!   storage backend briefly unavailable) resets the static streak
//!   instead of reading as "no growth" — only consecutive *successful*
//!   static probes count toward the timeout, so an I/O hiccup can never
//!   false-kill a healthy child; and before a child's **first observed
//!   byte of growth** the allowance is `heartbeat_timeout_s ×
//!   grace_factor` — artifact provisioning legitimately writes nothing
//!   for a long stretch, and killing through it would relaunch into the
//!   same stall until quarantine.
//! - **Crashes**: a child that exits nonzero, dies on a signal, or exits
//!   zero with an incomplete stream is relaunched. Every relaunch goes
//!   through the existing `--resume` path, so it continues from the last
//!   durable row rather than starting over.
//! - **Backoff + quarantine**: relaunches back off exponentially
//!   (`backoff_base_ms << (attempt-1)`, capped at `backoff_cap_ms`). A
//!   shard that exhausts `retry_budget` relaunches is **quarantined**:
//!   the study keeps going for the other shards and the supervisor
//!   reports the failure structurally ([`ShardReport`]) instead of
//!   aborting everything.
//! - **Exit status**: [`SuperviseStatus`] distinguishes `Complete` (all
//!   shards done, merge published — exit 0), `Degraded` (some shards
//!   quarantined, merge skipped — exit 2), and `Failed` (every shard
//!   quarantined, or the final merge itself failed — exit 3).
//!
//! Completion is never taken on faith: a shard counts as done only when
//! [`shard_stream_complete`] revalidates its file (header, row count,
//! per-row cell indices, no error rows) — a child exiting 0 with a
//! wounded stream is treated as a crash.
//!
//! # Launchers
//!
//! The supervisor is generic over a [`Launcher`] so the retry/heartbeat
//! logic is testable without processes. [`ProcessLauncher`] is the real
//! one (spawns `odl-har sweep` children — kill means SIGKILL);
//! [`ThreadLauncher`] runs shards on in-process threads (used by unit
//! tests and useful for library callers; threads cannot be killed, so
//! hang faults need the process launcher). Deterministic fault injection
//! ([`FaultPlan`], `--inject-faults`) threads through both; see
//! `rust/RELIABILITY.md` for the fault model and replayability story.

use super::sweep::{
    merge_shard_files, resume_shard_to_file_with_faults, shard_stream_complete, MergeOutcome,
    ShardSpec, SweepPlan, SweepSpec,
};
use crate::storage::{key_for_path, Storage};
use crate::util::faults::FaultPlan;
use anyhow::{ensure, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Supervisor knobs (CLI flags and the `[supervise]` TOML section; see
/// `crate::config`). Defaults are production-shaped; tests shrink the
/// timing knobs.
#[derive(Clone, Debug)]
pub struct SuperviseConfig {
    /// Shard count requested on the CLI (`--shard auto:N`); `0` means
    /// auto (resolved against cores and grid size by the caller — the
    /// supervisor itself takes the count from the shard path list).
    pub shards: usize,
    /// `--workers` forwarded to each child process.
    pub workers_per_shard: usize,
    /// Relaunches allowed per shard after its first attempt; exhausting
    /// the budget quarantines the shard.
    pub retry_budget: usize,
    /// Kill a child whose results file has not grown for this long.
    pub heartbeat_timeout_s: f64,
    /// Pre-first-byte allowance multiplier: until an attempt's first
    /// observed byte of growth, the heartbeat window is
    /// `heartbeat_timeout_s × grace_factor` (≥ 1), covering long
    /// artifact provisioning before the first row lands.
    pub grace_factor: f64,
    /// First relaunch delay; doubles per relaunch.
    pub backoff_base_ms: u64,
    /// Ceiling on the relaunch delay.
    pub backoff_cap_ms: u64,
    /// Supervisor poll interval.
    pub poll_ms: u64,
    /// `--inject-faults` spec forwarded to children (chaos testing).
    pub fault_spec: Option<String>,
    /// Number of leading attempts per shard that carry the fault spec;
    /// later relaunches run clean. The default (1) models "the fault
    /// happened once"; raise it to keep a shard failing through retries.
    pub fault_attempts: usize,
}

impl Default for SuperviseConfig {
    fn default() -> Self {
        SuperviseConfig {
            shards: 0,
            workers_per_shard: 1,
            retry_budget: 2,
            heartbeat_timeout_s: 60.0,
            grace_factor: 3.0,
            backoff_base_ms: 250,
            backoff_cap_ms: 5000,
            poll_ms: 50,
            fault_spec: None,
            fault_attempts: 1,
        }
    }
}

/// Terminal classification of a supervised run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SuperviseStatus {
    /// Every shard completed; the merge (when requested) was published.
    Complete,
    /// Some shards quarantined — the merge is skipped (it would not be
    /// byte-complete), but the surviving shard files are durable and a
    /// later `--shard auto` run resumes only the quarantined slices.
    Degraded,
    /// Every shard quarantined, or the final merge itself failed.
    Failed,
}

impl SuperviseStatus {
    /// Process exit code contract: 0 complete / 2 degraded / 3 failed
    /// (1 is left to generic CLI errors).
    pub fn exit_code(self) -> i32 {
        match self {
            SuperviseStatus::Complete => 0,
            SuperviseStatus::Degraded => 2,
            SuperviseStatus::Failed => 3,
        }
    }
}

/// Per-shard structured outcome.
#[derive(Clone, Debug)]
pub struct ShardReport {
    /// 1-based shard index.
    pub index: usize,
    /// Launches performed (0 if the shard file was already complete).
    pub attempts: usize,
    /// True if the shard exhausted its retry budget.
    pub quarantined: bool,
    /// The most recent failure, if any attempt failed.
    pub last_error: Option<String>,
    /// The shard's results file.
    pub path: PathBuf,
}

/// What [`supervise`] hands back. Always `Ok` once the state machine
/// settles — degraded/failed studies are data, not `Err` (the CLI maps
/// [`SuperviseStatus::exit_code`]).
#[derive(Debug)]
pub struct SuperviseOutcome {
    pub status: SuperviseStatus,
    pub shards: Vec<ShardReport>,
    /// The merge result when one was requested and published.
    pub merged: Option<MergeOutcome>,
    /// Why the merge failed, when it did.
    pub merge_error: Option<String>,
}

/// A running shard attempt, as the supervisor sees it.
pub trait ShardChild {
    /// `Ok(None)` while running; `Ok(Some(success))` once exited.
    fn poll_exit(&mut self) -> Result<Option<bool>>;
    /// Best-effort terminate (SIGKILL for processes; threads cannot be
    /// killed and implement this as a no-op).
    fn kill(&mut self);
}

/// Strategy for launching one shard attempt.
pub trait Launcher {
    type Child: ShardChild;
    /// Start attempt `attempt` (0-based) of `shard`, writing to `out`.
    fn launch(
        &self,
        shard: ShardSpec,
        out: &Path,
        attempt: usize,
        cfg: &SuperviseConfig,
    ) -> Result<Self::Child>;
}

/// The real launcher: one `odl-har sweep --shard I/N --resume` child
/// process per attempt.
pub struct ProcessLauncher {
    /// Path to the `odl-har` binary (tests use `CARGO_BIN_EXE_odl-har`).
    pub exe: PathBuf,
    /// `--config` forwarded to each child, so the child re-derives the
    /// exact same spec (and therefore grid hash) as the supervisor.
    pub config_path: PathBuf,
    /// `--storage` forwarded to each child, so shard streams hydrate
    /// from and publish to the shared backend.
    pub storage_uri: Option<String>,
}

pub struct ProcessChild {
    child: Option<std::process::Child>,
}

impl Launcher for ProcessLauncher {
    type Child = ProcessChild;

    fn launch(
        &self,
        shard: ShardSpec,
        out: &Path,
        attempt: usize,
        cfg: &SuperviseConfig,
    ) -> Result<ProcessChild> {
        let mut cmd = std::process::Command::new(&self.exe);
        cmd.arg("sweep")
            .arg("--config")
            .arg(&self.config_path)
            .arg("--shard")
            .arg(format!("{}/{}", shard.index, shard.of))
            .arg("--out")
            .arg(out)
            .arg("--resume")
            .arg("--workers")
            .arg(cfg.workers_per_shard.to_string())
            .stdin(std::process::Stdio::null())
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::inherit());
        if let Some(uri) = &self.storage_uri {
            cmd.arg("--storage").arg(uri);
        }
        if let Some(spec) = &cfg.fault_spec {
            if attempt < cfg.fault_attempts {
                cmd.arg("--inject-faults").arg(spec);
            }
        }
        let child = cmd
            .spawn()
            .with_context(|| format!("spawning shard {}/{} child", shard.index, shard.of))?;
        Ok(ProcessChild { child: Some(child) })
    }
}

impl ShardChild for ProcessChild {
    fn poll_exit(&mut self) -> Result<Option<bool>> {
        let Some(child) = self.child.as_mut() else {
            return Ok(Some(false));
        };
        match child.try_wait().context("polling shard child")? {
            None => Ok(None),
            Some(status) => {
                self.child = None;
                Ok(Some(status.success()))
            }
        }
    }

    fn kill(&mut self) {
        if let Some(mut child) = self.child.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

impl Drop for ProcessChild {
    /// Never leak a child past the supervisor (e.g. on panic/`?`).
    fn drop(&mut self) {
        self.kill();
    }
}

/// In-process launcher: each attempt is
/// [`resume_shard_to_file_with_faults`] on a std thread. Used by the
/// unit tests and usable by library callers that want supervision
/// without process fan-out. `kill` is a no-op (std threads cannot be
/// terminated), so hang-style faults require [`ProcessLauncher`].
pub struct ThreadLauncher {
    spec: Arc<SweepSpec>,
}

impl ThreadLauncher {
    pub fn new(spec: Arc<SweepSpec>) -> Self {
        ThreadLauncher { spec }
    }
}

pub struct ThreadChild {
    handle: Option<std::thread::JoinHandle<bool>>,
}

impl Launcher for ThreadLauncher {
    type Child = ThreadChild;

    fn launch(
        &self,
        shard: ShardSpec,
        out: &Path,
        attempt: usize,
        cfg: &SuperviseConfig,
    ) -> Result<ThreadChild> {
        let faults = match &cfg.fault_spec {
            Some(spec) if attempt < cfg.fault_attempts => {
                FaultPlan::parse(spec)?.for_shard(shard.index)
            }
            _ => FaultPlan::default(),
        };
        let spec = Arc::clone(&self.spec);
        let out = out.to_path_buf();
        let handle = std::thread::Builder::new()
            .name(format!("shard-{}of{}", shard.index, shard.of))
            .spawn(move || {
                // the plan is cheap to re-derive and keeps the closure
                // free of borrowed supervisor state
                let plan = spec.plan();
                match resume_shard_to_file_with_faults(&spec, &plan, shard, &out, &faults) {
                    Ok(_) => true,
                    Err(e) => {
                        eprintln!("shard {}/{} attempt failed: {e:#}", shard.index, shard.of);
                        false
                    }
                }
            })
            .context("spawning shard thread")?;
        Ok(ThreadChild {
            handle: Some(handle),
        })
    }
}

impl ShardChild for ThreadChild {
    fn poll_exit(&mut self) -> Result<Option<bool>> {
        let Some(handle) = self.handle.as_ref() else {
            return Ok(Some(false));
        };
        if !handle.is_finished() {
            return Ok(None);
        }
        let handle = self.handle.take().expect("handle vanished");
        // a panicked shard thread is a failed attempt, not a supervisor
        // crash (cell panics are already caught inside the pool; this
        // only fires for panics outside run_cells)
        Ok(Some(handle.join().unwrap_or(false)))
    }

    fn kill(&mut self) {}
}

/// The canonical shard-file siblings for an output path: `a/b.jsonl` →
/// `a/b.shard{I}of{N}.jsonl` — the same naming the `sweep --shard I/N`
/// CLI defaults to, so supervised and manual runs share files.
pub fn shard_out_paths(out: &Path, of: usize) -> Vec<PathBuf> {
    let stem = out
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("sweep")
        .to_string();
    let ext = out
        .extension()
        .and_then(|s| s.to_str())
        .unwrap_or("jsonl")
        .to_string();
    (1..=of)
        .map(|i| out.with_file_name(format!("{stem}.shard{i}of{of}.{ext}")))
        .collect()
}

enum ShardState<C> {
    Pending { attempt: usize, not_before: Instant },
    Running { child: C, attempt: usize, hb: Heartbeat },
    Done,
    Quarantined,
}

/// Byte-growth liveness tracker for one running attempt. The two rules
/// the bugfixes pinned down:
///
/// * only **consecutive successful** static probes count toward the
///   timeout — a probe *error* (transient stat failure, storage backend
///   briefly unavailable) means "liveness unknown" and resets the
///   streak, where the old `unwrap_or(0)` read it as "file static" and
///   could false-kill a healthy child;
/// * until the attempt's first observed byte of growth the allowance is
///   the *grace* window (`heartbeat_timeout_s × grace_factor`), so a
///   child doing long artifact provisioning before its first row is not
///   killed into the same stall over and over until quarantine.
struct Heartbeat {
    /// Last successfully observed length (absent file = 0).
    last_len: u64,
    /// Whether this attempt has ever been observed growing the file.
    grew: bool,
    /// Start of the current run of consecutive successful static
    /// probes; `None` after growth, a probe error, or at launch.
    static_since: Option<Instant>,
}

impl Heartbeat {
    /// Tracker for a fresh launch; `initial` is the launch-time probe
    /// (`None` for "file absent" *and* for a failed probe — either way
    /// the first in-flight observation establishes the baseline).
    fn start(initial: Option<u64>) -> Heartbeat {
        Heartbeat {
            last_len: initial.unwrap_or(0),
            grew: false,
            static_since: None,
        }
    }

    /// Fold in one probe made at `now`.
    fn observe(&mut self, probe: std::result::Result<Option<u64>, String>, now: Instant) {
        match probe {
            // liveness unknown — never count an error as "static"
            Err(_) => self.static_since = None,
            Ok(len) => {
                let len = len.unwrap_or(0);
                if len > self.last_len {
                    self.last_len = len;
                    self.grew = true;
                    self.static_since = None;
                } else {
                    self.static_since.get_or_insert(now);
                }
            }
        }
    }

    /// Whether the static streak has outlived its allowance: `timeout`
    /// once the attempt has produced bytes, `grace` before that.
    fn expired(&self, now: Instant, timeout: Duration, grace: Duration) -> bool {
        let limit = if self.grew { timeout } else { grace };
        self.static_since
            .is_some_and(|t| now.saturating_duration_since(t) >= limit)
    }
}

/// One heartbeat length probe — through the storage backend when the
/// study runs on one (multi-host placement probes the shared object),
/// directly via the filesystem otherwise. Errors come back as `Err`,
/// never as a zero length: [`Heartbeat::observe`] must be able to tell
/// "could not look" from "looked, no growth".
fn probe_len(
    storage: Option<&Storage>,
    path: &Path,
) -> std::result::Result<Option<u64>, String> {
    match storage {
        Some(st) => match key_for_path(path) {
            Ok(key) => st.probe(&key),
            Err(e) => Err(format!("{e:#}")),
        },
        None => match std::fs::metadata(path) {
            Ok(m) => Ok(Some(m.len())),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.to_string()),
        },
    }
}

/// Record a failed attempt and decide the shard's next state: backoff
/// into another `Pending`, or `Quarantined` once the budget is spent.
fn retire<C>(
    report: &mut ShardReport,
    cfg: &SuperviseConfig,
    attempt: usize,
    error: String,
) -> ShardState<C> {
    report.last_error = Some(error);
    let next = attempt + 1;
    if next > cfg.retry_budget {
        report.quarantined = true;
        return ShardState::Quarantined;
    }
    let backoff = cfg
        .backoff_base_ms
        .saturating_mul(1u64 << (next - 1).min(20))
        .min(cfg.backoff_cap_ms);
    ShardState::Pending {
        attempt: next,
        not_before: Instant::now() + Duration::from_millis(backoff),
    }
}

/// Drive every shard of `plan` to completion (or quarantine) and then
/// merge into `merged_out` (when given and no shard quarantined). One
/// results file per entry of `shard_paths`; shard `i+1/N` owns
/// `shard_paths[i]`. Shards whose file already passes
/// [`shard_stream_complete`] are recognized without a launch, so a
/// degraded study can be re-supervised to finish only its quarantined
/// slices. With `storage` set, heartbeat probes go through the backend
/// (the callers pass one only for backends whose objects track the live
/// spool — today the local-dir backend, where the spool *is* the
/// object).
pub fn supervise<L: Launcher>(
    plan: &SweepPlan,
    cfg: &SuperviseConfig,
    launcher: &L,
    shard_paths: &[PathBuf],
    merged_out: Option<&Path>,
    storage: Option<&Storage>,
) -> Result<SuperviseOutcome> {
    let of = shard_paths.len();
    ensure!(of >= 1, "supervise needs at least one shard path");
    ensure!(
        cfg.heartbeat_timeout_s > 0.0,
        "heartbeat timeout must be positive"
    );
    ensure!(
        cfg.grace_factor >= 1.0,
        "grace factor must be at least 1 (it scales the heartbeat timeout)"
    );
    let timeout = Duration::from_secs_f64(cfg.heartbeat_timeout_s);
    let grace = timeout.mul_f64(cfg.grace_factor);

    let mut reports: Vec<ShardReport> = (0..of)
        .map(|s| ShardReport {
            index: s + 1,
            attempts: 0,
            quarantined: false,
            last_error: None,
            path: shard_paths[s].clone(),
        })
        .collect();
    let mut states: Vec<ShardState<L::Child>> = (0..of)
        .map(|_| ShardState::Pending {
            attempt: 0,
            not_before: Instant::now(),
        })
        .collect();

    loop {
        let mut settled = true;
        for s in 0..of {
            if matches!(states[s], ShardState::Done | ShardState::Quarantined) {
                continue;
            }
            settled = false;
            let shard = ShardSpec { index: s + 1, of };
            let path = &shard_paths[s];
            let state = std::mem::replace(&mut states[s], ShardState::Quarantined);
            states[s] = match state {
                ShardState::Pending { attempt, not_before } => {
                    if Instant::now() < not_before {
                        ShardState::Pending { attempt, not_before }
                    } else if shard_stream_complete(plan, shard, path) {
                        // already durable (prior run, or a crash after
                        // the stream finished) — no launch needed
                        ShardState::Done
                    } else {
                        reports[s].attempts += 1;
                        match launcher.launch(shard, path, attempt, cfg) {
                            Ok(child) => ShardState::Running {
                                child,
                                attempt,
                                hb: Heartbeat::start(probe_len(storage, path).ok().flatten()),
                            },
                            Err(e) => {
                                retire(&mut reports[s], cfg, attempt, format!("launch: {e:#}"))
                            }
                        }
                    }
                }
                ShardState::Running {
                    mut child,
                    attempt,
                    mut hb,
                } => match child.poll_exit() {
                    Ok(Some(true)) if shard_stream_complete(plan, shard, path) => ShardState::Done,
                    Ok(Some(true)) => retire(
                        &mut reports[s],
                        cfg,
                        attempt,
                        "child exited cleanly but its results stream is incomplete".to_string(),
                    ),
                    // a crash *after* the stream finished (e.g. SIGKILL
                    // between the trailer write and process exit) leaves a
                    // complete, durable file — that is success, not a
                    // failed attempt; retiring here would burn the retry
                    // budget (or quarantine outright at budget 0) over
                    // work that is already on disk
                    Ok(Some(false)) if shard_stream_complete(plan, shard, path) => ShardState::Done,
                    Ok(Some(false)) => retire(
                        &mut reports[s],
                        cfg,
                        attempt,
                        "child exited with a failure status".to_string(),
                    ),
                    Err(e) => {
                        child.kill();
                        retire(&mut reports[s], cfg, attempt, format!("poll: {e:#}"))
                    }
                    Ok(None) => {
                        hb.observe(probe_len(storage, path), Instant::now());
                        if hb.expired(Instant::now(), timeout, grace) {
                            child.kill();
                            // a static file is only a hang if the stream is
                            // still incomplete — a child that wrote its
                            // trailer and then stalled (or a relaunch onto
                            // an already-complete file that outlives the
                            // heartbeat while revalidating) must not be
                            // retired as a false hang
                            if shard_stream_complete(plan, shard, path) {
                                ShardState::Done
                            } else {
                                retire(
                                    &mut reports[s],
                                    cfg,
                                    attempt,
                                    format!(
                                        "no heartbeat (results file static) for {:.1}s — killed",
                                        cfg.heartbeat_timeout_s
                                    ),
                                )
                            }
                        } else {
                            ShardState::Running { child, attempt, hb }
                        }
                    }
                },
                done_or_quarantined => done_or_quarantined,
            };
        }
        if settled {
            break;
        }
        std::thread::sleep(Duration::from_millis(cfg.poll_ms.max(1)));
    }

    let quarantined = reports.iter().filter(|r| r.quarantined).count();
    let (status, merged, merge_error) = if quarantined == 0 {
        match merged_out {
            None => (SuperviseStatus::Complete, None, None),
            Some(out) => match merge_shard_files(plan, shard_paths, out) {
                Ok(m) => (SuperviseStatus::Complete, Some(m), None),
                Err(e) => (SuperviseStatus::Failed, None, Some(format!("{e:#}"))),
            },
        }
    } else if quarantined == of {
        (SuperviseStatus::Failed, None, None)
    } else {
        (SuperviseStatus::Degraded, None, None)
    };
    Ok(SuperviseOutcome {
        status,
        shards: reports,
        merged,
        merge_error,
    })
}

#[cfg(test)]
mod tests {
    use super::super::fleet::{DetectorKind, Scenario};
    use super::super::sweep::{resume_shard_to_file, run_planned_to_file};
    use super::*;
    use crate::data::SynthConfig;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    fn fixture_spec() -> SweepSpec {
        let base = {
            let mut b = Scenario {
                n_edges: 2,
                n_hidden: 16,
                event_period_s: 1.0,
                horizon_s: 40.0,
                drift_at_s: 15.0,
                train_target: 24,
                synth: SynthConfig {
                    n_features: 24,
                    n_classes: 3,
                    n_subjects: 30,
                    samples_per_cell: 4,
                    proto_sigma: 1.1,
                    confuse_frac: 0.04,
                    ..Default::default()
                },
                ..Default::default()
            };
            b.data_seed = Some(0x50BE);
            b
        };
        SweepSpec {
            seeds: vec![1, 2],
            thetas: vec![None, Some(0.2)],
            edge_counts: vec![2],
            detectors: vec![DetectorKind::Oracle],
            n_hiddens: vec![base.n_hidden],
            loss_probs: vec![base.channel.loss_prob],
            teacher_errors: vec![base.teacher_error],
            workers: 2,
            record_pca: false,
            memo_edge_state: true,
            base,
        }
    }

    fn fast_cfg() -> SuperviseConfig {
        SuperviseConfig {
            shards: 2,
            poll_ms: 2,
            backoff_base_ms: 1,
            backoff_cap_ms: 4,
            ..Default::default()
        }
    }

    fn setup(name: &str) -> (SweepSpec, SweepPlan, PathBuf, Vec<u8>) {
        let spec = fixture_spec();
        let plan = spec.plan();
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let single = dir.join("single.jsonl");
        run_planned_to_file(&spec, &plan, &single).unwrap();
        let bytes = std::fs::read(&single).unwrap();
        (spec, plan, dir, bytes)
    }

    #[test]
    fn clean_supervised_run_completes_and_merges_byte_identically() {
        let (spec, plan, dir, single) = setup("odl_har_supervise_clean_test");
        let merged = dir.join("merged.jsonl");
        let paths = shard_out_paths(&merged, 2);
        let cfg = fast_cfg();
        let launcher = ThreadLauncher::new(Arc::new(spec));
        let out = supervise(&plan, &cfg, &launcher, &paths, Some(&merged), None).unwrap();
        assert_eq!(out.status, SuperviseStatus::Complete);
        assert_eq!(out.status.exit_code(), 0);
        assert!(out.merged.is_some());
        assert!(out
            .shards
            .iter()
            .all(|r| r.attempts == 1 && !r.quarantined && r.last_error.is_none()));
        assert_eq!(std::fs::read(&merged).unwrap(), single);
        // re-supervising a finished study recognizes the durable shards
        // without a single launch and republishes the identical merge
        let again = supervise(&plan, &cfg, &launcher, &paths, Some(&merged), None).unwrap();
        assert_eq!(again.status, SuperviseStatus::Complete);
        assert!(again.shards.iter().all(|r| r.attempts == 0));
        assert_eq!(std::fs::read(&merged).unwrap(), single);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_failure_is_retried_to_byte_identical_completion() {
        let (spec, plan, dir, single) = setup("odl_har_supervise_retry_test");
        let merged = dir.join("merged.jsonl");
        let paths = shard_out_paths(&merged, 2);
        let cfg = SuperviseConfig {
            // both shards fail their first attempt at results slot 2,
            // then retry clean and resume from the durable prefix
            fault_spec: Some("0:ioerr@2".to_string()),
            fault_attempts: 1,
            ..fast_cfg()
        };
        let launcher = ThreadLauncher::new(Arc::new(spec));
        let out = supervise(&plan, &cfg, &launcher, &paths, Some(&merged), None).unwrap();
        assert_eq!(out.status, SuperviseStatus::Complete);
        for r in &out.shards {
            assert_eq!(r.attempts, 2, "shard {} should fail once then heal", r.index);
            assert!(!r.quarantined);
            assert!(r.last_error.as_deref().unwrap().contains("failure status"));
        }
        assert_eq!(std::fs::read(&merged).unwrap(), single);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn exhausted_budget_quarantines_every_shard_and_reports_failed() {
        let (spec, plan, dir, _single) = setup("odl_har_supervise_failed_test");
        let merged = dir.join("merged.jsonl");
        let paths = shard_out_paths(&merged, 2);
        let cfg = SuperviseConfig {
            fault_spec: Some("0:ioerr@1".to_string()),
            fault_attempts: 99, // the fault never clears
            retry_budget: 1,
            ..fast_cfg()
        };
        let launcher = ThreadLauncher::new(Arc::new(spec));
        let out = supervise(&plan, &cfg, &launcher, &paths, Some(&merged), None).unwrap();
        assert_eq!(out.status, SuperviseStatus::Failed);
        assert_eq!(out.status.exit_code(), 3);
        assert!(out.merged.is_none());
        assert!(!merged.exists(), "a failed study must not publish a merge");
        for r in &out.shards {
            assert!(r.quarantined);
            assert_eq!(r.attempts, 2); // first try + the one budgeted retry
            assert!(r.last_error.is_some());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn single_quarantine_degrades_without_merging() {
        let (spec, plan, dir, _single) = setup("odl_har_supervise_degraded_test");
        let merged = dir.join("merged.jsonl");
        let paths = shard_out_paths(&merged, 2);
        let cfg = SuperviseConfig {
            fault_spec: Some("0:ioerr@1#2".to_string()), // only shard 2
            fault_attempts: 99,
            retry_budget: 1,
            ..fast_cfg()
        };
        let launcher = ThreadLauncher::new(Arc::new(spec));
        let out = supervise(&plan, &cfg, &launcher, &paths, Some(&merged), None).unwrap();
        assert_eq!(out.status, SuperviseStatus::Degraded);
        assert_eq!(out.status.exit_code(), 2);
        assert!(out.merged.is_none() && !merged.exists());
        assert!(!out.shards[0].quarantined && out.shards[0].attempts == 1);
        assert!(out.shards[1].quarantined);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Scripted launcher for the pure supervisor logic (hangs, kills,
    /// launch errors) that ThreadLauncher cannot express.
    struct FakeLauncher {
        spec: Arc<SweepSpec>,
        plan: SweepPlan,
        script: Mutex<std::collections::HashMap<usize, Vec<FakeAct>>>,
        kills: Arc<AtomicUsize>,
    }

    #[derive(Clone, Copy)]
    enum FakeAct {
        CompleteOk,
        FailExit,
        Hang,
    }

    struct FakeChild {
        exit: Option<bool>,
        kills: Arc<AtomicUsize>,
    }

    impl Launcher for FakeLauncher {
        type Child = FakeChild;
        fn launch(
            &self,
            shard: ShardSpec,
            out: &Path,
            _attempt: usize,
            _cfg: &SuperviseConfig,
        ) -> Result<FakeChild> {
            let act = {
                let mut script = self.script.lock().unwrap();
                let acts = script.entry(shard.index).or_default();
                if acts.is_empty() {
                    FakeAct::CompleteOk
                } else {
                    acts.remove(0)
                }
            };
            let exit = match act {
                FakeAct::CompleteOk => {
                    resume_shard_to_file(&self.spec, &self.plan, shard, out)?;
                    Some(true)
                }
                FakeAct::FailExit => Some(false),
                FakeAct::Hang => None,
            };
            Ok(FakeChild {
                exit,
                kills: Arc::clone(&self.kills),
            })
        }
    }

    impl ShardChild for FakeChild {
        fn poll_exit(&mut self) -> Result<Option<bool>> {
            Ok(self.exit)
        }
        fn kill(&mut self) {
            self.kills.fetch_add(1, Ordering::SeqCst);
        }
    }

    #[test]
    fn hung_child_is_killed_on_heartbeat_timeout_and_relaunched() {
        let (spec, plan, dir, _single) = setup("odl_har_supervise_hang_test");
        let merged = dir.join("merged.jsonl");
        let paths = shard_out_paths(&merged, 2);
        let kills = Arc::new(AtomicUsize::new(0));
        let spec = Arc::new(spec);
        let launcher = FakeLauncher {
            spec: Arc::clone(&spec),
            // plans are deterministic; re-deriving avoids a Clone bound
            plan: spec.plan(),
            script: Mutex::new(
                [(1, vec![FakeAct::Hang]), (2, vec![FakeAct::FailExit])]
                    .into_iter()
                    .collect(),
            ),
            kills: Arc::clone(&kills),
        };
        let cfg = SuperviseConfig {
            heartbeat_timeout_s: 0.05,
            ..fast_cfg()
        };
        let out = supervise(&plan, &cfg, &launcher, &paths, Some(&merged), None).unwrap();
        assert_eq!(out.status, SuperviseStatus::Complete);
        assert_eq!(kills.load(Ordering::SeqCst), 1, "the hung child is killed");
        assert_eq!(out.shards[0].attempts, 2);
        assert!(out.shards[0]
            .last_error
            .as_deref()
            .unwrap()
            .contains("no heartbeat"));
        assert_eq!(out.shards[1].attempts, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn heartbeat_probe_errors_do_not_count_as_no_growth() {
        // deterministic synthetic clock: t0 + n·10ms observations
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let timeout = Duration::from_millis(30);
        let grace = Duration::from_millis(90);
        let mut hb = Heartbeat::start(Some(10));
        // growth, then a static streak that would expire at +40ms…
        hb.observe(Ok(Some(20)), at(0));
        hb.observe(Ok(Some(20)), at(10));
        assert!(!hb.expired(at(39), timeout, grace));
        // …but a probe error at +20ms resets the streak: liveness was
        // unknown, so the static window restarts at the next success
        hb.observe(Err("injected stat failure".into()), at(20));
        assert!(!hb.expired(at(60), timeout, grace));
        hb.observe(Ok(Some(20)), at(60));
        assert!(!hb.expired(at(89), timeout, grace));
        assert!(hb.expired(at(90), timeout, grace));
        // under the old unwrap_or(0) semantics an *erroring* probe also
        // looked like a shrink-to-zero "static" read; here even a
        // permanent error stream never expires the heartbeat
        let mut hb = Heartbeat::start(Some(10));
        for n in 0..50 {
            hb.observe(Err("backend unavailable".into()), at(n * 10));
        }
        assert!(!hb.expired(at(1000), timeout, grace));
    }

    #[test]
    fn heartbeat_grants_grace_before_first_byte_and_timeout_after() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let timeout = Duration::from_millis(30);
        let grace = Duration::from_millis(90);
        // provisioning child: file absent, no bytes yet — static probes
        // count against the grace window, not the plain timeout
        let mut hb = Heartbeat::start(None);
        hb.observe(Ok(None), at(0));
        assert!(!hb.expired(at(89), timeout, grace), "inside grace");
        assert!(hb.expired(at(90), timeout, grace), "grace exhausted");
        // once the first byte lands, the allowance tightens to timeout
        let mut hb = Heartbeat::start(None);
        hb.observe(Ok(None), at(0));
        hb.observe(Ok(Some(64)), at(50)); // first growth, inside grace
        hb.observe(Ok(Some(64)), at(60));
        assert!(!hb.expired(at(89), timeout, grace));
        assert!(hb.expired(at(90), timeout, grace));
        // a relaunch onto a resumed spool: initial length is nonzero but
        // the *attempt* has produced nothing — still the grace window
        let mut hb = Heartbeat::start(Some(4096));
        hb.observe(Ok(Some(4096)), at(0));
        assert!(!hb.expired(at(89), timeout, grace));
        assert!(hb.expired(at(90), timeout, grace));
    }

    #[test]
    fn supervise_rejects_a_sub_one_grace_factor() {
        let (spec, plan, dir, _single) = setup("odl_har_supervise_grace_cfg_test");
        let paths = shard_out_paths(&dir.join("merged.jsonl"), 2);
        let cfg = SuperviseConfig {
            grace_factor: 0.5,
            ..fast_cfg()
        };
        let launcher = ThreadLauncher::new(Arc::new(spec));
        let err = supervise(&plan, &cfg, &launcher, &paths, None, None).unwrap_err();
        assert!(format!("{err:#}").contains("grace factor"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn supervised_run_probing_through_storage_merges_byte_identically() {
        // the multi-host shape on one host: shard spools live inside a
        // local-dir storage root (spool == object), the supervisor's
        // heartbeat probes go through the backend, and the merge of the
        // published set is byte-identical to the single-process run
        use crate::storage::{Storage, StorageConfig};
        let (spec, plan, dir, single) = setup("odl_har_supervise_storage_test");
        let store = dir.join("store");
        std::fs::create_dir_all(&store).unwrap();
        let st = Storage::local_dir(&store, &StorageConfig::default());
        let merged = store.join("merged.jsonl");
        let paths = shard_out_paths(&merged, 2);
        let cfg = SuperviseConfig {
            // one shard tears a write on its first attempt; the retry
            // resumes and the probe path sees every intermediate length
            fault_spec: Some("0:tear@2#1".to_string()),
            fault_attempts: 1,
            ..fast_cfg()
        };
        let launcher = ThreadLauncher::new(Arc::new(spec));
        let out = supervise(&plan, &cfg, &launcher, &paths, Some(&merged), Some(&st)).unwrap();
        assert_eq!(out.status, SuperviseStatus::Complete);
        assert_eq!(std::fs::read(&merged).unwrap(), single);
        // the shard spools are storage objects — listable and pullable
        let keys: Vec<String> = st.list("").unwrap().into_iter().map(|m| m.key).collect();
        assert!(keys.contains(&"merged.shard1of2.jsonl".to_string()), "{keys:?}");
        assert!(keys.contains(&"merged.jsonl".to_string()), "{keys:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_out_paths_name_canonical_siblings() {
        let paths = shard_out_paths(Path::new("results/sweep.jsonl"), 3);
        assert_eq!(
            paths,
            vec![
                PathBuf::from("results/sweep.shard1of3.jsonl"),
                PathBuf::from("results/sweep.shard2of3.jsonl"),
                PathBuf::from("results/sweep.shard3of3.jsonl"),
            ]
        );
    }
}
