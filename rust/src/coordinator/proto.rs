//! The `odl-har serve` wire protocol: JSONL over TCP.
//!
//! One JSON object per line in each direction, built on the in-tree
//! [`crate::util::json`] (no external deps, stable key order). The
//! protocol is designed so that *every* network failure is recoverable by
//! replay: events carry a client-assigned sequence number, the server
//! applies them exactly once in order (duplicates are acknowledged
//! without re-training, gaps are shed), and the handshake returns the
//! server's applied watermark so a reconnecting client fast-forwards its
//! buffered stream instead of replaying blind.
//!
//! Feature vectors and probabilities travel as **f32 bit patterns**
//! (`u32` integers), not decimal floats — the serve stack's byte-identity
//! contract (chaos run ≡ undisturbed run, snapshot round-trips exactly)
//! leaves no room for decimal rounding on the wire.
//!
//! ```text
//! client → server                         server → client
//! ---------------                         ---------------
//! {"type":"hello","client":NAME}          {"type":"welcome","client":NAME,
//!                                          "restored":BOOL,"next_seq":N}
//!                                         {"type":"busy","retry_after_ms":MS}
//! {"type":"event","seq":N,"label":L,      {"type":"decision","seq":N,
//!  "x":[bits,…]}                           "action":"trained"|"skipped"|
//!                                          "duplicate","class":C,
//!                                          "p1":bits,"p2":bits[,"label":L]}
//!                                         {"type":"shed","seq":N,
//!                                          "retry_after_ms":MS}
//! {"type":"events","events":[             {"type":"decisions","decisions":
//!  {"seq":N,"label":L,"x":[bits,…]},…]}    [{decision|shed},…]}
//! {"type":"ping"}                         {"type":"pong"}
//! {"type":"bye"}                          (close)
//! {"type":"shutdown"}                     {"type":"draining"}
//!                                         {"type":"error","reason":STR}
//! ```
//!
//! The batched frame (`events` → `decisions`) amortizes one round-trip
//! (and one fault site) over up to K in-order events for one client. The
//! server runs the *same* per-element watermark rules as the single-event
//! path — duplicates are acknowledged, gaps shed — and answers with one
//! `decisions` array carrying a `decision`/`shed` element per event, in
//! frame order. A frame larger than the server's `max_batch` is refused
//! with `error` and nothing in it is applied.

use crate::util::json::{obj, Json};
use anyhow::{bail, ensure, Context, Result};

/// Protocol / snapshot schema tag.
pub const PROTO_VERSION: &str = "odl-har-serve/v1";

/// Encode a feature vector as its f32 bit patterns.
pub fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Decode f32 bit patterns back into the exact feature vector.
pub fn floats_of(bits: &[u32]) -> Vec<f32> {
    bits.iter().map(|&b| f32::from_bits(b)).collect()
}

/// What the server did with an applied (or re-seen) event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecisionAction {
    /// Pruning gate said query: the teacher labelled it, the model trained.
    Trained,
    /// Pruning gate said skip: no teacher query, no training.
    Skipped,
    /// `seq` below the applied watermark — acknowledged, not re-applied.
    Duplicate,
}

impl DecisionAction {
    fn as_str(self) -> &'static str {
        match self {
            DecisionAction::Trained => "trained",
            DecisionAction::Skipped => "skipped",
            DecisionAction::Duplicate => "duplicate",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "trained" => DecisionAction::Trained,
            "skipped" => DecisionAction::Skipped,
            "duplicate" => DecisionAction::Duplicate,
            other => bail!("unknown decision action '{other}'"),
        })
    }
}

/// One element of a batched `events` frame — the same fields as a
/// single `event` request, without the `type` tag.
#[derive(Clone, Debug, PartialEq)]
pub struct EventItem {
    pub seq: u64,
    pub label: usize,
    pub x_bits: Vec<u32>,
}

/// A client → server message.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Register (or re-attach to) per-client state under `client`.
    Hello { client: String },
    /// One sensed sample: client-assigned sequence number, ground-truth
    /// label (feeds the oracle teacher), f32-bit feature vector.
    Event { seq: u64, label: usize, x_bits: Vec<u32> },
    /// Up to `max_batch` in-order events in one frame, each applied under
    /// the single-event watermark rules; answered by one `decisions`.
    Events { items: Vec<EventItem> },
    /// Liveness probe.
    Ping,
    /// Orderly goodbye — the server keeps the client's state in memory.
    Bye,
    /// Admin: stop accepting, drain in-flight work, snapshot, exit.
    Shutdown,
}

impl Request {
    /// One JSONL line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Request::Hello { client } => obj(vec![
                ("type", Json::Str("hello".into())),
                ("client", Json::Str(client.clone())),
            ]),
            Request::Event { seq, label, x_bits } => obj(vec![
                ("type", Json::Str("event".into())),
                ("seq", Json::Num(*seq as f64)),
                ("label", Json::Num(*label as f64)),
                (
                    "x",
                    Json::Arr(x_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
                ),
            ]),
            Request::Events { items } => obj(vec![
                ("type", Json::Str("events".into())),
                (
                    "events",
                    Json::Arr(
                        items
                            .iter()
                            .map(|it| {
                                obj(vec![
                                    ("seq", Json::Num(it.seq as f64)),
                                    ("label", Json::Num(it.label as f64)),
                                    (
                                        "x",
                                        Json::Arr(
                                            it.x_bits
                                                .iter()
                                                .map(|&b| Json::Num(b as f64))
                                                .collect(),
                                        ),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Request::Ping => obj(vec![("type", Json::Str("ping".into()))]),
            Request::Bye => obj(vec![("type", Json::Str("bye".into()))]),
            Request::Shutdown => obj(vec![("type", Json::Str("shutdown".into()))]),
        }
        .to_string()
    }

    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad request JSON: {e}"))?;
        let ty = j
            .get("type")
            .and_then(Json::as_str)
            .context("request missing 'type'")?;
        Ok(match ty {
            "hello" => Request::Hello {
                client: j
                    .get("client")
                    .and_then(Json::as_str)
                    .context("hello missing 'client'")?
                    .to_string(),
            },
            "event" => {
                let it = parse_event_item(&j)?;
                Request::Event { seq: it.seq, label: it.label, x_bits: it.x_bits }
            }
            "events" => {
                let arr = match j.get("events") {
                    Some(Json::Arr(items)) => items,
                    _ => bail!("events frame missing 'events' array"),
                };
                ensure!(!arr.is_empty(), "events frame must carry at least one event");
                Request::Events {
                    items: arr.iter().map(parse_event_item).collect::<Result<Vec<_>>>()?,
                }
            }
            "ping" => Request::Ping,
            "bye" => Request::Bye,
            "shutdown" => Request::Shutdown,
            other => bail!("unknown request type '{other}'"),
        })
    }
}

/// A server → client message.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Handshake accepted. `next_seq` is the applied watermark: the first
    /// event sequence number the server has not yet applied — a
    /// reconnecting client fast-forwards its buffered stream to it.
    Welcome { client: String, restored: bool, next_seq: u64 },
    /// Admission control: the connection cap is reached; come back after
    /// `retry_after_ms` (structured, so clients back off instead of spin).
    Busy { retry_after_ms: u64 },
    /// The outcome for one event. `p1`/`p2` are the local prediction's
    /// top-2 probabilities as f32 bits; `label` is the teacher's label
    /// when the event trained.
    Decision {
        seq: u64,
        action: DecisionAction,
        class: usize,
        p1_bits: u32,
        p2_bits: u32,
        label: Option<usize>,
    },
    /// Backpressure: `seq` is more than the pipelining window ahead of
    /// the applied watermark — deterministically refused, retry later.
    Shed { seq: u64, retry_after_ms: u64 },
    /// The per-element outcomes of one batched `events` frame, in frame
    /// order. Elements are restricted to `Decision` / `Shed` — the same
    /// two outcomes the single-event path can produce.
    Decisions { items: Vec<Response> },
    /// Liveness reply.
    Pong,
    /// The server is draining: no further requests will be served.
    Draining,
    /// Malformed or out-of-protocol request (the request was NOT applied).
    Error { reason: String },
}

impl Response {
    /// One JSONL line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    fn to_json(&self) -> Json {
        match self {
            Response::Welcome { client, restored, next_seq } => obj(vec![
                ("type", Json::Str("welcome".into())),
                ("client", Json::Str(client.clone())),
                ("restored", Json::Bool(*restored)),
                ("next_seq", Json::Num(*next_seq as f64)),
            ]),
            Response::Busy { retry_after_ms } => obj(vec![
                ("type", Json::Str("busy".into())),
                ("retry_after_ms", Json::Num(*retry_after_ms as f64)),
            ]),
            Response::Decision { seq, action, class, p1_bits, p2_bits, label } => {
                let mut pairs = vec![
                    ("type", Json::Str("decision".into())),
                    ("seq", Json::Num(*seq as f64)),
                    ("action", Json::Str(action.as_str().into())),
                    ("class", Json::Num(*class as f64)),
                    ("p1", Json::Num(*p1_bits as f64)),
                    ("p2", Json::Num(*p2_bits as f64)),
                ];
                if let Some(l) = label {
                    pairs.push(("label", Json::Num(*l as f64)));
                }
                obj(pairs)
            }
            Response::Shed { seq, retry_after_ms } => obj(vec![
                ("type", Json::Str("shed".into())),
                ("seq", Json::Num(*seq as f64)),
                ("retry_after_ms", Json::Num(*retry_after_ms as f64)),
            ]),
            Response::Decisions { items } => obj(vec![
                ("type", Json::Str("decisions".into())),
                (
                    "decisions",
                    Json::Arr(items.iter().map(|r| r.to_json()).collect()),
                ),
            ]),
            Response::Pong => obj(vec![("type", Json::Str("pong".into()))]),
            Response::Draining => obj(vec![("type", Json::Str("draining".into()))]),
            Response::Error { reason } => obj(vec![
                ("type", Json::Str("error".into())),
                ("reason", Json::Str(reason.clone())),
            ]),
        }
    }

    /// Parse one response line.
    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response JSON: {e}"))?;
        Self::from_json(&j)
    }

    fn from_json(j: &Json) -> Result<Response> {
        let ty = j
            .get("type")
            .and_then(Json::as_str)
            .context("response missing 'type'")?;
        Ok(match ty {
            "welcome" => Response::Welcome {
                client: j
                    .get("client")
                    .and_then(Json::as_str)
                    .context("welcome missing 'client'")?
                    .to_string(),
                restored: matches!(j.get("restored"), Some(Json::Bool(true))),
                next_seq: j
                    .get("next_seq")
                    .and_then(Json::as_usize)
                    .context("welcome missing 'next_seq'")? as u64,
            },
            "busy" => Response::Busy {
                retry_after_ms: j
                    .get("retry_after_ms")
                    .and_then(Json::as_usize)
                    .context("busy missing 'retry_after_ms'")? as u64,
            },
            "decision" => Response::Decision {
                seq: j
                    .get("seq")
                    .and_then(Json::as_usize)
                    .context("decision missing 'seq'")? as u64,
                action: DecisionAction::parse(
                    j.get("action")
                        .and_then(Json::as_str)
                        .context("decision missing 'action'")?,
                )?,
                class: j
                    .get("class")
                    .and_then(Json::as_usize)
                    .context("decision missing 'class'")?,
                p1_bits: j
                    .get("p1")
                    .and_then(Json::as_usize)
                    .context("decision missing 'p1'")? as u32,
                p2_bits: j
                    .get("p2")
                    .and_then(Json::as_usize)
                    .context("decision missing 'p2'")? as u32,
                label: j.get("label").and_then(Json::as_usize),
            },
            "shed" => Response::Shed {
                seq: j
                    .get("seq")
                    .and_then(Json::as_usize)
                    .context("shed missing 'seq'")? as u64,
                retry_after_ms: j
                    .get("retry_after_ms")
                    .and_then(Json::as_usize)
                    .context("shed missing 'retry_after_ms'")? as u64,
            },
            "decisions" => {
                let arr = match j.get("decisions") {
                    Some(Json::Arr(items)) => items,
                    _ => bail!("decisions frame missing 'decisions' array"),
                };
                let items = arr
                    .iter()
                    .map(|e| {
                        let r = Response::from_json(e)?;
                        ensure!(
                            matches!(r, Response::Decision { .. } | Response::Shed { .. }),
                            "decisions elements must be decision or shed"
                        );
                        Ok(r)
                    })
                    .collect::<Result<Vec<_>>>()?;
                Response::Decisions { items }
            }
            "pong" => Response::Pong,
            "draining" => Response::Draining,
            "error" => Response::Error {
                reason: j
                    .get("reason")
                    .and_then(Json::as_str)
                    .unwrap_or("unspecified")
                    .to_string(),
            },
            other => bail!("unknown response type '{other}'"),
        })
    }
}

fn parse_event_item(j: &Json) -> Result<EventItem> {
    Ok(EventItem {
        seq: j
            .get("seq")
            .and_then(Json::as_usize)
            .context("event missing 'seq'")? as u64,
        label: j
            .get("label")
            .and_then(Json::as_usize)
            .context("event missing 'label'")?,
        x_bits: parse_bits(j.get("x").context("event missing 'x'")?)?,
    })
}

fn parse_bits(j: &Json) -> Result<Vec<u32>> {
    let arr = j.as_arr().context("'x' must be an array of f32 bit patterns")?;
    arr.iter()
        .map(|v| {
            let n = v.as_usize().context("'x' entries must be u32 bit patterns")?;
            anyhow::ensure!(n <= u32::MAX as usize, "'x' entry {n} exceeds u32");
            Ok(n as u32)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_bits_roundtrip_exactly() {
        let xs = vec![0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, -3.25e-12, 1.0e30];
        let back = floats_of(&bits_of(&xs));
        for (a, b) in xs.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} lost bits on the wire");
        }
    }

    #[test]
    fn requests_roundtrip_through_lines() {
        let reqs = vec![
            Request::Hello { client: "edge-3".into() },
            Request::Event {
                seq: 41,
                label: 2,
                x_bits: bits_of(&[0.25, -1.75, 3.0e-7]),
            },
            Request::Ping,
            Request::Bye,
            Request::Shutdown,
        ];
        for r in reqs {
            let line = r.to_line();
            assert!(!line.contains('\n'), "lines must be newline-free: {line}");
            assert_eq!(Request::parse(&line).unwrap(), r);
        }
    }

    #[test]
    fn responses_roundtrip_through_lines() {
        let resps = vec![
            Response::Welcome { client: "edge-0".into(), restored: true, next_seq: 17 },
            Response::Busy { retry_after_ms: 50 },
            Response::Decision {
                seq: 17,
                action: DecisionAction::Trained,
                class: 4,
                p1_bits: 0.75f32.to_bits(),
                p2_bits: 0.125f32.to_bits(),
                label: Some(3),
            },
            Response::Decision {
                seq: 18,
                action: DecisionAction::Skipped,
                class: 1,
                p1_bits: 0.9f32.to_bits(),
                p2_bits: 0.05f32.to_bits(),
                label: None,
            },
            Response::Decision {
                seq: 2,
                action: DecisionAction::Duplicate,
                class: 0,
                p1_bits: 0,
                p2_bits: 0,
                label: None,
            },
            Response::Shed { seq: 99, retry_after_ms: 10 },
            Response::Pong,
            Response::Draining,
            Response::Error { reason: "bad request JSON".into() },
        ];
        for r in resps {
            let line = r.to_line();
            assert!(!line.contains('\n'));
            assert_eq!(Response::parse(&line).unwrap(), r);
        }
    }

    #[test]
    fn garbled_lines_are_rejected_not_misparsed() {
        // a garble fault corrupts bytes; the peer must get a clean error,
        // never a silently wrong message
        assert!(Request::parse("{\"type\":\"event\",\"seq\":").is_err());
        assert!(Request::parse("not json at all").is_err());
        assert!(Request::parse("{\"type\":\"warp\"}").is_err());
        assert!(Response::parse("{\"type\":\"decision\",\"seq\":1}").is_err());
        assert!(Response::parse("").is_err());
        // event with a non-integer bit pattern is refused
        assert!(Request::parse("{\"type\":\"event\",\"seq\":1,\"label\":0,\"x\":[1.5]}").is_err());
    }

    #[test]
    fn batched_frames_roundtrip_through_lines() {
        let req = Request::Events {
            items: vec![
                EventItem { seq: 7, label: 1, x_bits: bits_of(&[0.5, -2.0]) },
                EventItem { seq: 8, label: 0, x_bits: bits_of(&[1.0e-3, 4.0]) },
            ],
        };
        let line = req.to_line();
        assert!(!line.contains('\n'));
        assert_eq!(Request::parse(&line).unwrap(), req);

        let resp = Response::Decisions {
            items: vec![
                Response::Decision {
                    seq: 7,
                    action: DecisionAction::Trained,
                    class: 2,
                    p1_bits: 0.625f32.to_bits(),
                    p2_bits: 0.25f32.to_bits(),
                    label: Some(1),
                },
                Response::Decision {
                    seq: 3,
                    action: DecisionAction::Duplicate,
                    class: 0,
                    p1_bits: 0,
                    p2_bits: 0,
                    label: None,
                },
                Response::Shed { seq: 8, retry_after_ms: 5 },
            ],
        };
        let line = resp.to_line();
        assert!(!line.contains('\n'));
        assert_eq!(Response::parse(&line).unwrap(), resp);
    }

    #[test]
    fn malformed_batched_frames_are_rejected() {
        // empty batch
        assert!(Request::parse("{\"type\":\"events\",\"events\":[]}").is_err());
        // missing / non-array events field
        assert!(Request::parse("{\"type\":\"events\"}").is_err());
        assert!(Request::parse("{\"type\":\"events\",\"events\":3}").is_err());
        // one bad element poisons the whole frame
        assert!(Request::parse(
            "{\"type\":\"events\",\"events\":[{\"seq\":1,\"label\":0,\"x\":[12]},{\"seq\":2}]}"
        )
        .is_err());
        // decisions arrays may only carry decision/shed elements
        assert!(Response::parse("{\"type\":\"decisions\",\"decisions\":[{\"type\":\"pong\"}]}")
            .is_err());
        assert!(Response::parse("{\"type\":\"decisions\",\"decisions\":7}").is_err());
        // nested decisions inside decisions is out of protocol too
        assert!(Response::parse(
            "{\"type\":\"decisions\",\"decisions\":[{\"type\":\"decisions\",\"decisions\":[]}]}"
        )
        .is_err());
    }

    #[test]
    fn duplicate_ack_has_no_label() {
        let line = Response::Decision {
            seq: 5,
            action: DecisionAction::Duplicate,
            class: 0,
            p1_bits: 0,
            p2_bits: 0,
            label: None,
        }
        .to_line();
        assert!(!line.contains("label"));
        assert!(line.contains("duplicate"));
    }
}
