//! The teacher device (Figure 2(a)): "a mobile computer that has an
//! ensemble of highly accurate models".
//!
//! The paper's experiments use dataset labels as the teacher's predictions
//! (§3: "Labels of these datasets are used as teacher's predicted
//! labels"), so the default teacher is a ground-truth oracle with an
//! optional error rate. A real model ensemble (majority vote over OS-ELM
//! members trained on bootstrap resamples) is provided for the
//! teacher-quality ablation.

use crate::data::Dataset;
use crate::odl::{AlphaKind, OsElm, OsElmConfig};
use crate::util::rng::Rng64;
use crate::util::stats::argmax;

/// Which teacher implementation.
pub enum TeacherKind {
    /// Ground-truth labels with an error probability (0 = paper protocol).
    Oracle { error_rate: f64 },
    /// Majority vote of an OS-ELM ensemble trained on the training pool.
    Ensemble { members: Vec<OsElm> },
}

/// The teacher service.
pub struct Teacher {
    kind: TeacherKind,
    rng: Rng64,
    /// Service time per query [s] (inference + scheduling on the mobile).
    pub service_time_s: f64,
    pub queries_served: u64,
}

impl Teacher {
    pub fn oracle(error_rate: f64, seed: u64) -> Teacher {
        Teacher {
            kind: TeacherKind::Oracle { error_rate },
            rng: Rng64::new(seed),
            service_time_s: 0.002,
            queries_served: 0,
        }
    }

    /// Train an ensemble teacher on the given pool.
    pub fn ensemble(
        pool: &Dataset,
        n_members: usize,
        n_hidden: usize,
        seed: u64,
    ) -> anyhow::Result<Teacher> {
        let mut rng = Rng64::new(seed);
        let mut members = Vec::with_capacity(n_members);
        for k in 0..n_members {
            let cfg = OsElmConfig {
                n_in: pool.n_features(),
                n_hidden,
                n_out: pool.n_classes,
                alpha: AlphaKind::Hash,
                ..Default::default()
            };
            let mut m = OsElm::new(cfg, &mut rng, (seed as u16).wrapping_add(k as u16 * 17));
            // bootstrap resample
            let rows: Vec<usize> = (0..pool.len()).map(|_| rng.below(pool.len())).collect();
            let boot = pool.take(&rows);
            m.init_batch(&boot.xs, &boot.labels)?;
            members.push(m);
        }
        Ok(Teacher {
            kind: TeacherKind::Ensemble { members },
            rng,
            service_time_s: 0.010,
            queries_served: 0,
        })
    }

    /// Answer a label query. `true_label` feeds the oracle (and metrics);
    /// an ensemble teacher ignores it and runs its models.
    pub fn respond(&mut self, x: &[f32], true_label: usize, n_classes: usize) -> usize {
        self.queries_served += 1;
        match &mut self.kind {
            TeacherKind::Oracle { error_rate } => {
                if *error_rate > 0.0 && self.rng.bernoulli(*error_rate) {
                    let mut l = self.rng.below(n_classes - 1);
                    if l >= true_label {
                        l += 1;
                    }
                    l
                } else {
                    true_label
                }
            }
            TeacherKind::Ensemble { members } => {
                let mut votes = vec![0usize; n_classes];
                for m in members.iter_mut() {
                    votes[m.predict(x).class] += 1;
                }
                argmax(&votes.iter().map(|&v| v as f32).collect::<Vec<_>>())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthConfig, SynthHar};

    #[test]
    fn oracle_returns_truth() {
        let mut t = Teacher::oracle(0.0, 1);
        for c in 0..6 {
            assert_eq!(t.respond(&[], c, 6), c);
        }
        assert_eq!(t.queries_served, 6);
    }

    #[test]
    fn noisy_oracle_errs_at_rate() {
        let mut t = Teacher::oracle(0.3, 2);
        let n = 2000;
        let wrong = (0..n).filter(|_| t.respond(&[], 2, 6) != 2).count();
        let rate = wrong as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.04, "rate {rate}");
    }

    #[test]
    fn noisy_oracle_never_returns_out_of_range() {
        let mut t = Teacher::oracle(1.0, 3);
        for _ in 0..200 {
            let l = t.respond(&[], 5, 6);
            assert!(l < 6 && l != 5);
        }
    }

    #[test]
    fn ensemble_teacher_is_accurate() {
        let mut rng = Rng64::new(7);
        let cfg = SynthConfig {
            n_features: 40,
            n_classes: 4,
            n_subjects: 10,
            samples_per_cell: 20,
            proto_sigma: 1.1,
            confuse_frac: 0.0,
            ..Default::default()
        };
        let pool = SynthHar::new(cfg, &mut rng).generate(&mut rng);
        let mut teacher = Teacher::ensemble(&pool, 3, 64, 11).unwrap();
        let correct = (0..200)
            .filter(|&r| {
                teacher.respond(pool.xs.row(r), pool.labels[r], pool.n_classes)
                    == pool.labels[r]
            })
            .count();
        assert!(correct > 170, "ensemble accuracy {correct}/200");
    }
}
