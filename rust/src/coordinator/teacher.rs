//! The teacher device (Figure 2(a)): "a mobile computer that has an
//! ensemble of highly accurate models".
//!
//! The paper's experiments use dataset labels as the teacher's predictions
//! (§3: "Labels of these datasets are used as teacher's predicted
//! labels"), so the default teacher is a ground-truth oracle with an
//! optional error rate. A real model ensemble (majority vote over OS-ELM
//! members trained on bootstrap resamples) is provided for the
//! teacher-quality ablation.

use crate::data::Dataset;
use crate::odl::{AlphaKind, OsElm, OsElmConfig};
use crate::util::rng::Rng64;
use crate::util::stats::argmax;

/// Which teacher implementation.
pub enum TeacherKind {
    /// Ground-truth labels with an error probability (0 = paper protocol).
    Oracle { error_rate: f64 },
    /// Majority vote of an OS-ELM ensemble trained on the training pool.
    Ensemble { members: Vec<OsElm> },
}

/// The teacher service.
pub struct Teacher {
    kind: TeacherKind,
    rng: Rng64,
    /// Service time per query [s] (inference + scheduling on the mobile).
    pub service_time_s: f64,
    pub queries_served: u64,
}

impl Teacher {
    pub fn oracle(error_rate: f64, seed: u64) -> Teacher {
        Teacher {
            kind: TeacherKind::Oracle { error_rate },
            rng: Rng64::new(seed),
            service_time_s: 0.002,
            queries_served: 0,
        }
    }

    /// Train an ensemble teacher on the given pool.
    pub fn ensemble(
        pool: &Dataset,
        n_members: usize,
        n_hidden: usize,
        seed: u64,
    ) -> anyhow::Result<Teacher> {
        let mut rng = Rng64::new(seed);
        let mut members = Vec::with_capacity(n_members);
        for k in 0..n_members {
            let cfg = OsElmConfig {
                n_in: pool.n_features(),
                n_hidden,
                n_out: pool.n_classes,
                alpha: AlphaKind::Hash,
                ..Default::default()
            };
            let mut m = OsElm::new(cfg, &mut rng, (seed as u16).wrapping_add(k as u16 * 17));
            // bootstrap resample
            let rows: Vec<usize> = (0..pool.len()).map(|_| rng.below(pool.len())).collect();
            let boot = pool.take(&rows);
            m.init_batch(&boot.xs, &boot.labels)?;
            members.push(m);
        }
        Ok(Teacher {
            kind: TeacherKind::Ensemble { members },
            rng,
            service_time_s: 0.010,
            queries_served: 0,
        })
    }

    /// The oracle's raw RNG position — persisted by serve snapshots so a
    /// restored teacher continues the exact same error stream.
    pub fn rng_state(&self) -> u64 {
        self.rng.state()
    }

    /// Rebuild an oracle teacher mid-stream from snapshotted state
    /// ([`Self::rng_state`] + [`Self::queries_served`]); the continuation
    /// draws exactly what the original teacher would have drawn.
    pub fn oracle_from_state(error_rate: f64, rng_state: u64, queries_served: u64) -> Teacher {
        Teacher {
            kind: TeacherKind::Oracle { error_rate },
            rng: Rng64::from_state(rng_state),
            service_time_s: 0.002,
            queries_served,
        }
    }

    /// Answer a label query. `true_label` feeds the oracle (and metrics);
    /// an ensemble teacher ignores it and runs its models.
    pub fn respond(&mut self, x: &[f32], true_label: usize, n_classes: usize) -> usize {
        self.queries_served += 1;
        match &mut self.kind {
            TeacherKind::Oracle { error_rate } => {
                // with a single class there is no wrong label to return —
                // skip the error draw entirely (below(0) would be a
                // remainder-by-zero) but keep the bernoulli draw so the
                // stream position matches the multi-class trajectory
                if *error_rate > 0.0 && self.rng.bernoulli(*error_rate) && n_classes > 1 {
                    let mut l = self.rng.below(n_classes - 1);
                    if l >= true_label {
                        l += 1;
                    }
                    l
                } else {
                    true_label
                }
            }
            TeacherKind::Ensemble { members } => {
                let mut votes = vec![0usize; n_classes];
                for m in members.iter_mut() {
                    votes[m.predict(x).class] += 1;
                }
                argmax(&votes.iter().map(|&v| v as f32).collect::<Vec<_>>())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthConfig, SynthHar};

    #[test]
    fn oracle_returns_truth() {
        let mut t = Teacher::oracle(0.0, 1);
        for c in 0..6 {
            assert_eq!(t.respond(&[], c, 6), c);
        }
        assert_eq!(t.queries_served, 6);
    }

    #[test]
    fn noisy_oracle_errs_at_rate() {
        let mut t = Teacher::oracle(0.3, 2);
        let n = 2000;
        let wrong = (0..n).filter(|_| t.respond(&[], 2, 6) != 2).count();
        let rate = wrong as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.04, "rate {rate}");
    }

    #[test]
    fn noisy_oracle_never_returns_out_of_range() {
        let mut t = Teacher::oracle(1.0, 3);
        for _ in 0..200 {
            let l = t.respond(&[], 5, 6);
            assert!(l < 6 && l != 5);
        }
    }

    #[test]
    fn zero_error_rate_never_draws() {
        // error_rate 0.0 short-circuits before any RNG draw: the stream
        // position is untouched, so repeated runs are trivially identical
        let mut t = Teacher::oracle(0.0, 9);
        let state0 = t.rng_state();
        for c in 0..100 {
            assert_eq!(t.respond(&[], c % 4, 4), c % 4);
        }
        assert_eq!(t.rng_state(), state0, "oracle at rate 0 must not draw");
    }

    #[test]
    fn full_error_rate_is_deterministic_across_streams() {
        // error_rate 1.0: always wrong, and two teachers with the same
        // seed produce byte-identical label sequences
        let run = || -> Vec<usize> {
            let mut t = Teacher::oracle(1.0, 41);
            (0..200).map(|i| t.respond(&[], i % 6, 6)).collect()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed must replay the same stream");
        for (i, &l) in a.iter().enumerate() {
            assert!(l < 6 && l != i % 6, "rate 1.0 must always mislabel in range");
        }
    }

    #[test]
    fn single_class_oracle_cannot_mislabel() {
        // n_classes == 1: the only label is the true one — even at
        // error_rate 1.0 there is no wrong label to draw (this used to
        // panic with a remainder-by-zero in below(0))
        let mut t = Teacher::oracle(1.0, 5);
        for _ in 0..50 {
            assert_eq!(t.respond(&[], 0, 1), 0);
        }
        assert_eq!(t.queries_served, 50);
    }

    #[test]
    fn oracle_state_roundtrip_continues_stream() {
        let mut t1 = Teacher::oracle(0.35, 77);
        for i in 0..60 {
            t1.respond(&[], i % 5, 5);
        }
        let mut t2 = Teacher::oracle_from_state(0.35, t1.rng_state(), t1.queries_served);
        assert_eq!(t2.queries_served, 60);
        for i in 0..60 {
            assert_eq!(
                t1.respond(&[], i % 5, 5),
                t2.respond(&[], i % 5, 5),
                "restored teacher diverged at continuation step {i}"
            );
        }
    }

    #[test]
    fn ensemble_teacher_is_accurate() {
        let mut rng = Rng64::new(7);
        let cfg = SynthConfig {
            n_features: 40,
            n_classes: 4,
            n_subjects: 10,
            samples_per_cell: 20,
            proto_sigma: 1.1,
            confuse_frac: 0.0,
            ..Default::default()
        };
        let pool = SynthHar::new(cfg, &mut rng).generate(&mut rng);
        let mut teacher = Teacher::ensemble(&pool, 3, 64, 11).unwrap();
        let correct = (0..200)
            .filter(|&r| {
                teacher.respond(pool.xs.row(r), pool.labels[r], pool.n_classes)
                    == pool.labels[r]
            })
            .count();
        assert!(correct > 170, "ensemble accuracy {correct}/200");
    }
}
