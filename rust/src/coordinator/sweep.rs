//! Memoized, resumable scenario-sweep engine — the cross-fleet level of
//! the parallel provisioning stack (`odl-har sweep`).
//!
//! A parameter study (the paper's Fig. 3/4 and Table 3 are exactly this)
//! runs a grid of fleet scenarios. The grid spans **seven axes** — seeds ×
//! pruning thresholds × fleet sizes × detectors × hidden widths × channel
//! loss × teacher error — enumerated in one deterministic order
//! ([`SweepSpec::cells`]). Naively each cell pays the full `Fleet::new`:
//! pool generation, standardizer fit, the per-fleet shuffle, and per-edge
//! `init_batch`. This engine instead precomputes a [`SweepPlan`] and
//! executes it over the shared deterministic executor
//! ([`crate::util::parallel`]):
//!
//! 1. [`ProvisionArtifacts`] are **memoized** by
//!    [`ProvisionArtifacts::data_key`] and built **lazily** at their
//!    first-use cell — whichever worker gets there first builds under the
//!    slot lock (a pure function of the key, so any builder produces the
//!    same bits) — and **dropped at their last-use cell**, so peak memory
//!    tracks the in-flight working set, not the grid's seed count.
//! 2. The per-fleet **shuffled pool** is memoized the same way, keyed
//!    `(data key, fleet seed)` alongside the artifact memo
//!    ([`ProvisionArtifacts::shuffled_train`] is pure), with its own
//!    last-use drop point.
//! 3. Per-edge **provisioned cores** are memoized per `(data key, fleet
//!    seed, n_hidden)` —
//!    [`super::fleet::provisioned_edge_model`] is independent of
//!    `n_edges` and of every pure-simulation knob (θ, detector, channel,
//!    teacher) — so cells that differ only in fleet size (or those
//!    knobs) clone the shared cores via [`Fleet::with_edge_models`]
//!    instead of re-running `init_batch` per edge. Toggleable
//!    ([`SweepSpec::memo_edge_state`]); bitwise invisible either way.
//! 4. Cells fan over [`crate::util::parallel::parallel_map_n`] and
//!    **stream** one JSON row per cell, in cell order, into the results
//!    file (an [`OrderedSink`] reorders out-of-order completions).
//!
//! # Resume protocol
//!
//! [`resume_sweep_to_file`] (`odl-har sweep --resume`) restarts an
//! interrupted sweep: it re-derives the header (schema + cell count +
//! [`SweepPlan::grid_hash`], a fingerprint of every cell's full scenario
//! plus `record_pca` — every knob that can move an output byte) and
//! refuses to touch a file whose header doesn't match byte for byte.
//! It then keeps the longest valid prefix of completed cell rows (original
//! bytes, verbatim — a truncated trailing line from a kill mid-write is
//! discarded), re-runs only the remaining cells, and appends the stats
//! trailer. Because every cell report is deterministic, the final file is
//! **byte-identical** to an uninterrupted run; resuming an already
//! complete file verifies the trailer and writes nothing. The prefix
//! rewrite goes through a sibling temp file renamed into place before
//! new rows are appended, so a crash at any point of a resume loses at
//! most one in-flight row — never the completed prefix.
//!
//! # Shards + merge (process-level fan-out)
//!
//! [`run_shard_to_file`] (`odl-har sweep --shard I/N`) runs one of `N`
//! disjoint slices of the grid, so a big study can fan out across
//! processes or hosts. [`SweepPlan::cost_shard_ranges`] (the body behind
//! [`SweepPlan::shard_ranges`]) partitions the cell order into `N`
//! contiguous ranges by **estimated cell cost** (`n_edges × horizon` —
//! the knobs that dominate a cell's wall clock), not by cell count, so a
//! grid mixing big and small fleets still hands every shard a comparable
//! amount of work. Each cut starts at the even *cost* split and snaps to
//! a `data_key` group boundary when one lies within half a shard's cost
//! of it — shards keep whole artifact groups whenever the grid has
//! enough of them, so each shard's memo hit rate matches its slice and
//! no shard rebuilds a neighbour's artifacts. A shard file is the same stream a
//! full run writes — header, completed-cell rows carrying their
//! **global** cell indices, stats trailer — except the header carries a
//! `shard` annotation (`index`/`of`/`start`/`count`) and the trailer
//! accounts the slice, not the grid. `--shard 1/1` **is** the unsharded
//! stream, byte for byte. Shards resume independently
//! ([`resume_shard_to_file`]) under the same protocol as full runs.
//!
//! [`merge_shard_files`] (`odl-har merge`) validates a complete shard
//! set — every header byte-compared against this spec's plan, every
//! shard complete (no error rows, no missing trailer), indices `1..=N`
//! present exactly once, which makes the ranges tile the grid by
//! construction — then re-interleaves the row bytes in global cell
//! order and writes a header + stats trailer recomputed from the full
//! plan. The output is **byte-identical** to a single-process
//! [`run_sweep_to_file`] over the same spec, from any complete shard
//! set, in any argument order, for any `N`.
//!
//! # Failure domain
//!
//! The contract extends through failures (see `rust/RELIABILITY.md`):
//! the prefix rewrite and the merge publish fsync their temp file **and
//! its parent directory** around the rename, so a power loss cannot
//! surface an empty or stale results file; the resume prefix scan reads
//! raw bytes and treats a trailing line with a partial UTF-8 sequence or
//! interleaved NULs (a torn write) as a discardable partial row; a
//! worker-cell panic is caught per cell
//! ([`crate::util::parallel::parallel_map_n_caught`]), retried once, and
//! only then recorded as a structured error row — the pool survives. A
//! [`FaultPlan`](crate::util::faults::FaultPlan) threads these failure
//! paths deterministically through the `*_with_faults` entry points
//! (`odl-har sweep --inject-faults`); the empty plan is a no-op. The
//! shard supervisor ([`super::supervise`]) drives sharded runs through
//! crash/hang/retry cycles on top of these primitives.
//!
//! Determinism contract: each cell's `FleetReport` is **bitwise
//! identical** to the report of an individually constructed
//! `Fleet::new(cfg).run()` for the same scenario — memoization, lazy
//! builds, drop points, the worker pool, resume, and every injected or
//! organic failure above are wall-clock/memory knobs, never numerics
//! knobs. Asserted by the in-module tests and re-checked by
//! `benches/bench_sweep.rs` before it times anything.

use super::channel::ChannelConfig;
use super::fleet::{
    provisioned_edge_model, DetectorKind, Fleet, FleetConfig, ProvisionArtifacts, Scenario,
};
use super::metrics::{FleetReport, MetricsMode};
use crate::data::Dataset;
use crate::odl::OsElm;
use crate::storage::{key_for_path, pull_to_file, push_from_file, Storage};
use crate::util::faults::{self, FaultKind, FaultPlan};
use crate::util::json::{obj, Json};
use crate::util::parallel;
use crate::util::rng::hash_fold;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::io::Write;
use std::ops::Range;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Results-file schema tag. v2 added the `n_hidden` / `loss_prob` /
/// `teacher_error` axes and the `grid_hash` resume fingerprint, and
/// dropped the worker count from the header (the stream is a pure
/// function of the spec; worker counts are wall-clock knobs and a resume
/// may legitimately use a different count than the original run). v3
/// added the shard annotation to sharded headers and the edge-state memo
/// ledger (`edge_builds` / `edge_hits`) to the stats trailer. v4
/// switched the shard partitioner to cost-weighted cuts — the stream
/// layout is unchanged, but a shard header's `start`/`count` for a given
/// grid can differ from v3's, so v3 shard files must not be resumed or
/// merged under v4 semantics (the header byte-compare refuses them). v5
/// switched the cost model's horizon weighting from whole seconds to
/// integer milliseconds — the stream layout is again unchanged, but
/// cost-weighted cuts (and so a shard header's `start`/`count`) can
/// differ from v4's on fractional-horizon grids, so cross-version
/// resumes/merges are refused the same way.
const SCHEMA: &str = "odl-har-sweep/v5";

/// A declared scenario grid. Every axis left at its one-element default
/// degenerates to the base scenario's value, so a sweep with only
/// `seeds = [...]` is a plain seed study.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Base scenario; each cell clones and overrides it.
    pub base: Scenario,
    /// Simulation seeds.
    pub seeds: Vec<u64>,
    /// Pruning thresholds; `None` = the auto-θ ladder.
    pub thetas: Vec<Option<f32>>,
    /// Fleet sizes.
    pub edge_counts: Vec<usize>,
    /// Drift detectors.
    pub detectors: Vec<DetectorKind>,
    /// Hidden-layer widths (the model-capacity axis).
    pub n_hiddens: Vec<usize>,
    /// Channel loss probabilities (the connectivity axis).
    pub loss_probs: Vec<f64>,
    /// Teacher label-error rates (the supervision-quality axis).
    pub teacher_errors: Vec<f64>,
    /// Cross-cell worker threads (0 = auto via
    /// [`crate::util::auto_workers`]; resolve before calling the engine).
    pub workers: usize,
    /// Fit the optional PCA summary per data config and record its
    /// eigenvalues in the results rows.
    pub record_pca: bool,
    /// Memoize provisioned per-edge cores across cells that share
    /// `(data key, fleet seed, n_hidden)` — on by default; off re-runs
    /// `init_batch` per cell per edge (the pre-memo behaviour). Bitwise
    /// invisible in every cell report either way; only the stats
    /// trailer's edge ledger (and the wall clock) moves.
    pub memo_edge_state: bool,
}

impl Default for SweepSpec {
    fn default() -> Self {
        let base = Scenario::default();
        SweepSpec {
            seeds: vec![1],
            thetas: vec![base.fixed_theta],
            edge_counts: vec![base.n_edges],
            detectors: vec![base.detector],
            n_hiddens: vec![base.n_hidden],
            loss_probs: vec![base.channel.loss_prob],
            teacher_errors: vec![base.teacher_error],
            workers: 1,
            record_pca: false,
            memo_edge_state: true,
            base,
        }
    }
}

/// One grid coordinate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SweepCell {
    pub index: usize,
    pub seed: u64,
    pub theta: Option<f32>,
    pub n_edges: usize,
    pub detector: DetectorKind,
    pub n_hidden: usize,
    pub loss_prob: f64,
    pub teacher_error: f64,
}

impl SweepSpec {
    /// Materialize the grid in its one deterministic order: seeds →
    /// thetas → edge counts → detectors → hidden widths → loss probs →
    /// teacher errors (first axis slowest, last axis fastest).
    pub fn cells(&self) -> Vec<(SweepCell, Scenario)> {
        let mut out = Vec::with_capacity(
            self.seeds.len()
                * self.thetas.len()
                * self.edge_counts.len()
                * self.detectors.len()
                * self.n_hiddens.len()
                * self.loss_probs.len()
                * self.teacher_errors.len(),
        );
        for &seed in &self.seeds {
            for &theta in &self.thetas {
                for &n_edges in &self.edge_counts {
                    for &detector in &self.detectors {
                        for &n_hidden in &self.n_hiddens {
                            for &loss_prob in &self.loss_probs {
                                for &teacher_error in &self.teacher_errors {
                                    let mut sc = self.base.clone();
                                    sc.fixed_theta = theta;
                                    sc.n_edges = n_edges;
                                    sc.detector = detector;
                                    sc.n_hidden = n_hidden;
                                    sc.channel.loss_prob = loss_prob;
                                    sc.teacher_error = teacher_error;
                                    out.push((
                                        SweepCell {
                                            index: out.len(),
                                            seed,
                                            theta,
                                            n_edges,
                                            detector,
                                            n_hidden,
                                            loss_prob,
                                            teacher_error,
                                        },
                                        sc,
                                    ));
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Precompute the execution plan: cell enumeration, memo slots,
    /// artifact/shuffle/edge-state lifetimes, the memo ledger, and the
    /// grid fingerprint. `run_sweep*`, the shard engine, and `odl-har
    /// sweep --dry-run` share this.
    pub fn plan(&self) -> SweepPlan {
        let cells = self.cells();
        let mut artifacts: Vec<ArtifactPlan> = Vec::new();
        let mut cell_slots = Vec::with_capacity(cells.len());
        // O(1) key → slot lookups (lookup only, never iterated, so map
        // order cannot touch the plan): a derived-data-seed study has one
        // artifact group per seed, and linear slot scans would make
        // planning quadratic in the seed count — plan() runs in every
        // shard process, in merge, and in --dry-run
        let mut slot_by_key: HashMap<u64, usize> = HashMap::new();
        let mut shuf_by_key: HashMap<(usize, u64), usize> = HashMap::new();
        let mut est_by_key: HashMap<(usize, usize, usize), usize> = HashMap::new();
        // record_pca changes row bytes (pca_eigenvalues) and
        // memo_edge_state changes the trailer's edge ledger — both belong
        // in the fingerprint alongside every cell's scenario
        let mut grid = hash_fold(
            hash_fold(
                hash_fold(0x6B1D, cells.len() as u64),
                self.record_pca as u64,
            ),
            self.memo_edge_state as u64,
        );
        for (i, (cell, sc)) in cells.iter().enumerate() {
            grid = hash_fold(grid, scenario_fingerprint(sc, cell.seed));
            let key = ProvisionArtifacts::data_key(sc, cell.seed);
            let slot = match slot_by_key.get(&key) {
                Some(&slot) => {
                    let a = &mut artifacts[slot];
                    a.last_cell = i;
                    a.uses += 1;
                    slot
                }
                None => {
                    artifacts.push(ArtifactPlan {
                        key,
                        first_cell: i,
                        last_cell: i,
                        uses: 1,
                        shuffles: Vec::new(),
                    });
                    slot_by_key.insert(key, artifacts.len() - 1);
                    artifacts.len() - 1
                }
            };
            let a = &mut artifacts[slot];
            let shuf = match shuf_by_key.get(&(slot, cell.seed)) {
                Some(&shuf) => {
                    let s = &mut a.shuffles[shuf];
                    s.last_cell = i;
                    s.uses += 1;
                    shuf
                }
                None => {
                    a.shuffles.push(ShufflePlan {
                        seed: cell.seed,
                        first_cell: i,
                        last_cell: i,
                        uses: 1,
                        edge_states: Vec::new(),
                    });
                    shuf_by_key.insert((slot, cell.seed), a.shuffles.len() - 1);
                    a.shuffles.len() - 1
                }
            };
            let s = &mut a.shuffles[shuf];
            let est = match est_by_key.get(&(slot, shuf, cell.n_hidden)) {
                Some(&est) => {
                    let e = &mut s.edge_states[est];
                    e.last_cell = i;
                    e.max_edges = e.max_edges.max(cell.n_edges);
                    e.edge_uses += cell.n_edges;
                    est
                }
                None => {
                    s.edge_states.push(EdgeStatePlan {
                        n_hidden: cell.n_hidden,
                        first_cell: i,
                        last_cell: i,
                        max_edges: cell.n_edges,
                        edge_uses: cell.n_edges,
                    });
                    est_by_key.insert((slot, shuf, cell.n_hidden), s.edge_states.len() - 1);
                    s.edge_states.len() - 1
                }
            };
            cell_slots.push((slot, shuf, est));
        }
        let mut plan = SweepPlan {
            cells,
            artifacts,
            cell_slots,
            stats: SweepStats::default(),
            grid_hash: grid,
            memo_edge_state: self.memo_edge_state,
        };
        let stats = plan.range_stats(0..plan.cells.len());
        plan.stats = stats;
        plan
    }
}

/// One slice of a sharded sweep: shard `index` of `of`, 1-based (the CLI
/// form `--shard 2/3`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub of: usize,
}

impl ShardSpec {
    /// The degenerate whole-grid shard. Its stream is defined as the
    /// unsharded stream — `--shard 1/1` is byte-identical to no `--shard`
    /// flag at all.
    pub const WHOLE: ShardSpec = ShardSpec { index: 1, of: 1 };

    /// Parse the CLI form `I/N` (1-based, `1 <= I <= N`).
    pub fn parse(s: &str) -> Result<ShardSpec> {
        let (i, n) = s
            .split_once('/')
            .with_context(|| format!("--shard wants I/N (e.g. 2/3), got '{s}'"))?;
        let index: usize = i
            .trim()
            .parse()
            .with_context(|| format!("bad shard index in '{s}'"))?;
        let of: usize = n
            .trim()
            .parse()
            .with_context(|| format!("bad shard count in '{s}'"))?;
        ensure!(of >= 1, "shard count must be >= 1, got '{s}'");
        ensure!(
            (1..=of).contains(&index),
            "shard index {index} outside 1..={of}"
        );
        Ok(ShardSpec { index, of })
    }

    fn is_whole(self) -> bool {
        self.of == 1
    }
}

/// Identity hash of one cell's full scenario under its simulation seed —
/// every field that can move a report bit. Exhaustive destructuring (no
/// `..` rest pattern): adding a `Scenario` field without extending this
/// hash is a compile error, not a silent resume-compatibility hole.
fn scenario_fingerprint(sc: &Scenario, seed: u64) -> u64 {
    let Scenario {
        n_edges,
        n_hidden,
        event_period_s,
        horizon_s,
        drift_at_s,
        detector,
        fixed_theta,
        teacher_error,
        channel,
        synth: _, // covered (with the resolved data seed) by data_key below
        train_target,
        eval_period_s,
        eval_samples,
        eval_costs_power,
        data_seed,
        metrics,
    } = sc;
    let ChannelConfig {
        latency_s,
        loss_prob,
        max_retries,
    } = channel;
    let detector_tag = match detector {
        DetectorKind::Oracle => 1u64,
        DetectorKind::Centroid => 2,
    };
    let mut k = 0x5EE9_u64;
    for v in [
        seed,
        *n_edges as u64,
        *n_hidden as u64,
        event_period_s.to_bits(),
        horizon_s.to_bits(),
        drift_at_s.to_bits(),
        detector_tag,
        fixed_theta.is_some() as u64,
        fixed_theta.unwrap_or(0.0).to_bits() as u64,
        teacher_error.to_bits(),
        latency_s.to_bits(),
        loss_prob.to_bits(),
        *max_retries as u64,
        *train_target as u64,
        eval_period_s.to_bits(),
        *eval_samples as u64,
        *eval_costs_power as u64,
        data_seed.is_some() as u64,
        data_seed.unwrap_or(0),
        ProvisionArtifacts::data_key(sc, seed),
    ] {
        k = hash_fold(k, v);
    }
    // metrics is a reporting-memory knob, not a trajectory knob: full-mode
    // cells keep their pre-aggregate fingerprints (resume compatibility
    // with existing result files), aggregate cells fold a distinct tag so
    // the two row shapes never collide in one file.
    if *metrics == MetricsMode::Aggregate {
        k = hash_fold(k, 0xA66);
    }
    k
}

/// Memoization accounting, computed from the plan (never from execution,
/// so a resumed run — or a shard — reports the same ledger an
/// uninterrupted run over the same slice would):
/// `artifact_builds + artifact_hits == cells`,
/// `shuffle_builds + shuffle_hits == cells`, and
/// `edge_builds + edge_hits == Σ n_edges` over the accounted cells
/// (edge-state accounting is per provisioned *core*, not per cell;
/// with the memo off every core is a build).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepStats {
    pub cells: usize,
    pub artifact_builds: usize,
    pub artifact_hits: usize,
    pub shuffle_builds: usize,
    pub shuffle_hits: usize,
    pub edge_builds: usize,
    pub edge_hits: usize,
}

/// Lifetime plan for one memoized artifact slot: built lazily at
/// `first_cell`, lent to `uses` cells, dropped when the cell at
/// `last_cell` finishes.
#[derive(Clone, Debug)]
pub struct ArtifactPlan {
    pub key: u64,
    pub first_cell: usize,
    pub last_cell: usize,
    pub uses: usize,
    /// Per-`(slot, fleet seed)` shuffled-pool memo, in first-use order.
    pub shuffles: Vec<ShufflePlan>,
}

/// Lifetime plan for one memoized shuffled pool (keyed by the fleet seed
/// within its artifact slot).
#[derive(Clone, Debug)]
pub struct ShufflePlan {
    pub seed: u64,
    pub first_cell: usize,
    pub last_cell: usize,
    pub uses: usize,
    /// Per-`(slot, seed, n_hidden)` provisioned-core memo, in first-use
    /// order.
    pub edge_states: Vec<EdgeStatePlan>,
}

/// Lifetime plan for one memoized set of provisioned edge cores (keyed
/// by `n_hidden` within its `(artifact, seed)` shuffle slot — the only
/// scenario knob besides the data config and fleet seed that a
/// provisioned core depends on). Grown lazily in edge-id order up to
/// `max_edges`, lent to every cell of the key, dropped when the cell at
/// `last_cell` finishes.
#[derive(Clone, Debug)]
pub struct EdgeStatePlan {
    pub n_hidden: usize,
    pub first_cell: usize,
    pub last_cell: usize,
    /// Largest fleet among the key's cells = cores built (memo on).
    pub max_edges: usize,
    /// Σ `n_edges` over the key's cells = cores lent out.
    pub edge_uses: usize,
}

/// First/last use and lend count of one memo entry within a slice of the
/// grid (see [`SweepPlan::slice_lifetimes`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemoLife {
    pub first: usize,
    pub last: usize,
    /// Cells lent to (artifacts/shuffles) or cores lent out (edge
    /// states).
    pub uses: usize,
}

impl MemoLife {
    fn at(i: usize, uses: usize) -> MemoLife {
        MemoLife { first: i, last: i, uses }
    }

    fn touch(&mut self, i: usize, uses: usize) {
        self.last = i;
        self.uses += uses;
    }
}

/// Slice-local lifetimes of every memo entry a cell range touches —
/// keyed by the same (artifact slot, shuffle slot, edge-state slot)
/// coordinates as [`SweepPlan::cell_slots`]; edge-state entries also
/// carry the slice-local largest fleet (= cores built when the memo is
/// on).
pub struct SliceLifetimes {
    pub artifacts: BTreeMap<usize, MemoLife>,
    pub shuffles: BTreeMap<(usize, usize), MemoLife>,
    pub edge_states: BTreeMap<(usize, usize, usize), (MemoLife, usize)>,
}

/// The precomputed execution plan shared by the engine, the shard
/// partitioner, and `--dry-run`.
pub struct SweepPlan {
    pub cells: Vec<(SweepCell, Scenario)>,
    pub artifacts: Vec<ArtifactPlan>,
    /// cell index → (artifact slot, shuffle slot within that artifact,
    /// edge-state slot within that shuffle).
    pub cell_slots: Vec<(usize, usize, usize)>,
    pub stats: SweepStats,
    /// Fingerprint of the enumerated grid (every cell's full scenario,
    /// plus `record_pca` and `memo_edge_state`); the resume header's
    /// compatibility check.
    pub grid_hash: u64,
    /// Whether the edge-state memo is active (it moves the trailer's
    /// edge ledger, so it is part of the fingerprint).
    pub memo_edge_state: bool,
}

impl SweepPlan {
    /// Memoization accounting restricted to the cells of `range` — what
    /// executing exactly that slice builds and hits (the full-grid stats
    /// are `range_stats(0..cells.len())`). Plan-derived, never
    /// execution-derived, so shard trailers and resumed runs report the
    /// numbers an uninterrupted run over the same slice would.
    pub fn range_stats(&self, range: Range<usize>) -> SweepStats {
        let cells = range.len();
        let lt = self.slice_lifetimes(range);
        let edge_uses: usize = lt.edge_states.values().map(|(l, _)| l.uses).sum();
        let edge_builds = if self.memo_edge_state {
            // each key builds up to its slice-local largest fleet once
            lt.edge_states.values().map(|(_, max_edges)| *max_edges).sum()
        } else {
            edge_uses
        };
        SweepStats {
            cells,
            artifact_builds: lt.artifacts.len(),
            artifact_hits: cells - lt.artifacts.len(),
            shuffle_builds: lt.shuffles.len(),
            shuffle_hits: cells - lt.shuffles.len(),
            edge_builds,
            edge_hits: edge_uses - edge_builds,
        }
    }

    /// Slice-local memo lifetimes: first/last use and lend counts of every
    /// artifact / shuffle / edge-state entry touched by the cells of
    /// `range`. This is exactly what `run_cells` over that slice builds
    /// and drops (its remaining-use counts are slice-restricted), so the
    /// `--dry-run` display and [`Self::range_stats`] both derive from it —
    /// one source of truth for the lifetime semantics.
    pub fn slice_lifetimes(&self, range: Range<usize>) -> SliceLifetimes {
        let mut lt = SliceLifetimes {
            artifacts: BTreeMap::new(),
            shuffles: BTreeMap::new(),
            edge_states: BTreeMap::new(),
        };
        for i in range {
            let (slot, shuf, est) = self.cell_slots[i];
            let n_edges = self.cells[i].0.n_edges;
            lt.artifacts
                .entry(slot)
                .and_modify(|l| l.touch(i, 1))
                .or_insert(MemoLife::at(i, 1));
            lt.shuffles
                .entry((slot, shuf))
                .and_modify(|l| l.touch(i, 1))
                .or_insert(MemoLife::at(i, 1));
            lt.edge_states
                .entry((slot, shuf, est))
                .and_modify(|(l, max_edges)| {
                    l.touch(i, n_edges);
                    *max_edges = (*max_edges).max(n_edges);
                })
                .or_insert((MemoLife::at(i, n_edges), n_edges));
        }
        lt
    }

    /// Estimated execution cost of cell `i`: fleet size × simulated
    /// horizon, the two knobs that dominate a cell's wall clock (every
    /// edge steps through every simulated second). Only the *ratios*
    /// matter to the partitioner, so the estimate being in arbitrary
    /// units is fine; it must merely be deterministic. The horizon is
    /// weighted in integer **milliseconds**: truncating to whole seconds
    /// made 1.0s and 1.9s weigh identically and collapsed sub-second
    /// grids to uniform cost, skewing [`Self::cost_shard_ranges`] cuts.
    pub fn cell_cost(&self, i: usize) -> u64 {
        let (cell, sc) = &self.cells[i];
        let horizon_ms = (sc.horizon_s.max(0.0) * 1000.0).round() as u64;
        (cell.n_edges as u64).max(1) * horizon_ms.max(1)
    }

    /// Partition the cell order into `of` disjoint, contiguous,
    /// artifact-locality-aware ranges (the `--shard I/N` split),
    /// balanced by [`Self::cell_cost`] rather than cell count — a grid
    /// mixing 2-edge and 64-edge fleets hands every shard a comparable
    /// amount of *work*, not a comparable number of cells. Cut points
    /// start at the even cost split and snap to the nearest `data_key`
    /// group boundary within half an ideal shard's cost, so shards keep
    /// whole artifact groups whenever the grid has at least `of` of
    /// them — each shard's memo hit rate then matches its slice, and no
    /// shard rebuilds a neighbour's artifacts. Every cell lands in
    /// exactly one range; the ranges concatenate to `0..cells.len()` in
    /// order (so every shard's cell order is a subsequence of the global
    /// order); `of = 1` returns the whole grid.
    pub fn cost_shard_ranges(&self, of: usize) -> Vec<Range<usize>> {
        let n = self.cells.len();
        let of = of.max(1);
        // prefix cost sums: w[i] = total cost of cells 0..i (u128 so a
        // huge grid of huge fleets cannot overflow the running sum)
        let mut w = Vec::with_capacity(n + 1);
        w.push(0u128);
        for i in 0..n {
            let last = *w.last().expect("w starts non-empty");
            w.push(last + self.cell_cost(i) as u128);
        }
        let total = w[n];
        // artifact-group boundaries: the preferred cut candidates
        let mut bounds = vec![0usize];
        for i in 1..n {
            if self.cell_slots[i].0 != self.cell_slots[i - 1].0 {
                bounds.push(i);
            }
        }
        bounds.push(n);
        let mut cuts = Vec::with_capacity(of + 1);
        cuts.push(0usize);
        for k in 1..of {
            let prev = *cuts.last().expect("cuts start non-empty");
            let cut = if total == 0 {
                // degenerate zero-cost grid (n = 0): even cell-count split
                (k * n + of / 2) / of
            } else {
                let target = (k as u128 * total + of as u128 / 2) / of as u128;
                // snap to a group boundary when one is within half an
                // ideal shard's cost of the even split; otherwise cut
                // mid-group at the cell edge nearest the cost target (a
                // single huge group must still split to keep the shards
                // busy). Only boundaries strictly past the previous cut
                // are candidates — two cuts snapping onto the same
                // boundary would starve a shard while its neighbours
                // carry double load.
                let tol = total / (2 * of as u128);
                let dist = |b: usize| w[b].abs_diff(target);
                bounds
                    .iter()
                    .copied()
                    .filter(|b| *b > prev)
                    .min_by_key(|b| dist(*b))
                    .filter(|b| dist(*b) <= tol)
                    .unwrap_or_else(|| (prev + 1..=n).min_by_key(|b| dist(*b)).unwrap_or(n))
            };
            cuts.push(cut.max(prev));
        }
        cuts.push(n);
        (0..of).map(|k| cuts[k]..cuts[k + 1]).collect()
    }

    /// [`Self::cost_shard_ranges`] — the one shard partition every
    /// consumer (headers, resume, merge, the supervisor) agrees on.
    pub fn shard_ranges(&self, of: usize) -> Vec<Range<usize>> {
        self.cost_shard_ranges(of)
    }

    /// The cell range shard `shard` owns under this plan.
    pub fn shard_range(&self, shard: ShardSpec) -> Result<Range<usize>> {
        ensure!(
            shard.of >= 1 && (1..=shard.of).contains(&shard.index),
            "invalid shard {}/{}",
            shard.index,
            shard.of
        );
        Ok(self.shard_ranges(shard.of).swap_remove(shard.index - 1))
    }
}

/// The engine's result: per-cell reports in cell order plus the
/// memoization ledger.
pub struct SweepOutcome {
    pub reports: Vec<(SweepCell, FleetReport)>,
    pub stats: SweepStats,
}

/// Outcome of [`resume_sweep_to_file`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResumeOutcome {
    /// Completed cells kept from the existing file (original bytes).
    pub skipped: usize,
    /// Cells (re-)run by this invocation.
    pub ran: usize,
    /// The file already held the full grid plus trailer; nothing was
    /// written.
    pub already_complete: bool,
    pub stats: SweepStats,
}

/// Re-orders out-of-order line completions so the output stream is written
/// strictly in slot order regardless of worker scheduling. Carries the
/// run's [`FaultPlan`]: write faults key on the *slot* a line drains
/// into, so an injected tear/kill/ioerr lands at a deterministic stream
/// position no matter how workers interleave.
struct OrderedSink<W: Write> {
    next: usize,
    pending: BTreeMap<usize, String>,
    out: W,
    faults: FaultPlan,
}

impl<W: Write> OrderedSink<W> {
    fn new(out: W) -> Self {
        OrderedSink::starting_at(out, 0)
    }

    /// A sink whose first expected slot is `next` — the resume path seeds
    /// it past the header and the kept prefix rows.
    fn starting_at(out: W, next: usize) -> Self {
        OrderedSink {
            next,
            pending: BTreeMap::new(),
            out,
            faults: FaultPlan::default(),
        }
    }

    fn with_faults(mut self, faults: &FaultPlan) -> Self {
        self.faults = faults.clone();
        self
    }

    fn push(&mut self, index: usize, line: String) -> std::io::Result<()> {
        self.pending.insert(index, line);
        let mut wrote = false;
        while let Some(line) = self.pending.remove(&self.next) {
            let fault = if self.faults.is_noop() {
                None
            } else {
                self.faults.write_fault(self.next)
            };
            match fault {
                Some(FaultKind::IoErr) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::Other,
                        format!("injected I/O error at results slot {}", self.next),
                    ));
                }
                Some(FaultKind::Tear) => {
                    // torn write: a prefix of the correct bytes, no
                    // newline, then die — resume must discard the partial
                    // trailing line
                    let bytes = line.as_bytes();
                    let cut = (bytes.len() / 2).max(1);
                    self.out.write_all(&bytes[..cut])?;
                    self.out.flush()?;
                    faults::die(&format!("torn write at results slot {}", self.next));
                }
                _ => {}
            }
            self.out.write_all(line.as_bytes())?;
            self.out.write_all(b"\n")?;
            self.next += 1;
            wrote = true;
            match fault {
                // a "kill" lands after a fully flushed row — the
                // in-process stand-in for an external SIGKILL between rows
                Some(FaultKind::Kill) => {
                    self.out.flush()?;
                    faults::die(&format!("killed after results slot {}", self.next - 1));
                }
                Some(FaultKind::Hang) => {
                    self.out.flush()?;
                    faults::hang(&format!("hung after results slot {}", self.next - 1));
                }
                _ => {}
            }
        }
        // flush only when a line actually drained — keeps tail -f
        // streaming without paying a syscall for buffered-only pushes
        if wrote {
            self.out.flush()?;
        }
        Ok(())
    }
}

/// The per-cell results row: grid coordinates + a `FleetReport` rollup.
/// Aggregate-mode cells (`fleet.metrics = "aggregate"`) have no per-edge
/// rows; their rollup comes from the report's [`FleetAggregate`] and the
/// row additionally carries `metrics`/`sketches` keys. Full-mode rows are
/// byte-identical to the pre-aggregate schema (keys are only *added*, and
/// only in aggregate mode).
pub fn cell_row(cell: &SweepCell, report: &FleetReport, artifacts: &ProvisionArtifacts) -> Json {
    let agg = report.aggregate.as_ref();
    let edges = report.per_edge.len().max(1) as f64;
    // Mean of the last rolling-accuracy checkpoint over the edges that
    // have one (traces checkpoint every 50 predictions, so short horizons
    // may leave some — or all — edges without a reading; averaging those
    // in as 0.0 would skew the rollup). Null when no edge has reported.
    // Aggregate mode keeps the same reading per edge, but as a streaming
    // quantile sketch — the row reports its p50 instead of the mean.
    let acc_readings: Vec<f64> = report
        .per_edge
        .iter()
        .filter_map(|m| m.accuracy_trace.last().map(|&(_, a)| a))
        .collect();
    let final_acc = match agg {
        Some(a) if a.accuracy.count() > 0 => Json::Num(a.accuracy.p50()),
        Some(_) => Json::Null,
        None if acc_readings.is_empty() => Json::Null,
        None => Json::Num(acc_readings.iter().sum::<f64>() / acc_readings.len() as f64),
    };
    // comm_fraction needs per-edge radio/active splits the aggregate does
    // not carry — Null, not a fake 0.0, in aggregate mode
    let comm = match agg {
        Some(_) => Json::Null,
        None => Json::Num(
            report.per_edge.iter().map(|m| m.comm_fraction()).sum::<f64>() / edges,
        ),
    };
    let trained: u64 = match agg {
        Some(a) => a.trained,
        None => report.per_edge.iter().map(|m| m.trained).sum(),
    };
    let mut pairs = vec![
        ("cell", Json::Num(cell.index as f64)),
        ("seed", Json::Num(cell.seed as f64)),
        (
            "theta",
            match cell.theta {
                Some(t) => Json::Num(t as f64),
                None => Json::Str("auto".into()),
            },
        ),
        ("n_edges", Json::Num(cell.n_edges as f64)),
        ("detector", Json::Str(cell.detector.name().into())),
        ("n_hidden", Json::Num(cell.n_hidden as f64)),
        ("loss_prob", Json::Num(cell.loss_prob)),
        ("teacher_error", Json::Num(cell.teacher_error)),
        ("data_key", Json::Str(format!("{:016x}", artifacts.key))),
        ("queries", Json::Num(report.total_queries() as f64)),
        ("trained", Json::Num(trained as f64)),
        ("teacher_queries", Json::Num(report.teacher_queries as f64)),
        ("channel_attempts", Json::Num(report.channel_attempts as f64)),
        ("channel_failures", Json::Num(report.channel_failures as f64)),
        ("comm_fraction", comm),
        ("final_accuracy", final_acc),
        ("mean_edge_power_mw", Json::Num(report.mean_edge_power_mw())),
        ("total_energy_mj", Json::Num(report.total_energy_mj())),
    ];
    if let Some(a) = agg {
        // NaN quantiles (empty sketch) serialize as Null, not "NaN"
        let num = |v: f64| if v.is_nan() { Json::Null } else { Json::Num(v) };
        pairs.push(("metrics", Json::Str("aggregate".into())));
        pairs.push((
            "sketches",
            obj(vec![
                ("accuracy_p50", num(a.accuracy.p50())),
                ("accuracy_p90", num(a.accuracy.p90())),
                ("accuracy_p99", num(a.accuracy.p99())),
                ("power_mw_p50", num(a.power_mw.p50())),
                ("power_mw_p90", num(a.power_mw.p90())),
                ("power_mw_p99", num(a.power_mw.p99())),
                ("queries_p50", num(a.queries.p50())),
                ("queries_p90", num(a.queries.p90())),
                ("queries_p99", num(a.queries.p99())),
                ("distinct_edge_states", Json::Num(a.edge_states.estimate())),
                ("distinct_visited_cells", Json::Num(a.visited_cells.estimate())),
                ("events", Json::Num(a.events as f64)),
                ("mode_switches", Json::Num(a.mode_switches as f64)),
                ("query_failures", Json::Num(a.query_failures as f64)),
                ("skips", Json::Num(a.skips as f64)),
            ]),
        ));
    }
    if let Some(pca) = &artifacts.pca {
        pairs.push((
            "pca_eigenvalues",
            Json::Arr(pca.eigenvalues.iter().map(|&e| Json::Num(e as f64)).collect()),
        ));
    }
    obj(pairs)
}

/// The stream header: schema + total cell count + grid fingerprint, plus
/// the shard annotation (`index`/`of`/`start`/`count`) when the stream is
/// a real slice. Shard 1/1 writes the unsharded header — that is what
/// makes `--shard 1/1` byte-identical to no `--shard` flag.
fn header_json(plan: &SweepPlan, shard: ShardSpec) -> Json {
    let mut pairs = vec![
        ("schema", Json::Str(SCHEMA.into())),
        ("cells", Json::Num(plan.cells.len() as f64)),
        ("grid_hash", Json::Str(format!("{:016x}", plan.grid_hash))),
    ];
    // schema note: aggregate-mode rows drop per-edge-derived fields
    // (comm_fraction is null) and carry `metrics` + `sketches` keys.
    // Full-mode headers are byte-identical to pre-aggregate streams.
    if plan
        .cells
        .first()
        .map_or(false, |(_, sc)| sc.metrics == MetricsMode::Aggregate)
    {
        pairs.push(("metrics", Json::Str("aggregate".into())));
    }
    if !shard.is_whole() {
        // every caller validates the shard before writing a header
        let range = plan
            .shard_range(shard)
            .expect("header_json: shard validated by caller");
        pairs.push((
            "shard",
            obj(vec![
                ("index", Json::Num(shard.index as f64)),
                ("of", Json::Num(shard.of as f64)),
                ("start", Json::Num(range.start as f64)),
                ("count", Json::Num(range.len() as f64)),
            ]),
        ));
    }
    obj(pairs)
}

fn trailer_json(stats: &SweepStats) -> Json {
    obj(vec![(
        "stats",
        obj(vec![
            ("cells", Json::Num(stats.cells as f64)),
            ("artifact_builds", Json::Num(stats.artifact_builds as f64)),
            ("artifact_hits", Json::Num(stats.artifact_hits as f64)),
            ("shuffle_builds", Json::Num(stats.shuffle_builds as f64)),
            ("shuffle_hits", Json::Num(stats.shuffle_hits as f64)),
            ("edge_builds", Json::Num(stats.edge_builds as f64)),
            ("edge_hits", Json::Num(stats.edge_hits as f64)),
        ]),
    )])
}

/// Run the grid with memoized artifacts; collect reports only (no file).
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepOutcome> {
    let plan = spec.plan();
    let n = plan.cells.len();
    let reports = run_cells::<std::io::Sink>(spec, &plan, 0..n, 0, None, &FaultPlan::default())?;
    Ok(SweepOutcome {
        reports,
        stats: plan.stats,
    })
}

/// Run the grid, streaming one JSON row per cell (in cell order) into
/// `path` — a header line, the cell rows, and a stats trailer, one JSON
/// object per line.
pub fn run_sweep_to_file(spec: &SweepSpec, path: &Path) -> Result<SweepOutcome> {
    run_planned_to_file(spec, &spec.plan(), path)
}

/// [`run_sweep_to_file`] over an already-computed plan — for callers
/// (the CLI banner/dry-run, the resume path) that hold one anyway;
/// planning a large grid twice is pure waste. `plan` must come from
/// `spec.plan()`.
pub fn run_planned_to_file(spec: &SweepSpec, plan: &SweepPlan, path: &Path) -> Result<SweepOutcome> {
    run_shard_to_file(spec, plan, ShardSpec::WHOLE, path)
}

/// Run one shard of the grid (`odl-har sweep --shard I/N`), streaming
/// its slice of cell rows into `path`: the shard-annotated header, the
/// slice's rows (global cell indices, byte-identical to the rows a
/// single-process run writes), and a trailer accounting the slice.
/// Returns exactly the slice's reports and stats. `plan` must come from
/// `spec.plan()`.
pub fn run_shard_to_file(
    spec: &SweepSpec,
    plan: &SweepPlan,
    shard: ShardSpec,
    path: &Path,
) -> Result<SweepOutcome> {
    run_shard_to_file_with_faults(spec, plan, shard, path, &FaultPlan::default())
}

/// [`run_shard_to_file`] with a [`FaultPlan`] threaded through the
/// results sink and the cell pool (`odl-har sweep --inject-faults`).
/// The empty plan is a no-op; with faults the run may abort, hang, or
/// fail by design — recovery is resume's (and the supervisor's) job.
pub fn run_shard_to_file_with_faults(
    spec: &SweepSpec,
    plan: &SweepPlan,
    shard: ShardSpec,
    path: &Path,
    faults: &FaultPlan,
) -> Result<SweepOutcome> {
    let range = plan.shard_range(shard)?;
    let stats = plan.range_stats(range.clone());
    let mut sink = OrderedSink::new(create_results_file(path)?).with_faults(faults);
    // header occupies slot 0; the slice's cell i lands in slot
    // i - range.start + 1
    sink.push(0, header_json(plan, shard).to_string())?;
    let sink = Mutex::new(sink);
    let reports = run_cells(spec, plan, range.clone(), range.start, Some(&sink), faults)?;
    let mut sink = sink.into_inner().expect("sweep sink poisoned");
    sink.push(range.len() + 1, trailer_json(&stats).to_string())?;
    Ok(SweepOutcome { reports, stats })
}

/// Resume (or start) a sweep into `path`. See the module docs for the
/// protocol; the post-condition is a results file byte-identical to an
/// uninterrupted [`run_sweep_to_file`] over the same spec.
pub fn resume_sweep_to_file(spec: &SweepSpec, path: &Path) -> Result<ResumeOutcome> {
    resume_planned_to_file(spec, &spec.plan(), path)
}

/// [`resume_sweep_to_file`] over an already-computed plan (see
/// [`run_planned_to_file`]). `plan` must come from `spec.plan()`.
pub fn resume_planned_to_file(
    spec: &SweepSpec,
    plan: &SweepPlan,
    path: &Path,
) -> Result<ResumeOutcome> {
    resume_shard_to_file(spec, plan, ShardSpec::WHOLE, path)
}

/// Resume (or start) one shard's results file — the full-run resume
/// protocol applied to the shard's slice: header (including the shard
/// annotation) byte-checked, longest valid prefix of the slice's rows
/// kept verbatim, the remainder re-run, trailer appended. Byte-identical
/// to an uninterrupted [`run_shard_to_file`] from any cut point.
pub fn resume_shard_to_file(
    spec: &SweepSpec,
    plan: &SweepPlan,
    shard: ShardSpec,
    path: &Path,
) -> Result<ResumeOutcome> {
    resume_shard_to_file_with_faults(spec, plan, shard, path, &FaultPlan::default())
}

/// [`resume_shard_to_file`] with a [`FaultPlan`] threaded through the
/// appended rows' sink and the cell pool (see
/// [`run_shard_to_file_with_faults`]). The prefix scan and rewrite are
/// never faulted: they are the recovery path itself.
pub fn resume_shard_to_file_with_faults(
    spec: &SweepSpec,
    plan: &SweepPlan,
    shard: ShardSpec,
    path: &Path,
    faults: &FaultPlan,
) -> Result<ResumeOutcome> {
    let range = plan.shard_range(shard)?;
    let count = range.len();
    let stats = plan.range_stats(range.clone());
    // Raw bytes, not read_to_string: a torn write can leave a partial
    // multi-byte UTF-8 sequence (or NUL garbage) in the trailing line,
    // and that must read as "discardable partial row", never abort the
    // resume with a decode error.
    let bytes = if path.exists() {
        std::fs::read(path).with_context(|| format!("reading results file {}", path.display()))?
    } else {
        Vec::new()
    };
    // Complete lines only: a kill mid-write can leave a trailing partial
    // line, which resume must discard, never trust. split('\n') makes the
    // final element either "" (the bytes ended with a newline) or the
    // partial line — pop it either way.
    let mut lines: Vec<&[u8]> = bytes.split(|&b| b == b'\n').collect();
    lines.pop();
    if lines.is_empty() {
        // missing, empty, or truncated-to-nothing: a fresh full run
        let outcome = run_shard_to_file_with_faults(spec, plan, shard, path, faults)?;
        return Ok(ResumeOutcome {
            skipped: 0,
            ran: count,
            already_complete: false,
            stats: outcome.stats,
        });
    }
    let header = header_json(plan, shard).to_string();
    ensure!(
        lines[0] == header.as_bytes(),
        "refusing to resume {}: its header does not match this spec \
         (different grid, shard split, schema version, or engine revision)",
        path.display()
    );
    // The longest valid prefix of completed cell rows. Error rows, lines
    // that are not valid UTF-8 (torn multi-byte sequences), lines that
    // are not valid JSON (interleaved NULs), and anything after the
    // first gap are re-run.
    let mut done = 0usize;
    for raw in &lines[1..] {
        if done >= count {
            break;
        }
        let line = match std::str::from_utf8(raw) {
            Ok(line) => line,
            Err(_) => break,
        };
        let row = match Json::parse(line) {
            Ok(row) => row,
            Err(_) => break,
        };
        if row.get("error").is_some()
            || row.get("cell").and_then(Json::as_usize) != Some(range.start + done)
        {
            break;
        }
        done += 1;
    }
    let trailer = trailer_json(&stats).to_string();
    // complete = header + count rows + trailer and nothing else; extra
    // trailing lines would survive an early return and break the
    // byte-identical post-condition
    if done == count
        && lines.len() == count + 2
        && lines.get(1 + count).copied() == Some(trailer.as_bytes())
    {
        return Ok(ResumeOutcome {
            skipped: count,
            ran: 0,
            already_complete: true,
            stats,
        });
    }
    // Rewrite header + the verified prefix (original bytes, verbatim)
    // into a sibling temp file renamed into place, then append the re-run
    // rows: a kill during the prefix rewrite can no longer destroy the
    // completed rows (the original file stays intact until the atomic
    // rename), and a kill during the append leaves a partial trailing
    // line the next resume discards — the protocol's designed case. The
    // temp file is fsynced before the rename and the parent directory
    // after it, so a power loss around the swap cannot surface an empty
    // or stale file where completed rows used to be.
    let tmp = temp_sibling(path);
    let rewrite = || -> Result<()> {
        let mut out = create_results_file(&tmp)?;
        out.write_all(header.as_bytes())?;
        out.write_all(b"\n")?;
        for line in lines.iter().skip(1).take(done) {
            out.write_all(line)?;
            out.write_all(b"\n")?;
        }
        sync_writer(out, &tmp)
    };
    if let Err(e) = rewrite() {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("moving resumed results into place at {}", path.display()))?;
    sync_parent_dir(path)?;
    let out = std::io::BufWriter::new(
        std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("reopening results file {} for append", path.display()))?,
    );
    let sink = Mutex::new(OrderedSink::starting_at(out, done + 1).with_faults(faults));
    run_cells(
        spec,
        plan,
        range.start + done..range.end,
        range.start,
        Some(&sink),
        faults,
    )?;
    let mut sink = sink.into_inner().expect("sweep sink poisoned");
    sink.push(count + 1, trailer)?;
    Ok(ResumeOutcome {
        skipped: done,
        ran: count - done,
        already_complete: false,
        stats,
    })
}

/// Outcome of [`merge_shard_files`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MergeOutcome {
    /// Shard files merged.
    pub shards: usize,
    /// Grid cells in the merged file.
    pub cells: usize,
    /// The full grid's (plan-derived) memo ledger — what the merged
    /// trailer reports.
    pub stats: SweepStats,
}

/// Recombine a complete shard set into `out`, **byte-identical** to a
/// single-process [`run_sweep_to_file`] over the same spec (see the
/// module docs). Every input header is byte-compared against the header
/// this plan writes for its claimed shard — one check covering schema,
/// cell count, `grid_hash`, and the slice annotation — every shard must
/// be complete (count rows, in order, no error rows, slice trailer
/// intact), and indices `1..=N` must each appear exactly once; the
/// contiguous ranges then tile the grid by construction. Rows are copied
/// verbatim; header and trailer are regenerated from the full plan.
/// `plan` must come from `spec.plan()` of the sweep's spec.
pub fn merge_shard_files(
    plan: &SweepPlan,
    inputs: &[std::path::PathBuf],
    out: &Path,
) -> Result<MergeOutcome> {
    ensure!(!inputs.is_empty(), "merge needs at least one shard file");
    struct Piece<'a> {
        index: usize,
        start: usize,
        count: usize,
        path: &'a std::path::Path,
    }
    // Pass 1 — validate the set (each file's text is dropped before the
    // next loads, so peak memory is one shard file, not the whole study):
    // header byte-compared against this plan, stream complete (line
    // count + trailer byte-compared against the slice's plan-derived
    // stats), indices consistent and unique.
    let mut of_seen: Option<usize> = None;
    let mut pieces: Vec<Piece> = Vec::new();
    for path in inputs {
        let text = read_shard_text(path)?;
        let (shard, range, line_count) = shard_frame(plan, path, &text)?;
        match of_seen {
            None => of_seen = Some(shard.of),
            Some(of) => ensure!(
                of == shard.of,
                "mixed shard splits: {} is part of a 1..{} set but earlier files are 1..{}",
                path.display(),
                shard.of,
                of
            ),
        }
        ensure!(
            pieces.iter().all(|p| p.index != shard.index),
            "duplicate shard {}/{}: {}",
            shard.index,
            shard.of,
            path.display()
        );
        let count = range.len();
        ensure!(
            line_count == count + 2,
            "shard file {} is incomplete ({} of {} expected lines) — \
             `odl-har sweep --resume` it first",
            path.display(),
            line_count,
            count + 2
        );
        pieces.push(Piece {
            index: shard.index,
            start: range.start,
            count,
            path: path.as_path(),
        });
    }
    let of = of_seen.expect("at least one shard parsed");
    if pieces.len() != of {
        let mut missing: Vec<String> = (1..=of)
            .filter(|i| pieces.iter().all(|p| p.index != *i))
            .map(|i| format!("{i}/{of}"))
            .collect();
        missing.truncate(8);
        bail!(
            "incomplete shard set: {} of {of} shard file(s) given (missing {})",
            pieces.len(),
            missing.join(", ")
        );
    }
    // indices 1..=of each exactly once ⇒ the contiguous ranges tile the
    // grid; interleave = concatenate in range order.
    pieces.sort_by_key(|p| p.start);
    // The output must not be one of the inputs: create_results_file
    // truncates, which would destroy a validated shard before it is
    // copied. (Every input was just read, so canonicalize resolves.)
    if let Ok(out_canon) = out.canonicalize() {
        for piece in &pieces {
            ensure!(
                piece.path.canonicalize().ok().as_deref() != Some(out_canon.as_path()),
                "merge output {} is one of the input shard files — refusing to overwrite it",
                out.display()
            );
        }
    }
    // Pass 2 — stream the row bytes verbatim, one shard file in memory at
    // a time, validating each row (parses, no error, right cell index) as
    // it is copied. The frame is re-validated against the SAME text the
    // rows are copied from, so a file swapped between the passes is
    // caught, and each file is read exactly once per pass. The stream
    // goes to a sibling temp file renamed into place on success, so a
    // row-level failure (or a crash) can never leave a truncated/partial
    // stream at `out` — whatever was there before survives intact.
    let tmp = temp_sibling(out);
    let write = || -> Result<()> {
        let mut sink = create_results_file(&tmp)?;
        sink.write_all(header_json(plan, ShardSpec::WHOLE).to_string().as_bytes())?;
        sink.write_all(b"\n")?;
        for piece in &pieces {
            let path = piece.path;
            let text = read_shard_text(path)?;
            let (_, range, line_count) = shard_frame(plan, path, &text)?;
            ensure!(
                range.start == piece.start && line_count == piece.count + 2,
                "shard file {} changed while merging",
                path.display()
            );
            for (j, line) in text.lines().skip(1).take(piece.count).enumerate() {
                let row = Json::parse(line)
                    .map_err(|e| anyhow::anyhow!("shard file {} row {j}: {e}", path.display()))?;
                ensure!(
                    row.get("error").is_none(),
                    "shard file {} cell {} recorded an error — re-run that shard",
                    path.display(),
                    range.start + j
                );
                ensure!(
                    row.get("cell").and_then(Json::as_usize) == Some(range.start + j),
                    "shard file {} row {j} is out of cell order",
                    path.display()
                );
                sink.write_all(line.as_bytes())?;
                sink.write_all(b"\n")?;
            }
        }
        sink.write_all(trailer_json(&plan.stats).to_string().as_bytes())?;
        sink.write_all(b"\n")?;
        // fsync before the rename (and the directory after): the merged
        // file is the study's publish point — a power loss must never
        // surface an empty or stale file at `out`
        sync_writer(sink, &tmp)
    };
    if let Err(e) = write() {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, out)
        .with_context(|| format!("moving merged results into place at {}", out.display()))?;
    sync_parent_dir(out)?;
    Ok(MergeOutcome {
        shards: of,
        cells: plan.cells.len(),
        stats: plan.stats,
    })
}

// The atomic-publish primitives (fsync'd temp-file + rename) moved to
// `storage::local` — the local storage backend and the sweep engine
// share the exact same recipe. Re-exported so in-crate callers
// (serve's snapshot path) keep their import.
pub(crate) use crate::storage::local::{sync_parent_dir, sync_writer, temp_sibling};

/// [`resume_shard_to_file_with_faults`] routed through a
/// [`Storage`] backend (when one is configured): an absent local spool
/// is first hydrated from the shard's object — so a shard can move
/// hosts mid-study and resume from its published rows — and the
/// finished spool is published back under its file-name key. A local
/// spool that exists is always preferred over the object (the spool can
/// only be *ahead*: the object is a completed publish, the spool may
/// hold rows written since). With `storage: None` this is exactly the
/// plain local call.
pub fn resume_shard_via_storage(
    spec: &SweepSpec,
    plan: &SweepPlan,
    shard: ShardSpec,
    path: &Path,
    faults: &FaultPlan,
    storage: Option<&Storage>,
) -> Result<ResumeOutcome> {
    if let Some(st) = storage {
        if !path.exists() && pull_to_file(st, &key_for_path(path)?, path)? {
            eprintln!(
                "sweep: hydrated {} from {} storage",
                path.display(),
                st.backend_name()
            );
        }
    }
    let outcome = resume_shard_to_file_with_faults(spec, plan, shard, path, faults)?;
    if let Some(st) = storage {
        push_from_file(st, path, &key_for_path(path)?)?;
    }
    Ok(outcome)
}

/// [`run_shard_to_file_with_faults`] with the finished stream published
/// to `storage` (when one is configured). See
/// [`resume_shard_via_storage`].
pub fn run_shard_via_storage(
    spec: &SweepSpec,
    plan: &SweepPlan,
    shard: ShardSpec,
    path: &Path,
    faults: &FaultPlan,
    storage: Option<&Storage>,
) -> Result<SweepOutcome> {
    let outcome = run_shard_to_file_with_faults(spec, plan, shard, path, faults)?;
    if let Some(st) = storage {
        push_from_file(st, path, &key_for_path(path)?)?;
    }
    Ok(outcome)
}

/// [`merge_shard_files`] pulling from a [`Storage`] backend: any input
/// path absent locally is hydrated from the object named by its file
/// name (this is how `merge` on one host recombines shards published
/// from others), the merged output is published back, and the merged
/// bytes are — by `merge_shard_files`'s own contract — identical to a
/// local single-process run. Inputs present locally are used as-is.
pub fn merge_via_storage(
    plan: &SweepPlan,
    inputs: &[std::path::PathBuf],
    out: &Path,
    storage: Option<&Storage>,
) -> Result<MergeOutcome> {
    if let Some(st) = storage {
        for path in inputs {
            if !path.exists() {
                let key = key_for_path(path)?;
                ensure!(
                    pull_to_file(st, &key, path)?,
                    "shard file {} is absent locally and {} storage has no object '{}'",
                    path.display(),
                    st.backend_name(),
                    key
                );
            }
        }
    }
    let outcome = merge_shard_files(plan, inputs, out)?;
    if let Some(st) = storage {
        push_from_file(st, out, &key_for_path(out)?)?;
    }
    Ok(outcome)
}

/// Whether `path` holds a complete, valid results stream for `shard`
/// under this plan — the supervisor's post-exit acceptance check. The
/// frame (header bytes, trailer bytes, line count) and every row (valid
/// JSON, no `error` key, the right global cell index) are validated;
/// any failure is simply `false` — the caller's move is always the same
/// (resume or retry the shard), so the reasons stay in merge's errors.
pub fn shard_stream_complete(plan: &SweepPlan, shard: ShardSpec, path: &Path) -> bool {
    let Ok(text) = read_shard_text(path) else {
        return false;
    };
    let Ok((claimed, range, line_count)) = shard_frame(plan, path, &text) else {
        return false;
    };
    if claimed != shard || line_count != range.len() + 2 {
        return false;
    }
    text.lines()
        .skip(1)
        .take(range.len())
        .enumerate()
        .all(|(j, line)| match Json::parse(line) {
            Ok(row) => {
                row.get("error").is_none()
                    && row.get("cell").and_then(Json::as_usize) == Some(range.start + j)
            }
            Err(_) => false,
        })
}

/// Read one shard file, requiring the stream's terminating newline (a
/// missing one means a kill mid-write — resume it, don't merge it).
fn read_shard_text(path: &std::path::Path) -> Result<String> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading shard file {}", path.display()))?;
    ensure!(
        text.ends_with('\n'),
        "shard file {} is truncated mid-line — `odl-har sweep --resume` it first",
        path.display()
    );
    Ok(text)
}

/// Validate one shard stream's frame — header (byte-compared against the
/// plan for the shard it claims) and, when the stream is complete, the
/// slice trailer (byte-compared against plan-derived stats) — returning
/// the claimed shard, its cell range, and the complete-line count.
/// Operates on already-read text so a caller copying rows validates the
/// same bytes it copies.
fn shard_frame(
    plan: &SweepPlan,
    path: &std::path::Path,
    text: &str,
) -> Result<(ShardSpec, Range<usize>, usize)> {
    let lines: Vec<&str> = text.lines().collect();
    ensure!(!lines.is_empty(), "shard file {} is empty", path.display());
    let header = Json::parse(lines[0])
        .map_err(|e| anyhow::anyhow!("shard file {} header: {e}", path.display()))?;
    let shard = match header.get("shard") {
        // an unannotated header is the whole-grid stream (shard 1/1)
        None => ShardSpec::WHOLE,
        Some(s) => ShardSpec {
            index: s
                .get("index")
                .and_then(Json::as_usize)
                .with_context(|| format!("shard file {}: bad shard.index", path.display()))?,
            of: s
                .get("of")
                .and_then(Json::as_usize)
                .with_context(|| format!("shard file {}: bad shard.of", path.display()))?,
        },
    };
    ensure!(
        shard.of >= 1 && (1..=shard.of).contains(&shard.index),
        "shard file {} claims invalid shard {}/{}",
        path.display(),
        shard.index,
        shard.of
    );
    // one byte-compare validates schema, cell count, grid_hash, and the
    // start/count annotation against this plan
    ensure!(
        lines[0] == header_json(plan, shard).to_string(),
        "shard file {} does not belong to this sweep spec (header mismatch — \
         different grid, schema version, shard split, or engine revision)",
        path.display()
    );
    let range = plan.shard_range(shard).expect("shard validated above");
    let count = range.len();
    // the trailer is only in place when the stream is complete; checking
    // it here keeps 'incomplete' (wrong line count) and 'stale' (foreign
    // trailer bytes) failures distinct for the caller's messages
    if lines.len() == count + 2 {
        let expect_trailer = trailer_json(&plan.range_stats(range.clone())).to_string();
        ensure!(
            lines[count + 1] == expect_trailer,
            "shard file {} has an unexpected stats trailer — \
             `odl-har sweep --resume` it first",
            path.display()
        );
    }
    Ok((shard, range, lines.len()))
}

fn create_results_file(path: &Path) -> Result<std::io::BufWriter<std::fs::File>> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating results file {}", path.display()))?;
    Ok(std::io::BufWriter::new(file))
}

/// Per-slot memo state during a run: lazily built, refcounted down to
/// its planned drop point. The artifact, each (slot, seed) shuffle, and
/// each (slot, seed, n_hidden) edge-state set carry independent locks so
/// unrelated builds proceed concurrently (only peers needing the *same*
/// memo entry block on its build); no two locks are ever held at once —
/// acquire takes artifact, then shuffle, then edge state; release takes
/// the reverse order; each lock is dropped before the next is taken, so
/// lock order cannot deadlock.
struct Slot {
    artifact: Mutex<ArtifactState>,
    shuffles: Vec<ShuffleSlot>,
}

struct ShuffleSlot {
    state: Mutex<ShuffleState>,
    edge_states: Vec<Mutex<EdgeStateState>>,
}

struct ArtifactState {
    artifact: Option<Arc<ProvisionArtifacts>>,
    /// Cells (of this invocation) that still need this artifact.
    remaining: usize,
}

struct ShuffleState {
    train: Option<Arc<Dataset>>,
    remaining: usize,
}

/// The edge-state memo: provisioned cores in edge-id order, grown lazily
/// to the largest fleet that asks, cleared when the last cell of the
/// `(data key, seed, n_hidden)` key retires.
struct EdgeStateState {
    models: Vec<Arc<OsElm>>,
    remaining: usize,
}

/// Run the cells of `run` (a full grid, a shard's slice, or a resume's
/// remainder) over the worker pool, with lazily built, last-use-dropped
/// memo state. `origin` is the start of the stream's slice — the slice's
/// cell `i` claims sink slot `i - origin + 1` (slot 0 is the header).
/// Returns the reports of exactly the cells it ran, in cell order.
///
/// Panic isolation: every cell attempt runs caught
/// ([`parallel::parallel_map_n_caught`]), so a panicking cell — injected
/// via `faults` or organic — cannot take the pool down. A panicked cell
/// gets one clean sequential retry after the pool joins; a second panic
/// becomes the cell's structured error row (which still claims its sink
/// slot, so the stream drains) and the run's overall `Err`. Injected
/// panics fire before any memo state is touched, so their retries are
/// side-effect-free; an organic mid-cell panic may at worst leak memo
/// entries or poison a peer's lock — degrading to more error rows, never
/// to corrupt output bytes.
fn run_cells<W: Write + Send>(
    spec: &SweepSpec,
    plan: &SweepPlan,
    run: Range<usize>,
    origin: usize,
    sink: Option<&Mutex<OrderedSink<W>>>,
    faults: &FaultPlan,
) -> Result<Vec<(SweepCell, FleetReport)>> {
    // Remaining-use counts restricted to the cells this invocation
    // actually runs, so a shard or resume drops (or never builds) memo
    // state whose uses all sit outside its slice.
    let slots: Vec<Slot> = plan
        .artifacts
        .iter()
        .map(|a| Slot {
            artifact: Mutex::new(ArtifactState {
                artifact: None,
                remaining: 0,
            }),
            shuffles: a
                .shuffles
                .iter()
                .map(|s| ShuffleSlot {
                    state: Mutex::new(ShuffleState {
                        train: None,
                        remaining: 0,
                    }),
                    edge_states: s
                        .edge_states
                        .iter()
                        .map(|_| {
                            Mutex::new(EdgeStateState {
                                models: Vec::new(),
                                remaining: 0,
                            })
                        })
                        .collect(),
                })
                .collect(),
        })
        .collect();
    for &(slot, shuf, est) in &plan.cell_slots[run.clone()] {
        slots[slot]
            .artifact
            .lock()
            .expect("sweep slot poisoned")
            .remaining += 1;
        slots[slot].shuffles[shuf]
            .state
            .lock()
            .expect("sweep shuffle poisoned")
            .remaining += 1;
        slots[slot].shuffles[shuf].edge_states[est]
            .lock()
            .expect("sweep edge memo poisoned")
            .remaining += 1;
    }

    let run_cell = |i: usize, attempt: usize| -> Result<FleetReport> {
        let (cell, sc) = &plan.cells[i];
        let (slot, shuf, est) = plan.cell_slots[i];
        // injected panics fire here, before any lock or refcount is
        // touched, so the one-shot retry re-enters a clean cell
        if !faults.is_noop() && faults.cell_panics(cell.index, attempt) {
            panic!(
                "injected cell fault (cell {}, attempt {attempt})",
                cell.index
            );
        }
        // Acquire: build lazily under the respective lock. Whichever
        // worker gets there first builds; only peers needing the *same*
        // memo entry block until that build lands. Builds are pure
        // functions of their key, so the scheduling race cannot change a
        // bit.
        let artifacts = {
            let mut st = slots[slot].artifact.lock().expect("sweep slot poisoned");
            st.artifact
                .get_or_insert_with(|| {
                    Arc::new(ProvisionArtifacts::build(sc, cell.seed, spec.record_pca))
                })
                .clone()
        };
        let train = {
            let mut sh = slots[slot].shuffles[shuf]
                .state
                .lock()
                .expect("sweep shuffle poisoned");
            sh.train
                .get_or_insert_with(|| Arc::new(artifacts.shuffled_train(cell.seed)))
                .clone()
        };
        // Edge-state memo: grow the shared core set to this cell's fleet
        // size under the estate lock (provisioned_edge_model is a pure
        // function of (data/model knobs, seed, edge id, pool)), then lend
        // Arc clones out. A provisioning failure becomes this cell's
        // error row, exactly like a fleet-construction failure.
        let models: Result<Option<Vec<Arc<OsElm>>>> = if spec.memo_edge_state {
            let mut es = slots[slot].shuffles[shuf].edge_states[est]
                .lock()
                .expect("sweep edge memo poisoned");
            let mut built = Ok(());
            while es.models.len() < cell.n_edges {
                match provisioned_edge_model(sc, cell.seed, es.models.len(), &train) {
                    Ok(m) => es.models.push(Arc::new(m)),
                    Err(e) => {
                        built = Err(e);
                        break;
                    }
                }
            }
            built.map(|()| Some(es.models[..cell.n_edges].to_vec()))
        } else {
            Ok(None)
        };
        let result = models
            .and_then(|models| {
                let cfg = FleetConfig {
                    scenario: sc.clone(),
                    seed: cell.seed,
                };
                match models {
                    Some(ms) => Fleet::with_edge_models(cfg, &artifacts, &train, &ms, 1),
                    None => Fleet::with_shuffled_pool(cfg, &artifacts, &train, 1),
                }
            })
            .map(|fleet| fleet.run_parallel(1));
        if let Some(sink) = sink {
            // a failed cell still claims its slot (with an error row) so
            // the ordered sink can drain every later cell's completed row
            // instead of buffering them forever behind the gap
            let line = match &result {
                Ok(report) => cell_row(cell, report, &artifacts).to_string(),
                Err(e) => obj(vec![
                    ("cell", Json::Num(cell.index as f64)),
                    ("error", Json::Str(e.to_string())),
                ])
                .to_string(),
            };
            sink.lock()
                .expect("sweep sink poisoned")
                // slot 0 is the header line
                .push(i - origin + 1, line)
                .context("writing sweep results row")?;
        }
        // Release: drop this worker's handles, then retire the memo state
        // at its planned last use (reverse acquisition order, each lock
        // held alone) so peak memory tracks the in-flight working set,
        // not the grid's seed count.
        drop(train);
        drop(artifacts);
        {
            let mut es = slots[slot].shuffles[shuf].edge_states[est]
                .lock()
                .expect("sweep edge memo poisoned");
            es.remaining -= 1;
            if es.remaining == 0 {
                es.models = Vec::new();
            }
        }
        {
            let mut sh = slots[slot].shuffles[shuf]
                .state
                .lock()
                .expect("sweep shuffle poisoned");
            sh.remaining -= 1;
            if sh.remaining == 0 {
                sh.train = None;
            }
        }
        {
            let mut st = slots[slot].artifact.lock().expect("sweep slot poisoned");
            st.remaining -= 1;
            if st.remaining == 0 {
                st.artifact = None;
            }
        }
        result
    };

    let n_run = run.len();
    let start = run.start;
    // attempt 0 over the pool, each cell caught so one panic cannot
    // poison the run; panicked cells retry once, sequentially, after the
    // pool joins (the retry fills the cell's sink slot, draining any rows
    // buffered behind the gap)
    let mut results = parallel::parallel_map_n_caught(spec.workers, n_run, |j| {
        run_cell(start + j, 0)
    });
    for (j, caught) in results.iter_mut().enumerate() {
        if caught.is_ok() {
            continue;
        }
        *caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_cell(start + j, 1)
        }));
    }
    // twice-panicked cells: record a structured error row for every one
    // of them first (each claims its sink slot, so completed rows behind
    // the gaps still drain), then surface the panic as the cell's error
    let finals: Vec<Result<FleetReport>> = results
        .into_iter()
        .enumerate()
        .map(|(j, caught)| {
            caught.unwrap_or_else(|payload| {
                let cell = &plan.cells[start + j].0;
                let e = anyhow::anyhow!(
                    "cell worker panicked twice: {}",
                    parallel::panic_message(payload.as_ref())
                );
                if let Some(sink) = sink {
                    let pushed = sink.lock().expect("sweep sink poisoned").push(
                        start + j - origin + 1,
                        obj(vec![
                            ("cell", Json::Num(cell.index as f64)),
                            ("error", Json::Str(e.to_string())),
                        ])
                        .to_string(),
                    );
                    if let Err(io) = pushed {
                        return Err(anyhow::Error::new(io).context("writing sweep results row"));
                    }
                }
                Err(e)
            })
        })
        .collect();
    let mut reports = Vec::with_capacity(n_run);
    for ((cell, _), report) in plan.cells[run].iter().zip(finals) {
        reports.push((
            *cell,
            report.with_context(|| format!("sweep cell {} (seed {})", cell.index, cell.seed))?,
        ));
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthConfig;

    fn small_base() -> Scenario {
        Scenario {
            n_edges: 2,
            n_hidden: 16,
            event_period_s: 1.0,
            horizon_s: 80.0,
            drift_at_s: 25.0,
            train_target: 40,
            synth: SynthConfig {
                n_features: 24,
                n_classes: 3,
                n_subjects: 30,
                samples_per_cell: 4,
                proto_sigma: 1.1,
                confuse_frac: 0.04,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    fn small_spec() -> SweepSpec {
        let base = {
            let mut b = small_base();
            b.data_seed = Some(0x5EED);
            b
        };
        SweepSpec {
            seeds: vec![1, 2],
            thetas: vec![None, Some(0.2)],
            edge_counts: vec![2, 3],
            detectors: vec![DetectorKind::Oracle],
            n_hiddens: vec![base.n_hidden],
            loss_probs: vec![base.channel.loss_prob],
            teacher_errors: vec![base.teacher_error],
            workers: 2,
            record_pca: false,
            memo_edge_state: true,
            base,
        }
    }

    /// A grid that exercises the three new axes (hidden width, channel
    /// loss, teacher error) over one seed.
    fn new_axes_spec() -> SweepSpec {
        let base = {
            let mut b = small_base();
            b.data_seed = Some(0xA7E5);
            b
        };
        SweepSpec {
            seeds: vec![1],
            thetas: vec![None],
            edge_counts: vec![2],
            detectors: vec![DetectorKind::Oracle],
            n_hiddens: vec![16, 24],
            loss_probs: vec![0.0, 0.3],
            teacher_errors: vec![0.0, 0.3],
            workers: 2,
            record_pca: false,
            memo_edge_state: true,
            base,
        }
    }

    #[test]
    fn grid_enumeration_is_deterministic_and_complete() {
        let spec = small_spec();
        let cells = spec.cells();
        assert_eq!(cells.len(), 2 * 2 * 2);
        assert_eq!(cells[0].0.index, 0);
        // seeds are the slowest axis; with the trailing axes at their
        // one-element defaults, edge counts vary fastest here
        assert_eq!(cells[0].0.seed, 1);
        assert_eq!(cells[cells.len() - 1].0.seed, 2);
        assert_eq!(cells[0].0.theta, None);
        assert_eq!(cells[1].0.n_edges, 3);
        let again = spec.cells();
        for (a, b) in cells.iter().zip(&again) {
            assert_eq!(a.0, b.0);
        }
    }

    #[test]
    fn new_axes_enumerate_fastest_last() {
        let spec = new_axes_spec();
        let cells = spec.cells();
        assert_eq!(cells.len(), 2 * 2 * 2);
        // teacher error is the fastest axis, then loss, then n_hidden
        assert_eq!(
            (cells[0].0.n_hidden, cells[0].0.loss_prob, cells[0].0.teacher_error),
            (16, 0.0, 0.0)
        );
        assert_eq!(cells[1].0.teacher_error, 0.3);
        assert_eq!(cells[2].0.loss_prob, 0.3);
        assert_eq!(cells[4].0.n_hidden, 24);
        // and each cell's scenario carries the overrides
        for (cell, sc) in &cells {
            assert_eq!(sc.n_hidden, cell.n_hidden);
            assert_eq!(sc.channel.loss_prob, cell.loss_prob);
            assert_eq!(sc.teacher_error, cell.teacher_error);
        }
    }

    #[test]
    fn memoization_fits_data_once_per_config() {
        let spec = small_spec();
        let outcome = run_sweep(&spec).unwrap();
        assert_eq!(outcome.stats.cells, 8);
        // pinned data_seed → one data config across the whole grid
        assert_eq!(outcome.stats.artifact_builds, 1);
        assert_eq!(outcome.stats.artifact_hits, 7);
        // the per-fleet shuffle memoizes per (data key, seed)
        assert_eq!(outcome.stats.shuffle_builds, 2);
        assert_eq!(outcome.stats.shuffle_hits, 6);
        // the edge-state memo builds each seed's largest fleet once
        // (edge_counts [2, 3] → 3 cores per seed) and lends the rest
        assert_eq!(outcome.stats.edge_builds, 6);
        assert_eq!(outcome.stats.edge_hits, 14);
    }

    #[test]
    fn derived_data_seed_memoizes_per_simulation_seed() {
        let mut spec = small_spec();
        spec.base.data_seed = None;
        let outcome = run_sweep(&spec).unwrap();
        // one build per distinct sim seed, hits for the rest of the grid
        assert_eq!(outcome.stats.artifact_builds, 2);
        assert_eq!(outcome.stats.artifact_hits, 6);
        assert_eq!(outcome.stats.shuffle_builds, 2);
        assert_eq!(outcome.stats.shuffle_hits, 6);
        assert_eq!(outcome.stats.edge_builds, 6);
        assert_eq!(outcome.stats.edge_hits, 14);
    }

    #[test]
    fn plan_tracks_artifact_and_shuffle_lifetimes() {
        let spec = small_spec();
        let plan = spec.plan();
        assert_eq!(plan.artifacts.len(), 1);
        let a = &plan.artifacts[0];
        assert_eq!((a.first_cell, a.last_cell, a.uses), (0, 7, 8));
        // seeds are the slowest axis: seed 1 owns cells 0..=3, seed 2
        // cells 4..=7 — the shuffle drop points the engine retires at
        assert_eq!(a.shuffles.len(), 2);
        let s0 = &a.shuffles[0];
        assert_eq!((s0.seed, s0.first_cell, s0.last_cell, s0.uses), (1, 0, 3, 4));
        let s1 = &a.shuffles[1];
        assert_eq!((s1.seed, s1.first_cell, s1.last_cell, s1.uses), (2, 4, 7, 4));
        // one hidden width per seed → one edge-state set per shuffle,
        // alive for the seed's block, sized by the largest fleet
        for s in &a.shuffles {
            assert_eq!(s.edge_states.len(), 1);
            let e = &s.edge_states[0];
            assert_eq!(e.n_hidden, 16);
            assert_eq!((e.first_cell, e.last_cell), (s.first_cell, s.last_cell));
            assert_eq!((e.max_edges, e.edge_uses), (3, 10));
        }
        assert_eq!(
            plan.stats,
            SweepStats {
                cells: 8,
                artifact_builds: 1,
                artifact_hits: 7,
                shuffle_builds: 2,
                shuffle_hits: 6,
                edge_builds: 6,
                edge_hits: 14,
            }
        );
        // every cell points at a live slot
        for (i, &(slot, shuf, est)) in plan.cell_slots.iter().enumerate() {
            let a = &plan.artifacts[slot];
            assert!(a.first_cell <= i && i <= a.last_cell);
            let s = &a.shuffles[shuf];
            assert!(s.first_cell <= i && i <= s.last_cell);
            let e = &s.edge_states[est];
            assert!(e.first_cell <= i && i <= e.last_cell);
        }
    }

    #[test]
    fn edge_state_memo_is_bitwise_invisible() {
        // the memo must be a wall-clock knob only: identical FleetReports
        // with it on and off, for the same grid
        let on = run_sweep(&small_spec()).unwrap();
        let mut spec = small_spec();
        spec.memo_edge_state = false;
        let off = run_sweep(&spec).unwrap();
        assert_eq!(on.reports.len(), off.reports.len());
        for ((cell, a), (_, b)) in on.reports.iter().zip(&off.reports) {
            assert!(
                a.bitwise_eq(b),
                "cell {} diverged with the edge-state memo off",
                cell.index
            );
        }
        // only the ledger moves: memo off provisions every core fresh
        assert_eq!(on.stats.edge_builds, 6);
        assert_eq!(on.stats.edge_hits, 14);
        assert_eq!(off.stats.edge_builds, 20);
        assert_eq!(off.stats.edge_hits, 0);
    }

    #[test]
    fn sweep_reports_bitwise_match_individually_built_fleets() {
        let spec = small_spec();
        let outcome = run_sweep(&spec).unwrap();
        for ((cell, report), (_, sc)) in outcome.reports.iter().zip(spec.cells()) {
            let direct = Fleet::new(FleetConfig {
                scenario: sc,
                seed: cell.seed,
            })
            .unwrap()
            .run();
            assert!(
                direct.bitwise_eq(report),
                "cell {} diverged from the individually built fleet",
                cell.index
            );
        }
    }

    #[test]
    fn new_axes_cells_bitwise_match_individually_built_fleets() {
        let spec = new_axes_spec();
        let outcome = run_sweep(&spec).unwrap();
        // model/connectivity/supervision axes are simulation knobs, not
        // data knobs: the pinned data seed still fits the pool once, and
        // one seed means one shuffle
        assert_eq!(outcome.stats.artifact_builds, 1);
        assert_eq!(outcome.stats.shuffle_builds, 1);
        for ((cell, report), (_, sc)) in outcome.reports.iter().zip(spec.cells()) {
            let direct = Fleet::new(FleetConfig {
                scenario: sc,
                seed: cell.seed,
            })
            .unwrap()
            .run();
            assert!(
                direct.bitwise_eq(report),
                "cell {} diverged from the individually built fleet",
                cell.index
            );
        }
        // the axes must actually move the trajectories
        let r = &outcome.reports;
        assert!(!r[0].1.bitwise_eq(&r[1].1), "teacher-error axis is inert");
        assert!(!r[0].1.bitwise_eq(&r[2].1), "loss axis is inert");
        assert!(!r[0].1.bitwise_eq(&r[4].1), "n_hidden axis is inert");
    }

    #[test]
    fn worker_count_never_changes_results() {
        // the shared executor's canonical worker sweep, applied to whole
        // grid runs
        let mut spec = small_spec();
        spec.workers = parallel::WORKER_SWEEP[0];
        let reference = run_sweep(&spec).unwrap();
        for &workers in &parallel::WORKER_SWEEP[1..] {
            spec.workers = workers;
            let got = run_sweep(&spec).unwrap();
            assert_eq!(reference.stats, got.stats);
            for ((_, a), (_, b)) in reference.reports.iter().zip(&got.reports) {
                assert!(a.bitwise_eq(b), "sweep diverged at {workers} workers");
            }
        }
    }

    #[test]
    fn results_file_streams_rows_in_cell_order() {
        let spec = small_spec();
        let dir = std::env::temp_dir().join("odl_har_sweep_test");
        let path = dir.join("sweep.jsonl");
        let outcome = run_sweep_to_file(&spec, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // header + one row per cell + stats trailer
        assert_eq!(lines.len(), outcome.stats.cells + 2);
        let header = Json::parse(lines[0]).unwrap();
        assert_eq!(header.get("schema").unwrap().as_str().unwrap(), SCHEMA);
        assert_eq!(
            header.get("grid_hash").unwrap().as_str().unwrap(),
            format!("{:016x}", spec.plan().grid_hash)
        );
        for (i, line) in lines[1..=outcome.stats.cells].iter().enumerate() {
            let row = Json::parse(line).unwrap();
            assert_eq!(row.get("cell").unwrap().as_usize().unwrap(), i);
            assert!(row.get("final_accuracy").unwrap().as_f64().is_some());
            assert!(row.get("n_hidden").unwrap().as_usize().is_some());
            assert!(row.get("loss_prob").unwrap().as_f64().is_some());
            assert!(row.get("teacher_error").unwrap().as_f64().is_some());
        }
        let trailer = Json::parse(lines[lines.len() - 1]).unwrap();
        let stats = trailer.get("stats").unwrap();
        assert_eq!(
            stats.get("artifact_hits").unwrap().as_usize().unwrap(),
            outcome.stats.artifact_hits
        );
        assert_eq!(
            stats.get("shuffle_builds").unwrap().as_usize().unwrap(),
            outcome.stats.shuffle_builds
        );
        assert_eq!(
            stats.get("edge_builds").unwrap().as_usize().unwrap(),
            outcome.stats.edge_builds
        );
        assert_eq!(
            stats.get("edge_hits").unwrap().as_usize().unwrap(),
            outcome.stats.edge_hits
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn aggregate_mode_rows_carry_sketches_and_match_full_mode_totals() {
        // `fleet.metrics = "aggregate"` is a reporting knob: trajectories
        // (and thus every shared row field) match full mode exactly; the
        // rows gain `metrics` + `sketches`, drop per-edge-only fields to
        // null, and the header carries the schema note
        let base = {
            let mut b = small_base();
            b.data_seed = Some(0x5EED);
            b
        };
        let mut spec = SweepSpec {
            seeds: vec![1, 2],
            thetas: vec![base.fixed_theta],
            edge_counts: vec![base.n_edges],
            detectors: vec![base.detector],
            n_hiddens: vec![base.n_hidden],
            loss_probs: vec![base.channel.loss_prob],
            teacher_errors: vec![base.teacher_error],
            workers: 1,
            record_pca: false,
            memo_edge_state: true,
            base,
        };
        let dir = std::env::temp_dir().join("odl_har_sweep_aggregate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let full_path = dir.join("full.jsonl");
        run_sweep_to_file(&spec, &full_path).unwrap();
        let full_plan_hash = spec.plan().grid_hash;
        spec.base.metrics = MetricsMode::Aggregate;
        let agg_path = dir.join("agg.jsonl");
        run_sweep_to_file(&spec, &agg_path).unwrap();
        // distinct row shapes must never collide in one results file
        assert_ne!(spec.plan().grid_hash, full_plan_hash);

        let full_text = std::fs::read_to_string(&full_path).unwrap();
        let agg_text = std::fs::read_to_string(&agg_path).unwrap();
        let full_lines: Vec<&str> = full_text.lines().collect();
        let agg_lines: Vec<&str> = agg_text.lines().collect();
        assert_eq!(full_lines.len(), agg_lines.len());

        let full_header = Json::parse(full_lines[0]).unwrap();
        let agg_header = Json::parse(agg_lines[0]).unwrap();
        assert!(full_header.get("metrics").is_none());
        assert_eq!(
            agg_header.get("metrics").unwrap().as_str().unwrap(),
            "aggregate"
        );

        for (f, a) in full_lines[1..full_lines.len() - 1]
            .iter()
            .zip(&agg_lines[1..agg_lines.len() - 1])
        {
            let f = Json::parse(f).unwrap();
            let a = Json::parse(a).unwrap();
            // shared rollups come out of the same trajectories
            for key in [
                "cell",
                "seed",
                "queries",
                "trained",
                "teacher_queries",
                "channel_attempts",
                "channel_failures",
                "total_energy_mj",
                "mean_edge_power_mw",
            ] {
                assert_eq!(
                    f.get(key).unwrap().as_f64().unwrap(),
                    a.get(key).unwrap().as_f64().unwrap(),
                    "aggregate mode moved shared field {key}"
                );
            }
            // full rows keep the pre-aggregate shape
            assert!(f.get("metrics").is_none());
            assert!(f.get("sketches").is_none());
            assert!(f.get("comm_fraction").unwrap().as_f64().is_some());
            // aggregate rows: no per-edge comm split, sketches instead
            assert_eq!(a.get("metrics").unwrap().as_str().unwrap(), "aggregate");
            assert!(matches!(a.get("comm_fraction"), Some(Json::Null)));
            let sk = a.get("sketches").unwrap();
            assert!(sk.get("power_mw_p50").unwrap().as_f64().unwrap() > 0.0);
            assert!(sk.get("events").unwrap().as_f64().unwrap() > 0.0);
            assert!(sk.get("distinct_edge_states").unwrap().as_f64().is_some());
            assert!(sk.get("distinct_visited_cells").unwrap().as_f64().is_some());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_is_byte_identical_across_cut_points() {
        // the acceptance contract, over a grid exercising the three new
        // axes: resuming from any interruption point reproduces the
        // uninterrupted file byte for byte
        let spec = new_axes_spec();
        let n = spec.cells().len();
        let dir = std::env::temp_dir().join("odl_har_sweep_resume_test");
        std::fs::create_dir_all(&dir).unwrap();
        let full_path = dir.join("full.jsonl");
        run_sweep_to_file(&spec, &full_path).unwrap();
        let full = std::fs::read_to_string(&full_path).unwrap();
        let lines: Vec<&str> = full.lines().collect();
        assert_eq!(lines.len(), n + 2);

        for cut in [0usize, 1, 3, n, n + 2] {
            // keep header + `cut` rows (cut = n + 2 keeps trailer too)
            let keep = (cut + 1).min(lines.len());
            let text: String = lines[..keep].iter().map(|l| format!("{l}\n")).collect();
            let path = dir.join(format!("cut{cut}.jsonl"));
            std::fs::write(&path, &text).unwrap();
            let out = resume_sweep_to_file(&spec, &path).unwrap();
            assert_eq!(
                std::fs::read_to_string(&path).unwrap(),
                full,
                "resume from a {cut}-row prefix must reproduce the full file"
            );
            if cut >= n + 2 {
                assert!(out.already_complete);
                assert_eq!((out.skipped, out.ran), (n, 0));
            } else {
                let done = cut.min(n);
                assert!(!out.already_complete);
                assert_eq!((out.skipped, out.ran), (done, n - done));
            }
        }

        // junk appended after a complete stream is not "already
        // complete": resume must rewrite back to the canonical bytes
        let path = dir.join("appended.jsonl");
        std::fs::write(&path, format!("{full}{{\"cell\":0}}\n")).unwrap();
        let out = resume_sweep_to_file(&spec, &path).unwrap();
        assert!(!out.already_complete);
        assert_eq!((out.skipped, out.ran), (n, 0));
        assert_eq!(std::fs::read_to_string(&path).unwrap(), full);

        // a partial trailing line (kill mid-write) is discarded, never
        // trusted
        let mut text: String = lines[..3].iter().map(|l| format!("{l}\n")).collect();
        text.push_str("{\"cell\":2,\"trunc");
        let path = dir.join("partial.jsonl");
        std::fs::write(&path, &text).unwrap();
        let out = resume_sweep_to_file(&spec, &path).unwrap();
        assert_eq!((out.skipped, out.ran), (2, n - 2));
        assert_eq!(std::fs::read_to_string(&path).unwrap(), full);

        // missing file = fresh full run through the resume entry point
        let path = dir.join("fresh.jsonl");
        let out = resume_sweep_to_file(&spec, &path).unwrap();
        assert_eq!((out.skipped, out.ran), (0, n));
        assert_eq!(std::fs::read_to_string(&path).unwrap(), full);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_discards_torn_utf8_and_nul_tails() {
        // byte-level hardening: a crash can leave the tail of the file
        // mid-way through a multi-byte UTF-8 sequence, or a storage layer
        // can interleave NUL bytes into the last page. Every such tail is
        // a partial row — discarded, never trusted, never fatal
        let spec = new_axes_spec();
        let n = spec.cells().len();
        let dir = std::env::temp_dir().join("odl_har_sweep_bytetail_test");
        std::fs::create_dir_all(&dir).unwrap();
        let full_path = dir.join("full.jsonl");
        run_sweep_to_file(&spec, &full_path).unwrap();
        let full = std::fs::read(&full_path).unwrap();
        let text = String::from_utf8(full.clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // header + first two rows, intact
        let prefix: Vec<u8> = lines[..3]
            .iter()
            .flat_map(|l| l.bytes().chain(std::iter::once(b'\n')))
            .collect();

        let tails: [&[u8]; 5] = [
            b"\xE2\x82",              // torn multi-byte sequence, no newline
            b"\xE2\x82\n",            // torn sequence "completed" by a newline
            b"{\"cell\":2,\x00\x00",  // NUL-ridden partial row
            b"{\"cell\":2\x00}\n",    // complete line poisoned by a NUL
            b"\xFF\xFE\n",            // bytes that are never valid UTF-8
        ];
        for (t, tail) in tails.iter().enumerate() {
            let mut bytes = prefix.clone();
            bytes.extend_from_slice(tail);
            let path = dir.join(format!("tail{t}.jsonl"));
            std::fs::write(&path, &bytes).unwrap();
            let out = resume_sweep_to_file(&spec, &path).unwrap();
            assert_eq!(
                (out.skipped, out.ran),
                (2, n - 2),
                "tail #{t} must be treated as a discarded partial row"
            );
            assert_eq!(
                std::fs::read(&path).unwrap(),
                full,
                "resume over tail #{t} must restore byte identity"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_is_byte_identical_from_truncation_at_every_byte_offset() {
        // the strongest form of the resume contract: truncate a complete
        // stream at *every* byte offset — not just line boundaries — and
        // resume must reproduce the uninterrupted file byte for byte. A
        // deliberately tiny two-cell scenario keeps ~1000 resumes cheap
        let base = {
            let mut b = small_base();
            b.data_seed = Some(0x71AB);
            b.horizon_s = 10.0;
            b.drift_at_s = 4.0;
            b.train_target = 12;
            b
        };
        let spec = SweepSpec {
            seeds: vec![1, 2],
            thetas: vec![None],
            edge_counts: vec![2],
            detectors: vec![DetectorKind::Oracle],
            n_hiddens: vec![base.n_hidden],
            loss_probs: vec![base.channel.loss_prob],
            teacher_errors: vec![base.teacher_error],
            workers: 2,
            record_pca: false,
            memo_edge_state: true,
            base,
        };
        let dir = std::env::temp_dir().join("odl_har_sweep_bytecut_test");
        std::fs::create_dir_all(&dir).unwrap();
        let full_path = dir.join("full.jsonl");
        run_sweep_to_file(&spec, &full_path).unwrap();
        let full = std::fs::read(&full_path).unwrap();
        let path = dir.join("cut.jsonl");
        for cut in 0..=full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            resume_sweep_to_file(&spec, &path).unwrap();
            assert_eq!(
                std::fs::read(&path).unwrap(),
                full,
                "resume from a byte-{cut} truncation diverged"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_cell_panic_heals_in_process_byte_identically() {
        // a worker-cell panic is caught, retried once outside the pool,
        // and the stream comes out byte-identical to an undisturbed run
        let spec = new_axes_spec();
        let plan = spec.plan();
        let dir = std::env::temp_dir().join("odl_har_sweep_panicheal_test");
        std::fs::create_dir_all(&dir).unwrap();
        let clean = dir.join("clean.jsonl");
        run_planned_to_file(&spec, &plan, &clean).unwrap();
        let faulty = dir.join("faulty.jsonl");
        let faults = FaultPlan::parse("0:panic@1,panic@4").unwrap();
        let out =
            run_shard_to_file_with_faults(&spec, &plan, ShardSpec::WHOLE, &faulty, &faults)
                .unwrap();
        assert_eq!(out.stats.cells, plan.cells.len());
        assert_eq!(
            std::fs::read(&faulty).unwrap(),
            std::fs::read(&clean).unwrap()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn double_panic_becomes_error_row_and_resume_heals() {
        // `panic2` defeats the one-shot retry: the run fails with a
        // structured error row in the stream (not a poisoned pool), and a
        // clean resume reruns from that row and restores byte identity
        let spec = new_axes_spec();
        let plan = spec.plan();
        let n = plan.cells.len();
        let dir = std::env::temp_dir().join("odl_har_sweep_panic2_test");
        std::fs::create_dir_all(&dir).unwrap();
        let clean = dir.join("clean.jsonl");
        run_planned_to_file(&spec, &plan, &clean).unwrap();
        let path = dir.join("wounded.jsonl");
        let faults = FaultPlan::parse("0:panic2@2").unwrap();
        let err = run_shard_to_file_with_faults(&spec, &plan, ShardSpec::WHOLE, &path, &faults)
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("panicked"),
            "error should describe the panic: {err:#}"
        );
        // the stream drained through the error row: header + every row,
        // no trailer, and cell 2's slot holds a structured error
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1 + n);
        let row = Json::parse(lines[3]).unwrap();
        assert_eq!(row.get("cell").unwrap().as_usize().unwrap(), 2);
        assert!(row.get("error").is_some());
        let out = resume_planned_to_file(&spec, &plan, &path).unwrap();
        assert_eq!((out.skipped, out.ran), (2, n - 2));
        assert_eq!(
            std::fs::read(&path).unwrap(),
            std::fs::read(&clean).unwrap()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_io_error_fails_the_run_and_resume_heals() {
        let spec = new_axes_spec();
        let plan = spec.plan();
        let n = plan.cells.len();
        let dir = std::env::temp_dir().join("odl_har_sweep_ioerr_test");
        std::fs::create_dir_all(&dir).unwrap();
        let clean = dir.join("clean.jsonl");
        run_planned_to_file(&spec, &plan, &clean).unwrap();
        let path = dir.join("wounded.jsonl");
        let faults = FaultPlan::parse("0:ioerr@3").unwrap();
        let err = run_shard_to_file_with_faults(&spec, &plan, ShardSpec::WHOLE, &path, &faults)
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("injected I/O error"),
            "unexpected error chain: {err:#}"
        );
        // whatever prefix made it to disk, a clean resume completes it
        let out = resume_planned_to_file(&spec, &plan, &path).unwrap();
        assert_eq!(out.skipped + out.ran, n);
        assert_eq!(
            std::fs::read(&path).unwrap(),
            std::fs::read(&clean).unwrap()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_rejects_a_mismatched_grid() {
        let spec = small_spec();
        let dir = std::env::temp_dir().join("odl_har_sweep_mismatch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sweep.jsonl");
        run_sweep_to_file(&spec, &path).unwrap();
        // a different grid (extra seed) must refuse the existing file…
        let mut other = spec.clone();
        other.seeds.push(3);
        assert!(resume_sweep_to_file(&other, &path).is_err());
        // …as must a changed base scenario (same axes, different horizon)
        let mut other = spec.clone();
        other.base.horizon_s += 1.0;
        assert!(resume_sweep_to_file(&other, &path).is_err());
        // …and a flipped record_pca (it changes row bytes, so mixing it
        // into an existing file would break byte-identity)
        let mut other = spec.clone();
        other.record_pca = true;
        assert!(resume_sweep_to_file(&other, &path).is_err());
        // …and a flipped edge-state memo (it changes the trailer's edge
        // ledger, so it is part of the fingerprint too)
        let mut other = spec.clone();
        other.memo_edge_state = false;
        assert!(resume_sweep_to_file(&other, &path).is_err());
        // …and a file that is not a sweep stream at all
        let garbage = dir.join("garbage.jsonl");
        std::fs::write(&garbage, "{\"schema\":\"odl-har-sweep/v1\",\"cells\":8}\n").unwrap();
        assert!(resume_sweep_to_file(&spec, &garbage).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_pca_adds_eigenvalues_to_rows() {
        let mut spec = small_spec();
        spec.seeds = vec![1];
        spec.thetas = vec![None];
        spec.edge_counts = vec![2];
        spec.record_pca = true;
        let outcome = run_sweep(&spec).unwrap();
        let (cell, sc) = &spec.cells()[0];
        let artifacts = Arc::new(ProvisionArtifacts::build(sc, cell.seed, true));
        let row = cell_row(cell, &outcome.reports[0].1, &artifacts);
        let eig = row.get("pca_eigenvalues").unwrap().as_arr().unwrap();
        assert_eq!(eig.len(), 2);
        assert!(eig[0].as_f64().unwrap() >= eig[1].as_f64().unwrap());
    }

    /// A spec whose grid has exactly `k` cells (k seeds, one value per
    /// remaining axis) — plan-only helper for the partitioner properties.
    fn k_cell_spec(k: usize) -> SweepSpec {
        let base = small_base();
        SweepSpec {
            seeds: (1..=k as u64).collect(),
            thetas: vec![base.fixed_theta],
            edge_counts: vec![base.n_edges],
            detectors: vec![base.detector],
            n_hiddens: vec![base.n_hidden],
            loss_probs: vec![base.channel.loss_prob],
            teacher_errors: vec![base.teacher_error],
            workers: 1,
            record_pca: false,
            memo_edge_state: true,
            base,
        }
    }

    #[test]
    fn shard_partition_covers_every_cell_exactly_once() {
        // boundary grid sizes (empty, single, prime, power of two,
        // composite, N > cells) × every canonical shard count: the ranges
        // must be contiguous, in order, disjoint, and complete — so every
        // cell lands in exactly one shard and each shard's cell order is
        // a subsequence of the global order
        let mut specs = vec![
            k_cell_spec(0),
            k_cell_spec(1),
            k_cell_spec(7),
            k_cell_spec(8),
            small_spec(),
            new_axes_spec(),
        ];
        {
            // a 12-cell grid with 3 artifact groups of 4
            let mut s = small_spec();
            s.base.data_seed = None;
            s.seeds = vec![1, 2, 3];
            specs.push(s);
        }
        for spec in &specs {
            let plan = spec.plan();
            let n = plan.cells.len();
            for of in [1usize, 2, 3, 8] {
                let ranges = plan.shard_ranges(of);
                assert_eq!(ranges.len(), of, "{n} cells / {of} shards");
                assert_eq!(ranges[0].start, 0);
                for k in 1..of {
                    assert_eq!(
                        ranges[k].start,
                        ranges[k - 1].end,
                        "gap or overlap at shard {k} ({n} cells / {of} shards)"
                    );
                }
                assert_eq!(ranges[of - 1].end, n);
                let flattened: Vec<usize> = ranges.iter().cloned().flatten().collect();
                assert_eq!(flattened, (0..n).collect::<Vec<_>>());
                // per-shard stats account exactly the slice's cells
                let total: usize = ranges
                    .iter()
                    .map(|r| plan.range_stats(r.clone()).cells)
                    .sum();
                assert_eq!(total, n);
            }
        }
    }

    #[test]
    fn shard_cuts_respect_artifact_groups() {
        // derived data seeds → one artifact group per sim seed; with as
        // many shards as groups, every cut must land on a group boundary
        // and every shard must build exactly one artifact (its memo hit
        // rate matches its slice)
        let mut spec = small_spec();
        spec.base.data_seed = None;
        spec.seeds = vec![1, 2, 3];
        let plan = spec.plan();
        assert_eq!(plan.cells.len(), 12);
        assert_eq!(plan.artifacts.len(), 3);
        let ranges = plan.shard_ranges(3);
        assert_eq!(ranges, vec![0..4, 4..8, 8..12]);
        for r in ranges {
            let stats = plan.range_stats(r);
            assert_eq!(stats.artifact_builds, 1);
            assert_eq!(stats.artifact_hits, stats.cells - 1);
        }
    }

    #[test]
    fn cost_weighted_cuts_balance_heterogeneous_fleets() {
        // edge_counts [1, 2, 3, 18]: per-cell costs h, 2h, 3h, 18h (one
        // seed, pinned data seed → a single artifact group, so no
        // boundary is within snapping reach and the cost fallback
        // decides). An even *count* split of 4 cells would hand shard 2
        // a 21h/3h imbalance; the cost split cuts 3|1 — 6h vs 18h, the
        // best contiguous partition of this grid
        let mut spec = small_spec();
        spec.seeds = vec![1];
        spec.thetas = vec![None];
        spec.edge_counts = vec![1, 2, 3, 18];
        let plan = spec.plan();
        assert_eq!(plan.cells.len(), 4);
        let h = 80_000; // small_base horizon_s, in integer milliseconds
        assert_eq!(plan.cell_cost(0), h);
        assert_eq!(plan.cell_cost(3), 18 * h);
        assert_eq!(plan.shard_ranges(2), vec![0..3, 3..4]);
        // the public entry and the cost partitioner are the same split
        assert_eq!(plan.shard_ranges(2), plan.cost_shard_ranges(2));
    }

    #[test]
    fn cell_cost_weighs_fractional_horizons_in_milliseconds() {
        // 1.0s vs 1.9s must not weigh identically (the whole-second
        // truncation bug), and sub-second horizons must not collapse to
        // the 1-unit floor
        let mut spec = k_cell_spec(3);
        spec.edge_counts = vec![1];
        let mut plan = spec.plan();
        for (i, h) in [(0usize, 1.0f64), (1, 1.9), (2, 0.25)] {
            plan.cells[i].1.horizon_s = h;
        }
        assert_eq!(plan.cell_cost(0), 1000);
        assert_eq!(plan.cell_cost(1), 1900);
        assert_eq!(plan.cell_cost(2), 250);
        // degenerate horizons still cost at least one unit
        plan.cells[0].1.horizon_s = 0.0;
        assert_eq!(plan.cell_cost(0), 1);
    }

    #[test]
    fn cost_cuts_balance_fractional_horizon_grids() {
        // four 1-edge cells with horizons 0.4 / 0.4 / 0.4 / 1.9 — costs
        // 400/400/400/1900 ms (total 3100, half 1550). Whole-second
        // truncation clamped every horizon to 1, saw uniform cost, and
        // cut 2|2 — loads 800 vs 2300; millisecond weighting cuts 3|1 —
        // loads 1200 vs 1900, the best contiguous split. One pinned
        // data seed keeps a single artifact group, so no boundary snap
        // can mask the cost decision.
        let mut spec = k_cell_spec(4);
        spec.edge_counts = vec![1];
        spec.base.data_seed = Some(0x5EED);
        let mut plan = spec.plan();
        for cell in plan.cells.iter_mut().take(3) {
            cell.1.horizon_s = 0.4;
        }
        plan.cells[3].1.horizon_s = 1.9;
        assert_eq!(plan.cost_shard_ranges(2), vec![0..3, 3..4]);
        // sub-second grids keep real ratios too: horizons 0.2/0.2/0.8/0.8
        // (costs 200/200/800/800, prefix 200/400/1200/2000) cut at the
        // position nearest the half-cost point — 3, not the count split 2
        let mut plan = spec.plan();
        for (i, cell) in plan.cells.iter_mut().enumerate() {
            cell.1.horizon_s = if i < 2 { 0.2 } else { 0.8 };
        }
        assert_eq!(plan.cost_shard_ranges(2), vec![0..3, 3..4]);
    }

    #[test]
    fn shard_cuts_never_double_snap_onto_one_boundary() {
        // two data_key groups of 6 split 3 ways: both interior ideal cuts
        // (4 and 8) are within snapping distance of the single boundary
        // at 6 — the second cut must fall back toward the even split
        // instead of snapping onto 6 again and starving shard 2 while its
        // neighbours carry double load
        let mut spec = small_spec();
        spec.base.data_seed = None;
        spec.seeds = vec![1, 2];
        spec.thetas = vec![None, Some(0.1), Some(0.2)];
        let plan = spec.plan();
        assert_eq!(plan.cells.len(), 12);
        assert_eq!(plan.artifacts.len(), 2);
        let ranges = plan.shard_ranges(3);
        assert_eq!(ranges, vec![0..6, 6..8, 8..12]);
        assert!(ranges.iter().all(|r| !r.is_empty()));
    }

    #[test]
    fn slice_lifetimes_agree_with_range_stats() {
        // the dry-run display and the trailer ledger must share one
        // lifetime semantics: builds == distinct entries, lends == cells
        // (or Σ n_edges), and every first/last lies inside the slice
        let spec = small_spec();
        let plan = spec.plan();
        let n = plan.cells.len();
        for (a, b) in [(0usize, n), (0, 3), (2, 7), (5, 5), (n - 1, n)] {
            let stats = plan.range_stats(a..b);
            let lt = plan.slice_lifetimes(a..b);
            assert_eq!(lt.artifacts.len(), stats.artifact_builds);
            assert_eq!(lt.shuffles.len(), stats.shuffle_builds);
            let max_sum: usize = lt.edge_states.values().map(|(_, m)| *m).sum();
            let use_sum: usize = lt.edge_states.values().map(|(l, _)| l.uses).sum();
            assert_eq!(max_sum, stats.edge_builds);
            assert_eq!(use_sum, stats.edge_builds + stats.edge_hits);
            for l in lt.artifacts.values() {
                assert!(a <= l.first && l.first <= l.last && l.last < b);
            }
        }
    }

    #[test]
    fn shard_one_of_one_is_byte_identical_to_unsharded() {
        let spec = small_spec();
        let plan = spec.plan();
        let dir = std::env::temp_dir().join("odl_har_sweep_shard11_test");
        std::fs::create_dir_all(&dir).unwrap();
        let full = dir.join("full.jsonl");
        let shard = dir.join("shard.jsonl");
        run_planned_to_file(&spec, &plan, &full).unwrap();
        run_shard_to_file(&spec, &plan, ShardSpec::WHOLE, &shard).unwrap();
        assert_eq!(
            std::fs::read_to_string(&full).unwrap(),
            std::fs::read_to_string(&shard).unwrap(),
            "--shard 1/1 must write the unsharded stream byte for byte"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shards_merge_byte_identical_for_every_split() {
        // the merge acceptance contract on two grids (one exercising the
        // v2 axes): merge(shard 1/N .. N/N) == the unsharded file, byte
        // for byte, for N ∈ {1, 2, 3} and an N > 1-cell boundary split,
        // with the shard files given in scrambled order
        for (tag, spec) in [("small", small_spec()), ("axes", new_axes_spec())] {
            let plan = spec.plan();
            let n = plan.cells.len();
            let dir =
                std::env::temp_dir().join(format!("odl_har_sweep_merge_test_{tag}"));
            std::fs::create_dir_all(&dir).unwrap();
            let full_path = dir.join("full.jsonl");
            run_planned_to_file(&spec, &plan, &full_path).unwrap();
            let full = std::fs::read_to_string(&full_path).unwrap();
            for of in [1usize, 2, 3, 8] {
                let mut paths = Vec::new();
                for index in 1..=of {
                    let path = dir.join(format!("shard_{index}_of_{of}.jsonl"));
                    let outcome = run_shard_to_file(
                        &spec,
                        &plan,
                        ShardSpec { index, of },
                        &path,
                    )
                    .unwrap();
                    assert_eq!(
                        outcome.stats.cells,
                        plan.shard_ranges(of)[index - 1].len()
                    );
                    paths.push(path);
                }
                paths.reverse();
                let merged_path = dir.join(format!("merged_{of}.jsonl"));
                let outcome = merge_shard_files(&plan, &paths, &merged_path).unwrap();
                assert_eq!((outcome.shards, outcome.cells), (of, n));
                assert_eq!(outcome.stats, plan.stats);
                assert_eq!(
                    std::fs::read_to_string(&merged_path).unwrap(),
                    full,
                    "{tag}: merge of {of} shard(s) must reproduce the unsharded file"
                );
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn kill_then_merge_is_byte_identical() {
        // interrupt any one shard at any point, resume it, merge — the
        // merged file must equal the uninterrupted single-process run
        let spec = new_axes_spec();
        let plan = spec.plan();
        let of = 3usize;
        let dir = std::env::temp_dir().join("odl_har_sweep_kill_merge_test");
        std::fs::create_dir_all(&dir).unwrap();
        let full_path = dir.join("full.jsonl");
        run_planned_to_file(&spec, &plan, &full_path).unwrap();
        let full = std::fs::read_to_string(&full_path).unwrap();
        let shard_paths: Vec<std::path::PathBuf> = (1..=of)
            .map(|index| {
                let path = dir.join(format!("shard_{index}.jsonl"));
                run_shard_to_file(&spec, &plan, ShardSpec { index, of }, &path).unwrap();
                path
            })
            .collect();
        let pristine: Vec<String> = shard_paths
            .iter()
            .map(|p| std::fs::read_to_string(p).unwrap())
            .collect();
        for victim in 0..of {
            let shard = ShardSpec {
                index: victim + 1,
                of,
            };
            let count = plan.shard_ranges(of)[victim].len();
            let lines: Vec<&str> = pristine[victim].lines().collect();
            for cut in [0usize, 1, count / 2, count + 2] {
                // restore every shard, then truncate the victim to
                // header + `cut` rows (cut = count + 2 keeps the trailer:
                // the already-complete path)
                for (p, text) in shard_paths.iter().zip(&pristine) {
                    std::fs::write(p, text).unwrap();
                }
                let keep = (cut + 1).min(lines.len());
                let text: String =
                    lines[..keep].iter().map(|l| format!("{l}\n")).collect();
                std::fs::write(&shard_paths[victim], &text).unwrap();
                let out =
                    resume_shard_to_file(&spec, &plan, shard, &shard_paths[victim])
                        .unwrap();
                if cut >= count + 2 {
                    assert!(out.already_complete);
                } else {
                    let done = cut.min(count);
                    assert_eq!((out.skipped, out.ran), (done, count - done));
                }
                assert_eq!(
                    std::fs::read_to_string(&shard_paths[victim]).unwrap(),
                    pristine[victim],
                    "shard {}/{} resumed from cut {cut} must match the uninterrupted shard",
                    shard.index,
                    of
                );
                let merged_path = dir.join("merged.jsonl");
                merge_shard_files(&plan, &shard_paths, &merged_path).unwrap();
                assert_eq!(
                    std::fs::read_to_string(&merged_path).unwrap(),
                    full,
                    "merge after interrupting shard {}/{} at cut {cut} diverged",
                    shard.index,
                    of
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_pulling_shards_from_storage_is_byte_identical_to_local() {
        // shards publish to a shared store from one "host" directory;
        // merge on another host (no shard files present locally) pulls
        // them by key — the merged bytes must equal the single-process
        // run, and the published merged object must round-trip those
        // same bytes. The storage backend runs under injected transient
        // faults to prove the retry policy is byte-invisible.
        use crate::storage::{Storage, StorageConfig};
        let spec = small_spec();
        let plan = spec.plan();
        let of = 2usize;
        let dir = std::env::temp_dir().join("odl_har_sweep_storage_merge_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let full_path = dir.join("full.jsonl");
        run_planned_to_file(&spec, &plan, &full_path).unwrap();
        let full = std::fs::read_to_string(&full_path).unwrap();
        let store = dir.join("store");
        let cfg = StorageConfig {
            backoff_base_ms: 1,
            backoff_cap_ms: 4,
            ..StorageConfig::default()
        };
        // producer host: first two storage ops fault, retries converge
        let chaos = FaultPlan::parse("5:sioerr@0,stear@1").unwrap();
        let producer = Storage::open_uri(store.to_str().unwrap(), &cfg, &chaos).unwrap();
        let host_a = dir.join("host_a");
        for index in 1..=of {
            let path = host_a.join(format!("sweep.shard{index}of{of}.jsonl"));
            run_shard_via_storage(
                &spec,
                &plan,
                ShardSpec { index, of },
                &path,
                &FaultPlan::default(),
                Some(&producer),
            )
            .unwrap();
        }
        // consumer host: shard files named but absent — hydrated by key
        let consumer = Storage::open_uri(
            store.to_str().unwrap(),
            &cfg,
            &FaultPlan::default(),
        )
        .unwrap();
        let host_b = dir.join("host_b");
        std::fs::create_dir_all(&host_b).unwrap();
        let inputs: Vec<std::path::PathBuf> = (1..=of)
            .map(|i| host_b.join(format!("sweep.shard{i}of{of}.jsonl")))
            .collect();
        let merged = host_b.join("merged.jsonl");
        let outcome = merge_via_storage(&plan, &inputs, &merged, Some(&consumer)).unwrap();
        assert_eq!((outcome.shards, outcome.cells), (of, plan.cells.len()));
        assert_eq!(
            std::fs::read_to_string(&merged).unwrap(),
            full,
            "merge pulling from storage must reproduce the single-process file"
        );
        // the merged object published back to the store is those bytes too
        assert_eq!(
            consumer.get_bytes("merged.jsonl").unwrap().unwrap(),
            full.as_bytes(),
        );
        // a missing object is a hard, named error — not an empty merge
        let absent = vec![host_b.join("sweep.shard9of9.jsonl")];
        let err = merge_via_storage(&plan, &absent, &merged, Some(&consumer)).unwrap_err();
        assert!(format!("{err:#}").contains("sweep.shard9of9.jsonl"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_via_storage_hydrates_an_absent_spool() {
        // a shard completes on host A and publishes its stream; host B
        // then resumes the same shard with no local file — the spool
        // hydrates from the object and resume reports already_complete
        // without re-running a single cell (cross-host shard movement)
        use crate::storage::{Storage, StorageConfig};
        let spec = small_spec();
        let plan = spec.plan();
        let dir = std::env::temp_dir().join("odl_har_sweep_storage_resume_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let store = dir.join("store");
        let st = Storage::open_uri(
            store.to_str().unwrap(),
            &StorageConfig::default(),
            &FaultPlan::default(),
        )
        .unwrap();
        let shard = ShardSpec { index: 1, of: 2 };
        let a_path = dir.join("a").join("sweep.shard1of2.jsonl");
        run_shard_via_storage(&spec, &plan, shard, &a_path, &FaultPlan::default(), Some(&st))
            .unwrap();
        let b_path = dir.join("b").join("sweep.shard1of2.jsonl");
        let out = resume_shard_via_storage(
            &spec,
            &plan,
            shard,
            &b_path,
            &FaultPlan::default(),
            Some(&st),
        )
        .unwrap();
        assert!(out.already_complete, "hydrated spool must resume as complete");
        assert_eq!(
            std::fs::read_to_string(&b_path).unwrap(),
            std::fs::read_to_string(&a_path).unwrap(),
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_resume_rejects_a_mismatched_shard_or_spec() {
        let spec = small_spec();
        let plan = spec.plan();
        let dir = std::env::temp_dir().join("odl_har_sweep_shard_mismatch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard.jsonl");
        run_shard_to_file(&spec, &plan, ShardSpec { index: 1, of: 2 }, &path).unwrap();
        // same spec, wrong shard coordinates
        assert!(
            resume_shard_to_file(&spec, &plan, ShardSpec { index: 2, of: 2 }, &path)
                .is_err()
        );
        assert!(
            resume_shard_to_file(&spec, &plan, ShardSpec { index: 1, of: 3 }, &path)
                .is_err()
        );
        // unsharded resume must refuse a shard file too
        assert!(resume_planned_to_file(&spec, &plan, &path).is_err());
        // a different spec refuses the shard file even at the right
        // coordinates
        let mut other = spec.clone();
        other.base.horizon_s += 1.0;
        let other_plan = other.plan();
        assert!(resume_shard_to_file(
            &other,
            &other_plan,
            ShardSpec { index: 1, of: 2 },
            &path
        )
        .is_err());
        // out-of-range shard coordinates are rejected up front
        assert!(plan.shard_range(ShardSpec { index: 0, of: 2 }).is_err());
        assert!(plan.shard_range(ShardSpec { index: 3, of: 2 }).is_err());
        assert!(ShardSpec::parse("0/2").is_err());
        assert!(ShardSpec::parse("3/2").is_err());
        assert!(ShardSpec::parse("1of2").is_err());
        assert_eq!(ShardSpec::parse("2/3").unwrap(), ShardSpec { index: 2, of: 3 });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_rejects_incomplete_or_inconsistent_sets() {
        let spec = small_spec();
        let plan = spec.plan();
        let dir = std::env::temp_dir().join("odl_har_sweep_merge_reject_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut paths = Vec::new();
        for index in 1..=2usize {
            let path = dir.join(format!("shard_{index}.jsonl"));
            run_shard_to_file(&spec, &plan, ShardSpec { index, of: 2 }, &path).unwrap();
            paths.push(path);
        }
        let out = dir.join("merged.jsonl");
        // a complete set merges…
        merge_shard_files(&plan, &paths, &out).unwrap();
        // …but a missing shard is rejected with the gap named
        let err = merge_shard_files(&plan, &paths[..1].to_vec(), &out)
            .unwrap_err()
            .to_string();
        assert!(err.contains("incomplete shard set"), "{err}");
        assert!(err.contains("2/2"), "{err}");
        // a duplicate shard is rejected
        let dup = vec![paths[0].clone(), paths[0].clone()];
        assert!(merge_shard_files(&plan, &dup, &out).is_err());
        // an interrupted shard (header + 1 row, no trailer) is rejected
        let text = std::fs::read_to_string(&paths[1]).unwrap();
        let cut: String = text.lines().take(2).map(|l| format!("{l}\n")).collect();
        let broken = dir.join("broken.jsonl");
        std::fs::write(&broken, cut).unwrap();
        let bad = vec![paths[0].clone(), broken.clone()];
        let err = merge_shard_files(&plan, &bad, &out).unwrap_err().to_string();
        assert!(err.contains("incomplete"), "{err}");
        // mixed splits are rejected
        let odd = dir.join("shard_1_of_3.jsonl");
        run_shard_to_file(&spec, &plan, ShardSpec { index: 1, of: 3 }, &odd).unwrap();
        let mixed = vec![paths[0].clone(), odd];
        assert!(merge_shard_files(&plan, &mixed, &out).is_err());
        // another spec's shard files are rejected outright
        let mut other = spec.clone();
        other.base.horizon_s += 1.0;
        assert!(merge_shard_files(&other.plan(), &paths, &out).is_err());
        // merging onto one of the inputs must refuse before truncating it
        let before = std::fs::read_to_string(&paths[0]).unwrap();
        let err = merge_shard_files(&plan, &paths, &paths[0])
            .unwrap_err()
            .to_string();
        assert!(err.contains("refusing to overwrite"), "{err}");
        assert_eq!(std::fs::read_to_string(&paths[0]).unwrap(), before);
        // a damaged row behind an intact frame (rows swapped: header,
        // line count, and trailer all still byte-exact) fails row
        // validation — and must leave a pre-existing output untouched,
        // because the merge streams into a temp file renamed only on
        // success
        let good_out = std::fs::read_to_string(&out).unwrap();
        let text = std::fs::read_to_string(&paths[1]).unwrap();
        let mut rows: Vec<&str> = text.lines().collect();
        rows.swap(1, 2);
        let damaged = dir.join("damaged.jsonl");
        std::fs::write(
            &damaged,
            rows.iter().map(|l| format!("{l}\n")).collect::<String>(),
        )
        .unwrap();
        let bad = vec![paths[0].clone(), damaged];
        let err = merge_shard_files(&plan, &bad, &out).unwrap_err().to_string();
        assert!(err.contains("out of cell order"), "{err}");
        assert_eq!(
            std::fs::read_to_string(&out).unwrap(),
            good_out,
            "a failed merge must not disturb the existing output file"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
