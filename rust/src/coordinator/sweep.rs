//! Memoized scenario-sweep engine — the cross-fleet level of the parallel
//! provisioning stack (`odl-har sweep`).
//!
//! A parameter study (the paper's Fig. 3/4 and Table 3 are exactly this)
//! runs a grid of fleet scenarios: seeds × pruning thresholds × fleet
//! sizes × detectors. Naively each cell pays the full `Fleet::new` —
//! pool generation, standardizer fit, and per-edge `init_batch` — even
//! though every cell with the same data config generates bitwise the same
//! pool. This engine:
//!
//! 1. enumerates the grid in one deterministic order
//!    ([`SweepSpec::cells`]: seeds → thetas → edge counts → detectors);
//! 2. **memoizes** [`ProvisionArtifacts`] by
//!    [`ProvisionArtifacts::data_key`], so a P-point grid fits the data
//!    once per distinct `(synth config, data seed)` instead of P times
//!    (pin `Scenario::data_seed` in the sweep config to share across
//!    simulation seeds too);
//! 3. fans the cells over a scoped worker pool and **streams** one JSON
//!    row per cell, in cell order, into the results file (an
//!    [`OrderedSink`] reorders out-of-order completions before writing).
//!
//! Determinism contract: each cell's `FleetReport` is **bitwise
//! identical** to the report of an individually constructed
//! `Fleet::new(cfg).run()` for the same scenario — memoization and the
//! worker pool are wall-clock knobs, never numerics knobs. Asserted by
//! the in-module tests and re-checked by `benches/bench_sweep.rs` before
//! it times anything.

use super::fleet::{DetectorKind, Fleet, FleetConfig, ProvisionArtifacts, Scenario};
use super::metrics::FleetReport;
use crate::util::json::{obj, Json};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A declared scenario grid. Every axis left at its one-element default
/// degenerates to the base scenario's value, so a sweep with only
/// `seeds = [...]` is a plain seed study.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Base scenario; each cell clones and overrides it.
    pub base: Scenario,
    /// Simulation seeds.
    pub seeds: Vec<u64>,
    /// Pruning thresholds; `None` = the auto-θ ladder.
    pub thetas: Vec<Option<f32>>,
    /// Fleet sizes.
    pub edge_counts: Vec<usize>,
    /// Drift detectors.
    pub detectors: Vec<DetectorKind>,
    /// Cross-cell worker threads (0 = auto via
    /// [`crate::util::auto_workers`]; resolve before calling the engine).
    pub workers: usize,
    /// Fit the optional PCA summary per data config and record its
    /// eigenvalues in the results rows.
    pub record_pca: bool,
}

impl Default for SweepSpec {
    fn default() -> Self {
        let base = Scenario::default();
        SweepSpec {
            seeds: vec![1],
            thetas: vec![base.fixed_theta],
            edge_counts: vec![base.n_edges],
            detectors: vec![base.detector],
            workers: 1,
            record_pca: false,
            base,
        }
    }
}

/// One grid coordinate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SweepCell {
    pub index: usize,
    pub seed: u64,
    pub theta: Option<f32>,
    pub n_edges: usize,
    pub detector: DetectorKind,
}

impl SweepSpec {
    /// Materialize the grid in its one deterministic order:
    /// seeds → thetas → edge counts → detectors.
    pub fn cells(&self) -> Vec<(SweepCell, Scenario)> {
        let mut out = Vec::with_capacity(
            self.seeds.len() * self.thetas.len() * self.edge_counts.len() * self.detectors.len(),
        );
        for &seed in &self.seeds {
            for &theta in &self.thetas {
                for &n_edges in &self.edge_counts {
                    for &detector in &self.detectors {
                        let mut sc = self.base.clone();
                        sc.fixed_theta = theta;
                        sc.n_edges = n_edges;
                        sc.detector = detector;
                        out.push((
                            SweepCell {
                                index: out.len(),
                                seed,
                                theta,
                                n_edges,
                                detector,
                            },
                            sc,
                        ));
                    }
                }
            }
        }
        out
    }
}

/// Memoization accounting: `artifact_builds + artifact_hits == cells`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepStats {
    pub cells: usize,
    pub artifact_builds: usize,
    pub artifact_hits: usize,
}

/// The engine's result: per-cell reports in cell order plus the
/// memoization ledger.
pub struct SweepOutcome {
    pub reports: Vec<(SweepCell, FleetReport)>,
    pub stats: SweepStats,
}

/// Re-orders out-of-order line completions so the output stream is written
/// strictly in cell order regardless of worker scheduling.
struct OrderedSink<W: Write> {
    next: usize,
    pending: BTreeMap<usize, String>,
    out: W,
}

impl<W: Write> OrderedSink<W> {
    fn new(out: W) -> Self {
        OrderedSink {
            next: 0,
            pending: BTreeMap::new(),
            out,
        }
    }

    fn push(&mut self, index: usize, line: String) -> std::io::Result<()> {
        self.pending.insert(index, line);
        let mut wrote = false;
        while let Some(line) = self.pending.remove(&self.next) {
            self.out.write_all(line.as_bytes())?;
            self.out.write_all(b"\n")?;
            self.next += 1;
            wrote = true;
        }
        // flush only when a line actually drained — keeps tail -f
        // streaming without paying a syscall for buffered-only pushes
        if wrote {
            self.out.flush()?;
        }
        Ok(())
    }
}

/// The per-cell results row: grid coordinates + a `FleetReport` rollup.
pub fn cell_row(cell: &SweepCell, report: &FleetReport, artifacts: &ProvisionArtifacts) -> Json {
    let edges = report.per_edge.len().max(1) as f64;
    // Mean of the last rolling-accuracy checkpoint over the edges that
    // have one (traces checkpoint every 50 predictions, so short horizons
    // may leave some — or all — edges without a reading; averaging those
    // in as 0.0 would skew the rollup). Null when no edge has reported.
    let acc_readings: Vec<f64> = report
        .per_edge
        .iter()
        .filter_map(|m| m.accuracy_trace.last().map(|&(_, a)| a))
        .collect();
    let final_acc = if acc_readings.is_empty() {
        Json::Null
    } else {
        Json::Num(acc_readings.iter().sum::<f64>() / acc_readings.len() as f64)
    };
    let comm: f64 = report.per_edge.iter().map(|m| m.comm_fraction()).sum::<f64>() / edges;
    let trained: u64 = report.per_edge.iter().map(|m| m.trained).sum();
    let mut pairs = vec![
        ("cell", Json::Num(cell.index as f64)),
        ("seed", Json::Num(cell.seed as f64)),
        (
            "theta",
            match cell.theta {
                Some(t) => Json::Num(t as f64),
                None => Json::Str("auto".into()),
            },
        ),
        ("n_edges", Json::Num(cell.n_edges as f64)),
        ("detector", Json::Str(cell.detector.name().into())),
        ("data_key", Json::Str(format!("{:016x}", artifacts.key))),
        ("queries", Json::Num(report.total_queries() as f64)),
        ("trained", Json::Num(trained as f64)),
        ("teacher_queries", Json::Num(report.teacher_queries as f64)),
        ("channel_attempts", Json::Num(report.channel_attempts as f64)),
        ("channel_failures", Json::Num(report.channel_failures as f64)),
        ("comm_fraction", Json::Num(comm)),
        ("final_accuracy", final_acc),
        ("mean_edge_power_mw", Json::Num(report.mean_edge_power_mw())),
        ("total_energy_mj", Json::Num(report.total_energy_mj())),
    ];
    if let Some(pca) = &artifacts.pca {
        pairs.push((
            "pca_eigenvalues",
            Json::Arr(pca.eigenvalues.iter().map(|&e| Json::Num(e as f64)).collect()),
        ));
    }
    obj(pairs)
}

/// Run the grid with memoized artifacts; collect reports only (no file).
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepOutcome> {
    run_sweep_inner(spec, None)
}

/// Run the grid, streaming one JSON row per cell (in cell order) into
/// `path` — a header line, the cell rows, and a stats trailer, one JSON
/// object per line.
pub fn run_sweep_to_file(spec: &SweepSpec, path: &Path) -> Result<SweepOutcome> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating results file {}", path.display()))?;
    let mut sink = OrderedSink::new(std::io::BufWriter::new(file));
    let n_cells = spec.cells().len();
    let header = obj(vec![
        ("schema", Json::Str("odl-har-sweep/v1".into())),
        ("cells", Json::Num(n_cells as f64)),
        ("workers", Json::Num(spec.workers as f64)),
    ]);
    // header occupies slot 0; cell i lands in slot i + 1
    sink.push(0, header.to_string())?;
    let sink = Mutex::new(sink);
    let outcome = run_sweep_inner(spec, Some(&sink))?;
    let mut sink = sink.into_inner().expect("sweep sink poisoned");
    let trailer = obj(vec![
        ("cells", Json::Num(outcome.stats.cells as f64)),
        (
            "artifact_builds",
            Json::Num(outcome.stats.artifact_builds as f64),
        ),
        (
            "artifact_hits",
            Json::Num(outcome.stats.artifact_hits as f64),
        ),
    ]);
    sink.push(n_cells + 1, obj(vec![("stats", trailer)]).to_string())?;
    Ok(outcome)
}

fn run_sweep_inner(
    spec: &SweepSpec,
    sink: Option<&Mutex<OrderedSink<std::io::BufWriter<std::fs::File>>>>,
) -> Result<SweepOutcome> {
    let cells = spec.cells();
    let mut stats = SweepStats {
        cells: cells.len(),
        ..Default::default()
    };

    // Phase 1 — fit shared artifacts once per distinct data key. The
    // distinct keys are enumerated in first-occurrence order (a linear
    // scan; a handful of keys at most), then the independent builds fan
    // over the same worker budget phase 2 uses — a grid with one key per
    // simulation seed would otherwise pay every pool fit back to back on
    // the caller thread before any cell ran. Builds are pure functions of
    // the key, so the fan-out cannot change any artifact bit.
    let mut distinct: Vec<(u64, usize)> = Vec::new(); // (key, first cell index)
    let mut cell_key_slot: Vec<usize> = Vec::with_capacity(cells.len());
    for (i, (cell, sc)) in cells.iter().enumerate() {
        let key = ProvisionArtifacts::data_key(sc, cell.seed);
        match distinct.iter().position(|(k, _)| *k == key) {
            Some(slot) => {
                stats.artifact_hits += 1;
                cell_key_slot.push(slot);
            }
            None => {
                stats.artifact_builds += 1;
                cell_key_slot.push(distinct.len());
                distinct.push((key, i));
            }
        }
    }
    let build_workers = spec.workers.max(1).min(distinct.len().max(1));
    let built: Vec<Arc<ProvisionArtifacts>> = if build_workers <= 1 {
        distinct
            .iter()
            .map(|&(_, i)| {
                let (cell, sc) = &cells[i];
                Arc::new(ProvisionArtifacts::build(sc, cell.seed, spec.record_pca))
            })
            .collect()
    } else {
        let next_build = AtomicUsize::new(0);
        let build_slots: Vec<Mutex<Option<Arc<ProvisionArtifacts>>>> =
            (0..distinct.len()).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..build_workers {
                scope.spawn(|| loop {
                    let b = next_build.fetch_add(1, Ordering::SeqCst);
                    if b >= distinct.len() {
                        break;
                    }
                    let (cell, sc) = &cells[distinct[b].1];
                    let artifacts =
                        Arc::new(ProvisionArtifacts::build(sc, cell.seed, spec.record_pca));
                    *build_slots[b].lock().expect("build slot poisoned") = Some(artifacts);
                });
            }
        });
        build_slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .expect("build slot poisoned")
                    .expect("artifact build never ran")
            })
            .collect()
    };
    let cell_artifacts: Vec<Arc<ProvisionArtifacts>> =
        cell_key_slot.iter().map(|&slot| built[slot].clone()).collect();

    // Phase 2 — fan the cells over the worker pool. Each cell provisions
    // from its shared artifacts and runs single-threaded (the pool is the
    // parallelism); every slot is written by exactly one worker.
    let workers = spec.workers.max(1).min(cells.len().max(1));
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<FleetReport>>>> =
        (0..cells.len()).map(|_| Mutex::new(None)).collect();
    let run_cell = |i: usize| -> Result<FleetReport> {
        let (cell, sc) = &cells[i];
        let result = Fleet::with_artifacts(
            FleetConfig {
                scenario: sc.clone(),
                seed: cell.seed,
            },
            &cell_artifacts[i],
            1,
        )
        .map(|fleet| fleet.run_parallel(1));
        if let Some(sink) = sink {
            // a failed cell still claims its slot (with an error row) so
            // the ordered sink can drain every later cell's completed row
            // instead of buffering them forever behind the gap
            let line = match &result {
                Ok(report) => cell_row(cell, report, &cell_artifacts[i]).to_string(),
                Err(e) => obj(vec![
                    ("cell", Json::Num(cell.index as f64)),
                    ("error", Json::Str(e.to_string())),
                ])
                .to_string(),
            };
            sink.lock()
                .expect("sweep sink poisoned")
                // slot 0 is the header line
                .push(i + 1, line)
                .context("writing sweep results row")?;
        }
        result
    };
    if workers <= 1 {
        for i in 0..cells.len() {
            *slots[i].lock().expect("slot poisoned") = Some(run_cell(i));
        }
    } else {
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= cells.len() {
                        break;
                    }
                    *slots[i].lock().expect("slot poisoned") = Some(run_cell(i));
                });
            }
        });
    }

    let mut reports = Vec::with_capacity(cells.len());
    for ((cell, _), slot) in cells.iter().zip(slots) {
        let report = slot
            .into_inner()
            .expect("slot poisoned")
            .expect("sweep cell never ran")
            .with_context(|| format!("sweep cell {} (seed {})", cell.index, cell.seed))?;
        reports.push((*cell, report));
    }
    Ok(SweepOutcome { reports, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthConfig;

    fn small_base() -> Scenario {
        Scenario {
            n_edges: 2,
            n_hidden: 16,
            event_period_s: 1.0,
            horizon_s: 80.0,
            drift_at_s: 25.0,
            train_target: 40,
            synth: SynthConfig {
                n_features: 24,
                n_classes: 3,
                n_subjects: 30,
                samples_per_cell: 4,
                proto_sigma: 1.1,
                confuse_frac: 0.04,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    fn small_spec() -> SweepSpec {
        SweepSpec {
            base: {
                let mut b = small_base();
                b.data_seed = Some(0x5EED);
                b
            },
            seeds: vec![1, 2],
            thetas: vec![None, Some(0.2)],
            edge_counts: vec![2, 3],
            detectors: vec![DetectorKind::Oracle],
            workers: 2,
            record_pca: false,
        }
    }

    #[test]
    fn grid_enumeration_is_deterministic_and_complete() {
        let spec = small_spec();
        let cells = spec.cells();
        assert_eq!(cells.len(), 2 * 2 * 2);
        assert_eq!(cells[0].0.index, 0);
        // detectors is the fastest axis, seeds the slowest
        assert_eq!(cells[0].0.seed, 1);
        assert_eq!(cells[cells.len() - 1].0.seed, 2);
        assert_eq!(cells[0].0.theta, None);
        assert_eq!(cells[1].0.n_edges, 3);
        let again = spec.cells();
        for (a, b) in cells.iter().zip(&again) {
            assert_eq!(a.0, b.0);
        }
    }

    #[test]
    fn memoization_fits_data_once_per_config() {
        let spec = small_spec();
        let outcome = run_sweep(&spec).unwrap();
        assert_eq!(outcome.stats.cells, 8);
        // pinned data_seed → one data config across the whole grid
        assert_eq!(outcome.stats.artifact_builds, 1);
        assert_eq!(outcome.stats.artifact_hits, 7);
    }

    #[test]
    fn derived_data_seed_memoizes_per_simulation_seed() {
        let mut spec = small_spec();
        spec.base.data_seed = None;
        let outcome = run_sweep(&spec).unwrap();
        // one build per distinct sim seed, hits for the rest of the grid
        assert_eq!(outcome.stats.artifact_builds, 2);
        assert_eq!(outcome.stats.artifact_hits, 6);
    }

    #[test]
    fn sweep_reports_bitwise_match_individually_built_fleets() {
        let spec = small_spec();
        let outcome = run_sweep(&spec).unwrap();
        for ((cell, report), (_, sc)) in outcome.reports.iter().zip(spec.cells()) {
            let direct = Fleet::new(FleetConfig {
                scenario: sc,
                seed: cell.seed,
            })
            .unwrap()
            .run();
            assert!(
                direct.bitwise_eq(report),
                "cell {} diverged from the individually built fleet",
                cell.index
            );
        }
    }

    #[test]
    fn worker_count_never_changes_results() {
        let mut spec = small_spec();
        spec.workers = 1;
        let seq = run_sweep(&spec).unwrap();
        spec.workers = 4;
        let par = run_sweep(&spec).unwrap();
        assert_eq!(seq.stats, par.stats);
        for ((_, a), (_, b)) in seq.reports.iter().zip(&par.reports) {
            assert!(a.bitwise_eq(b));
        }
    }

    #[test]
    fn results_file_streams_rows_in_cell_order() {
        let spec = small_spec();
        let dir = std::env::temp_dir().join("odl_har_sweep_test");
        let path = dir.join("sweep.jsonl");
        let outcome = run_sweep_to_file(&spec, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // header + one row per cell + stats trailer
        assert_eq!(lines.len(), outcome.stats.cells + 2);
        let header = Json::parse(lines[0]).unwrap();
        assert_eq!(
            header.get("schema").unwrap().as_str().unwrap(),
            "odl-har-sweep/v1"
        );
        for (i, line) in lines[1..=outcome.stats.cells].iter().enumerate() {
            let row = Json::parse(line).unwrap();
            assert_eq!(row.get("cell").unwrap().as_usize().unwrap(), i);
            assert!(row.get("final_accuracy").unwrap().as_f64().is_some());
        }
        let trailer = Json::parse(lines[lines.len() - 1]).unwrap();
        assert_eq!(
            trailer
                .get("stats")
                .unwrap()
                .get("artifact_hits")
                .unwrap()
                .as_usize()
                .unwrap(),
            outcome.stats.artifact_hits
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_pca_adds_eigenvalues_to_rows() {
        let mut spec = small_spec();
        spec.seeds = vec![1];
        spec.thetas = vec![None];
        spec.edge_counts = vec![2];
        spec.record_pca = true;
        let outcome = run_sweep(&spec).unwrap();
        let (cell, sc) = &spec.cells()[0];
        let artifacts = Arc::new(ProvisionArtifacts::build(sc, cell.seed, true));
        let row = cell_row(cell, &outcome.reports[0].1, &artifacts);
        let eig = row.get("pca_eigenvalues").unwrap().as_arr().unwrap();
        assert_eq!(eig.len(), 2);
        assert!(eig[0].as_f64().unwrap() >= eig[1].as_f64().unwrap());
    }
}
