//! Memoized, resumable scenario-sweep engine — the cross-fleet level of
//! the parallel provisioning stack (`odl-har sweep`).
//!
//! A parameter study (the paper's Fig. 3/4 and Table 3 are exactly this)
//! runs a grid of fleet scenarios. The grid spans **seven axes** — seeds ×
//! pruning thresholds × fleet sizes × detectors × hidden widths × channel
//! loss × teacher error — enumerated in one deterministic order
//! ([`SweepSpec::cells`]). Naively each cell pays the full `Fleet::new`:
//! pool generation, standardizer fit, the per-fleet shuffle, and per-edge
//! `init_batch`. This engine instead precomputes a [`SweepPlan`] and
//! executes it over the shared deterministic executor
//! ([`crate::util::parallel`]):
//!
//! 1. [`ProvisionArtifacts`] are **memoized** by
//!    [`ProvisionArtifacts::data_key`] and built **lazily** at their
//!    first-use cell — whichever worker gets there first builds under the
//!    slot lock (a pure function of the key, so any builder produces the
//!    same bits) — and **dropped at their last-use cell**, so peak memory
//!    tracks the in-flight working set, not the grid's seed count.
//! 2. The per-fleet **shuffled pool** is memoized the same way, keyed
//!    `(data key, fleet seed)` alongside the artifact memo
//!    ([`ProvisionArtifacts::shuffled_train`] is pure), with its own
//!    last-use drop point.
//! 3. Cells fan over [`crate::util::parallel::parallel_map_n`] and
//!    **stream** one JSON row per cell, in cell order, into the results
//!    file (an [`OrderedSink`] reorders out-of-order completions).
//!
//! # Resume protocol
//!
//! [`resume_sweep_to_file`] (`odl-har sweep --resume`) restarts an
//! interrupted sweep: it re-derives the header (schema + cell count +
//! [`SweepPlan::grid_hash`], a fingerprint of every cell's full scenario
//! plus `record_pca` — every knob that can move an output byte) and
//! refuses to touch a file whose header doesn't match byte for byte.
//! It then keeps the longest valid prefix of completed cell rows (original
//! bytes, verbatim — a truncated trailing line from a kill mid-write is
//! discarded), re-runs only the remaining cells, and appends the stats
//! trailer. Because every cell report is deterministic, the final file is
//! **byte-identical** to an uninterrupted run; resuming an already
//! complete file verifies the trailer and writes nothing.
//!
//! Determinism contract: each cell's `FleetReport` is **bitwise
//! identical** to the report of an individually constructed
//! `Fleet::new(cfg).run()` for the same scenario — memoization, lazy
//! builds, drop points, the worker pool, and resume are wall-clock/memory
//! knobs, never numerics knobs. Asserted by the in-module tests and
//! re-checked by `benches/bench_sweep.rs` before it times anything.

use super::channel::ChannelConfig;
use super::fleet::{DetectorKind, Fleet, FleetConfig, ProvisionArtifacts, Scenario};
use super::metrics::FleetReport;
use crate::data::Dataset;
use crate::util::json::{obj, Json};
use crate::util::parallel;
use crate::util::rng::hash_fold;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Results-file schema tag. v2 added the `n_hidden` / `loss_prob` /
/// `teacher_error` axes and the `grid_hash` resume fingerprint, and
/// dropped the worker count from the header (the stream is a pure
/// function of the spec; worker counts are wall-clock knobs and a resume
/// may legitimately use a different count than the original run).
const SCHEMA: &str = "odl-har-sweep/v2";

/// A declared scenario grid. Every axis left at its one-element default
/// degenerates to the base scenario's value, so a sweep with only
/// `seeds = [...]` is a plain seed study.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Base scenario; each cell clones and overrides it.
    pub base: Scenario,
    /// Simulation seeds.
    pub seeds: Vec<u64>,
    /// Pruning thresholds; `None` = the auto-θ ladder.
    pub thetas: Vec<Option<f32>>,
    /// Fleet sizes.
    pub edge_counts: Vec<usize>,
    /// Drift detectors.
    pub detectors: Vec<DetectorKind>,
    /// Hidden-layer widths (the model-capacity axis).
    pub n_hiddens: Vec<usize>,
    /// Channel loss probabilities (the connectivity axis).
    pub loss_probs: Vec<f64>,
    /// Teacher label-error rates (the supervision-quality axis).
    pub teacher_errors: Vec<f64>,
    /// Cross-cell worker threads (0 = auto via
    /// [`crate::util::auto_workers`]; resolve before calling the engine).
    pub workers: usize,
    /// Fit the optional PCA summary per data config and record its
    /// eigenvalues in the results rows.
    pub record_pca: bool,
}

impl Default for SweepSpec {
    fn default() -> Self {
        let base = Scenario::default();
        SweepSpec {
            seeds: vec![1],
            thetas: vec![base.fixed_theta],
            edge_counts: vec![base.n_edges],
            detectors: vec![base.detector],
            n_hiddens: vec![base.n_hidden],
            loss_probs: vec![base.channel.loss_prob],
            teacher_errors: vec![base.teacher_error],
            workers: 1,
            record_pca: false,
            base,
        }
    }
}

/// One grid coordinate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SweepCell {
    pub index: usize,
    pub seed: u64,
    pub theta: Option<f32>,
    pub n_edges: usize,
    pub detector: DetectorKind,
    pub n_hidden: usize,
    pub loss_prob: f64,
    pub teacher_error: f64,
}

impl SweepSpec {
    /// Materialize the grid in its one deterministic order: seeds →
    /// thetas → edge counts → detectors → hidden widths → loss probs →
    /// teacher errors (first axis slowest, last axis fastest).
    pub fn cells(&self) -> Vec<(SweepCell, Scenario)> {
        let mut out = Vec::with_capacity(
            self.seeds.len()
                * self.thetas.len()
                * self.edge_counts.len()
                * self.detectors.len()
                * self.n_hiddens.len()
                * self.loss_probs.len()
                * self.teacher_errors.len(),
        );
        for &seed in &self.seeds {
            for &theta in &self.thetas {
                for &n_edges in &self.edge_counts {
                    for &detector in &self.detectors {
                        for &n_hidden in &self.n_hiddens {
                            for &loss_prob in &self.loss_probs {
                                for &teacher_error in &self.teacher_errors {
                                    let mut sc = self.base.clone();
                                    sc.fixed_theta = theta;
                                    sc.n_edges = n_edges;
                                    sc.detector = detector;
                                    sc.n_hidden = n_hidden;
                                    sc.channel.loss_prob = loss_prob;
                                    sc.teacher_error = teacher_error;
                                    out.push((
                                        SweepCell {
                                            index: out.len(),
                                            seed,
                                            theta,
                                            n_edges,
                                            detector,
                                            n_hidden,
                                            loss_prob,
                                            teacher_error,
                                        },
                                        sc,
                                    ));
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Precompute the execution plan: cell enumeration, memo slots,
    /// artifact/shuffle lifetimes, the memo ledger, and the grid
    /// fingerprint. `run_sweep*` and `odl-har sweep --dry-run` share this.
    pub fn plan(&self) -> SweepPlan {
        let cells = self.cells();
        let mut artifacts: Vec<ArtifactPlan> = Vec::new();
        let mut cell_slots = Vec::with_capacity(cells.len());
        let mut stats = SweepStats {
            cells: cells.len(),
            ..Default::default()
        };
        // record_pca is the one spec knob outside Scenario that changes
        // row bytes (pca_eigenvalues), so it belongs in the fingerprint
        let mut grid = hash_fold(
            hash_fold(0x6B1D, cells.len() as u64),
            self.record_pca as u64,
        );
        for (i, (cell, sc)) in cells.iter().enumerate() {
            grid = hash_fold(grid, scenario_fingerprint(sc, cell.seed));
            let key = ProvisionArtifacts::data_key(sc, cell.seed);
            let slot = match artifacts.iter().position(|a| a.key == key) {
                Some(slot) => {
                    stats.artifact_hits += 1;
                    let a = &mut artifacts[slot];
                    a.last_cell = i;
                    a.uses += 1;
                    slot
                }
                None => {
                    stats.artifact_builds += 1;
                    artifacts.push(ArtifactPlan {
                        key,
                        first_cell: i,
                        last_cell: i,
                        uses: 1,
                        shuffles: Vec::new(),
                    });
                    artifacts.len() - 1
                }
            };
            let a = &mut artifacts[slot];
            let shuf = match a.shuffles.iter().position(|s| s.seed == cell.seed) {
                Some(shuf) => {
                    stats.shuffle_hits += 1;
                    let s = &mut a.shuffles[shuf];
                    s.last_cell = i;
                    s.uses += 1;
                    shuf
                }
                None => {
                    stats.shuffle_builds += 1;
                    a.shuffles.push(ShufflePlan {
                        seed: cell.seed,
                        first_cell: i,
                        last_cell: i,
                        uses: 1,
                    });
                    a.shuffles.len() - 1
                }
            };
            cell_slots.push((slot, shuf));
        }
        SweepPlan {
            cells,
            artifacts,
            cell_slots,
            stats,
            grid_hash: grid,
        }
    }
}

/// Identity hash of one cell's full scenario under its simulation seed —
/// every field that can move a report bit. Exhaustive destructuring (no
/// `..` rest pattern): adding a `Scenario` field without extending this
/// hash is a compile error, not a silent resume-compatibility hole.
fn scenario_fingerprint(sc: &Scenario, seed: u64) -> u64 {
    let Scenario {
        n_edges,
        n_hidden,
        event_period_s,
        horizon_s,
        drift_at_s,
        detector,
        fixed_theta,
        teacher_error,
        channel,
        synth: _, // covered (with the resolved data seed) by data_key below
        train_target,
        eval_period_s,
        eval_samples,
        eval_costs_power,
        data_seed,
    } = sc;
    let ChannelConfig {
        latency_s,
        loss_prob,
        max_retries,
    } = channel;
    let detector_tag = match detector {
        DetectorKind::Oracle => 1u64,
        DetectorKind::Centroid => 2,
    };
    let mut k = 0x5EE9_u64;
    for v in [
        seed,
        *n_edges as u64,
        *n_hidden as u64,
        event_period_s.to_bits(),
        horizon_s.to_bits(),
        drift_at_s.to_bits(),
        detector_tag,
        fixed_theta.is_some() as u64,
        fixed_theta.unwrap_or(0.0).to_bits() as u64,
        teacher_error.to_bits(),
        latency_s.to_bits(),
        loss_prob.to_bits(),
        *max_retries as u64,
        *train_target as u64,
        eval_period_s.to_bits(),
        *eval_samples as u64,
        *eval_costs_power as u64,
        data_seed.is_some() as u64,
        data_seed.unwrap_or(0),
        ProvisionArtifacts::data_key(sc, seed),
    ] {
        k = hash_fold(k, v);
    }
    k
}

/// Memoization accounting, computed from the plan (never from execution,
/// so a resumed run reports the same ledger an uninterrupted run would):
/// `artifact_builds + artifact_hits == cells` and
/// `shuffle_builds + shuffle_hits == cells`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepStats {
    pub cells: usize,
    pub artifact_builds: usize,
    pub artifact_hits: usize,
    pub shuffle_builds: usize,
    pub shuffle_hits: usize,
}

/// Lifetime plan for one memoized artifact slot: built lazily at
/// `first_cell`, lent to `uses` cells, dropped when the cell at
/// `last_cell` finishes.
#[derive(Clone, Debug)]
pub struct ArtifactPlan {
    pub key: u64,
    pub first_cell: usize,
    pub last_cell: usize,
    pub uses: usize,
    /// Per-`(slot, fleet seed)` shuffled-pool memo, in first-use order.
    pub shuffles: Vec<ShufflePlan>,
}

/// Lifetime plan for one memoized shuffled pool (keyed by the fleet seed
/// within its artifact slot).
#[derive(Clone, Debug)]
pub struct ShufflePlan {
    pub seed: u64,
    pub first_cell: usize,
    pub last_cell: usize,
    pub uses: usize,
}

/// The precomputed execution plan shared by the engine and `--dry-run`.
pub struct SweepPlan {
    pub cells: Vec<(SweepCell, Scenario)>,
    pub artifacts: Vec<ArtifactPlan>,
    /// cell index → (artifact slot, shuffle slot within that artifact).
    pub cell_slots: Vec<(usize, usize)>,
    pub stats: SweepStats,
    /// Fingerprint of the enumerated grid (every cell's full scenario);
    /// the resume header's compatibility check.
    pub grid_hash: u64,
}

/// The engine's result: per-cell reports in cell order plus the
/// memoization ledger.
pub struct SweepOutcome {
    pub reports: Vec<(SweepCell, FleetReport)>,
    pub stats: SweepStats,
}

/// Outcome of [`resume_sweep_to_file`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResumeOutcome {
    /// Completed cells kept from the existing file (original bytes).
    pub skipped: usize,
    /// Cells (re-)run by this invocation.
    pub ran: usize,
    /// The file already held the full grid plus trailer; nothing was
    /// written.
    pub already_complete: bool,
    pub stats: SweepStats,
}

/// Re-orders out-of-order line completions so the output stream is written
/// strictly in slot order regardless of worker scheduling.
struct OrderedSink<W: Write> {
    next: usize,
    pending: BTreeMap<usize, String>,
    out: W,
}

impl<W: Write> OrderedSink<W> {
    fn new(out: W) -> Self {
        OrderedSink::starting_at(out, 0)
    }

    /// A sink whose first expected slot is `next` — the resume path seeds
    /// it past the header and the kept prefix rows.
    fn starting_at(out: W, next: usize) -> Self {
        OrderedSink {
            next,
            pending: BTreeMap::new(),
            out,
        }
    }

    fn push(&mut self, index: usize, line: String) -> std::io::Result<()> {
        self.pending.insert(index, line);
        let mut wrote = false;
        while let Some(line) = self.pending.remove(&self.next) {
            self.out.write_all(line.as_bytes())?;
            self.out.write_all(b"\n")?;
            self.next += 1;
            wrote = true;
        }
        // flush only when a line actually drained — keeps tail -f
        // streaming without paying a syscall for buffered-only pushes
        if wrote {
            self.out.flush()?;
        }
        Ok(())
    }
}

/// The per-cell results row: grid coordinates + a `FleetReport` rollup.
pub fn cell_row(cell: &SweepCell, report: &FleetReport, artifacts: &ProvisionArtifacts) -> Json {
    let edges = report.per_edge.len().max(1) as f64;
    // Mean of the last rolling-accuracy checkpoint over the edges that
    // have one (traces checkpoint every 50 predictions, so short horizons
    // may leave some — or all — edges without a reading; averaging those
    // in as 0.0 would skew the rollup). Null when no edge has reported.
    let acc_readings: Vec<f64> = report
        .per_edge
        .iter()
        .filter_map(|m| m.accuracy_trace.last().map(|&(_, a)| a))
        .collect();
    let final_acc = if acc_readings.is_empty() {
        Json::Null
    } else {
        Json::Num(acc_readings.iter().sum::<f64>() / acc_readings.len() as f64)
    };
    let comm: f64 = report.per_edge.iter().map(|m| m.comm_fraction()).sum::<f64>() / edges;
    let trained: u64 = report.per_edge.iter().map(|m| m.trained).sum();
    let mut pairs = vec![
        ("cell", Json::Num(cell.index as f64)),
        ("seed", Json::Num(cell.seed as f64)),
        (
            "theta",
            match cell.theta {
                Some(t) => Json::Num(t as f64),
                None => Json::Str("auto".into()),
            },
        ),
        ("n_edges", Json::Num(cell.n_edges as f64)),
        ("detector", Json::Str(cell.detector.name().into())),
        ("n_hidden", Json::Num(cell.n_hidden as f64)),
        ("loss_prob", Json::Num(cell.loss_prob)),
        ("teacher_error", Json::Num(cell.teacher_error)),
        ("data_key", Json::Str(format!("{:016x}", artifacts.key))),
        ("queries", Json::Num(report.total_queries() as f64)),
        ("trained", Json::Num(trained as f64)),
        ("teacher_queries", Json::Num(report.teacher_queries as f64)),
        ("channel_attempts", Json::Num(report.channel_attempts as f64)),
        ("channel_failures", Json::Num(report.channel_failures as f64)),
        ("comm_fraction", Json::Num(comm)),
        ("final_accuracy", final_acc),
        ("mean_edge_power_mw", Json::Num(report.mean_edge_power_mw())),
        ("total_energy_mj", Json::Num(report.total_energy_mj())),
    ];
    if let Some(pca) = &artifacts.pca {
        pairs.push((
            "pca_eigenvalues",
            Json::Arr(pca.eigenvalues.iter().map(|&e| Json::Num(e as f64)).collect()),
        ));
    }
    obj(pairs)
}

fn header_json(plan: &SweepPlan) -> Json {
    obj(vec![
        ("schema", Json::Str(SCHEMA.into())),
        ("cells", Json::Num(plan.cells.len() as f64)),
        ("grid_hash", Json::Str(format!("{:016x}", plan.grid_hash))),
    ])
}

fn trailer_json(stats: &SweepStats) -> Json {
    obj(vec![(
        "stats",
        obj(vec![
            ("cells", Json::Num(stats.cells as f64)),
            ("artifact_builds", Json::Num(stats.artifact_builds as f64)),
            ("artifact_hits", Json::Num(stats.artifact_hits as f64)),
            ("shuffle_builds", Json::Num(stats.shuffle_builds as f64)),
            ("shuffle_hits", Json::Num(stats.shuffle_hits as f64)),
        ]),
    )])
}

/// Run the grid with memoized artifacts; collect reports only (no file).
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepOutcome> {
    let plan = spec.plan();
    let reports = run_cells::<std::io::Sink>(spec, &plan, 0, None)?;
    Ok(SweepOutcome {
        reports,
        stats: plan.stats,
    })
}

/// Run the grid, streaming one JSON row per cell (in cell order) into
/// `path` — a header line, the cell rows, and a stats trailer, one JSON
/// object per line.
pub fn run_sweep_to_file(spec: &SweepSpec, path: &Path) -> Result<SweepOutcome> {
    run_planned_to_file(spec, &spec.plan(), path)
}

/// [`run_sweep_to_file`] over an already-computed plan — for callers
/// (the CLI banner/dry-run, the resume path) that hold one anyway;
/// planning a large grid twice is pure waste. `plan` must come from
/// `spec.plan()`.
pub fn run_planned_to_file(spec: &SweepSpec, plan: &SweepPlan, path: &Path) -> Result<SweepOutcome> {
    let mut sink = OrderedSink::new(create_results_file(path)?);
    // header occupies slot 0; cell i lands in slot i + 1
    sink.push(0, header_json(plan).to_string())?;
    let sink = Mutex::new(sink);
    let reports = run_cells(spec, plan, 0, Some(&sink))?;
    let mut sink = sink.into_inner().expect("sweep sink poisoned");
    sink.push(plan.cells.len() + 1, trailer_json(&plan.stats).to_string())?;
    Ok(SweepOutcome {
        reports,
        stats: plan.stats,
    })
}

/// Resume (or start) a sweep into `path`. See the module docs for the
/// protocol; the post-condition is a results file byte-identical to an
/// uninterrupted [`run_sweep_to_file`] over the same spec.
pub fn resume_sweep_to_file(spec: &SweepSpec, path: &Path) -> Result<ResumeOutcome> {
    resume_planned_to_file(spec, &spec.plan(), path)
}

/// [`resume_sweep_to_file`] over an already-computed plan (see
/// [`run_planned_to_file`]). `plan` must come from `spec.plan()`.
pub fn resume_planned_to_file(
    spec: &SweepSpec,
    plan: &SweepPlan,
    path: &Path,
) -> Result<ResumeOutcome> {
    let n = plan.cells.len();
    let text = if path.exists() {
        std::fs::read_to_string(path)
            .with_context(|| format!("reading results file {}", path.display()))?
    } else {
        String::new()
    };
    // Complete lines only: a kill mid-write can leave a trailing partial
    // line, which resume must discard, never trust. split('\n') makes the
    // final element either "" (text ended with a newline) or the partial
    // line — pop it either way.
    let mut lines: Vec<&str> = text.split('\n').collect();
    lines.pop();
    if lines.is_empty() {
        // missing, empty, or truncated-to-nothing: a fresh full run
        let outcome = run_planned_to_file(spec, plan, path)?;
        return Ok(ResumeOutcome {
            skipped: 0,
            ran: n,
            already_complete: false,
            stats: outcome.stats,
        });
    }
    let header = header_json(plan).to_string();
    ensure!(
        lines[0] == header,
        "refusing to resume {}: its header does not match this spec \
         (different grid, schema version, or engine revision)",
        path.display()
    );
    // The longest valid prefix of completed cell rows. Error rows and
    // anything after the first gap are re-run.
    let mut done = 0usize;
    for line in &lines[1..] {
        if done >= n {
            break;
        }
        let row = match Json::parse(line) {
            Ok(row) => row,
            Err(_) => break,
        };
        if row.get("error").is_some() || row.get("cell").and_then(Json::as_usize) != Some(done) {
            break;
        }
        done += 1;
    }
    let trailer = trailer_json(&plan.stats).to_string();
    // complete = header + n rows + trailer and nothing else; extra
    // trailing lines would survive an early return and break the
    // byte-identical post-condition
    if done == n
        && lines.len() == n + 2
        && lines.get(1 + n).copied() == Some(trailer.as_str())
    {
        return Ok(ResumeOutcome {
            skipped: n,
            ran: 0,
            already_complete: true,
            stats: plan.stats,
        });
    }
    // Rewrite: header + the verified prefix (original bytes, verbatim),
    // then run the remaining cells into the ordered sink and close with
    // the trailer.
    let mut out = create_results_file(path)?;
    out.write_all(header.as_bytes())?;
    out.write_all(b"\n")?;
    for line in lines.iter().skip(1).take(done) {
        out.write_all(line.as_bytes())?;
        out.write_all(b"\n")?;
    }
    out.flush()?;
    let sink = Mutex::new(OrderedSink::starting_at(out, done + 1));
    run_cells(spec, plan, done, Some(&sink))?;
    let mut sink = sink.into_inner().expect("sweep sink poisoned");
    sink.push(n + 1, trailer)?;
    Ok(ResumeOutcome {
        skipped: done,
        ran: n - done,
        already_complete: false,
        stats: plan.stats,
    })
}

fn create_results_file(path: &Path) -> Result<std::io::BufWriter<std::fs::File>> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating results file {}", path.display()))?;
    Ok(std::io::BufWriter::new(file))
}

/// Per-slot memo state during a run: lazily built, refcounted down to
/// its planned drop point. The artifact and each (slot, seed) shuffle
/// carry independent locks so shuffles for distinct seeds build
/// concurrently (only peers needing the *same* shuffle block on its
/// build); no two locks are ever held at once — acquire takes artifact
/// then shuffle, release takes shuffle then artifact, each dropped
/// before the next is taken, so lock order cannot deadlock.
struct Slot {
    artifact: Mutex<ArtifactState>,
    shuffles: Vec<Mutex<ShuffleState>>,
}

struct ArtifactState {
    artifact: Option<Arc<ProvisionArtifacts>>,
    /// Cells (of this invocation) that still need this artifact.
    remaining: usize,
}

struct ShuffleState {
    train: Option<Arc<Dataset>>,
    remaining: usize,
}

/// Run cells `first..` of the plan (0 for a full run; the kept-prefix
/// length when resuming) over the worker pool, with lazily built,
/// last-use-dropped memo state. Returns the reports of exactly the cells
/// it ran, in cell order.
fn run_cells<W: Write + Send>(
    spec: &SweepSpec,
    plan: &SweepPlan,
    first: usize,
    sink: Option<&Mutex<OrderedSink<W>>>,
) -> Result<Vec<(SweepCell, FleetReport)>> {
    let n = plan.cells.len();
    // Remaining-use counts restricted to the cells this invocation
    // actually runs, so a resume drops (or never builds) memo state whose
    // uses all sit in the completed prefix.
    let slots: Vec<Slot> = plan
        .artifacts
        .iter()
        .map(|a| Slot {
            artifact: Mutex::new(ArtifactState {
                artifact: None,
                remaining: 0,
            }),
            shuffles: a
                .shuffles
                .iter()
                .map(|_| {
                    Mutex::new(ShuffleState {
                        train: None,
                        remaining: 0,
                    })
                })
                .collect(),
        })
        .collect();
    for &(slot, shuf) in &plan.cell_slots[first..] {
        slots[slot]
            .artifact
            .lock()
            .expect("sweep slot poisoned")
            .remaining += 1;
        slots[slot].shuffles[shuf]
            .lock()
            .expect("sweep shuffle poisoned")
            .remaining += 1;
    }

    let run_cell = |i: usize| -> Result<FleetReport> {
        let (cell, sc) = &plan.cells[i];
        let (slot, shuf) = plan.cell_slots[i];
        // Acquire: build lazily under the respective lock. Whichever
        // worker gets there first builds; only peers needing the *same*
        // artifact / shuffle block until that build lands. Builds are
        // pure functions of the key / (key, seed), so the scheduling
        // race cannot change a bit.
        let artifacts = {
            let mut st = slots[slot].artifact.lock().expect("sweep slot poisoned");
            st.artifact
                .get_or_insert_with(|| {
                    Arc::new(ProvisionArtifacts::build(sc, cell.seed, spec.record_pca))
                })
                .clone()
        };
        let train = {
            let mut sh = slots[slot].shuffles[shuf]
                .lock()
                .expect("sweep shuffle poisoned");
            sh.train
                .get_or_insert_with(|| Arc::new(artifacts.shuffled_train(cell.seed)))
                .clone()
        };
        let result = Fleet::with_shuffled_pool(
            FleetConfig {
                scenario: sc.clone(),
                seed: cell.seed,
            },
            &artifacts,
            &train,
            1,
        )
        .map(|fleet| fleet.run_parallel(1));
        if let Some(sink) = sink {
            // a failed cell still claims its slot (with an error row) so
            // the ordered sink can drain every later cell's completed row
            // instead of buffering them forever behind the gap
            let line = match &result {
                Ok(report) => cell_row(cell, report, &artifacts).to_string(),
                Err(e) => obj(vec![
                    ("cell", Json::Num(cell.index as f64)),
                    ("error", Json::Str(e.to_string())),
                ])
                .to_string(),
            };
            sink.lock()
                .expect("sweep sink poisoned")
                // slot 0 is the header line
                .push(i + 1, line)
                .context("writing sweep results row")?;
        }
        // Release: drop this worker's handles, then retire the memo state
        // at its planned last use so peak memory tracks the in-flight
        // working set, not the grid's seed count.
        drop(train);
        drop(artifacts);
        {
            let mut sh = slots[slot].shuffles[shuf]
                .lock()
                .expect("sweep shuffle poisoned");
            sh.remaining -= 1;
            if sh.remaining == 0 {
                sh.train = None;
            }
        }
        {
            let mut st = slots[slot].artifact.lock().expect("sweep slot poisoned");
            st.remaining -= 1;
            if st.remaining == 0 {
                st.artifact = None;
            }
        }
        result
    };

    let results = parallel::parallel_map_n(spec.workers, n - first, |j| run_cell(first + j));
    let mut reports = Vec::with_capacity(n - first);
    for ((cell, _), report) in plan.cells[first..].iter().zip(results) {
        reports.push((
            *cell,
            report.with_context(|| format!("sweep cell {} (seed {})", cell.index, cell.seed))?,
        ));
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthConfig;

    fn small_base() -> Scenario {
        Scenario {
            n_edges: 2,
            n_hidden: 16,
            event_period_s: 1.0,
            horizon_s: 80.0,
            drift_at_s: 25.0,
            train_target: 40,
            synth: SynthConfig {
                n_features: 24,
                n_classes: 3,
                n_subjects: 30,
                samples_per_cell: 4,
                proto_sigma: 1.1,
                confuse_frac: 0.04,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    fn small_spec() -> SweepSpec {
        let base = {
            let mut b = small_base();
            b.data_seed = Some(0x5EED);
            b
        };
        SweepSpec {
            seeds: vec![1, 2],
            thetas: vec![None, Some(0.2)],
            edge_counts: vec![2, 3],
            detectors: vec![DetectorKind::Oracle],
            n_hiddens: vec![base.n_hidden],
            loss_probs: vec![base.channel.loss_prob],
            teacher_errors: vec![base.teacher_error],
            workers: 2,
            record_pca: false,
            base,
        }
    }

    /// A grid that exercises the three new axes (hidden width, channel
    /// loss, teacher error) over one seed.
    fn new_axes_spec() -> SweepSpec {
        let base = {
            let mut b = small_base();
            b.data_seed = Some(0xA7E5);
            b
        };
        SweepSpec {
            seeds: vec![1],
            thetas: vec![None],
            edge_counts: vec![2],
            detectors: vec![DetectorKind::Oracle],
            n_hiddens: vec![16, 24],
            loss_probs: vec![0.0, 0.3],
            teacher_errors: vec![0.0, 0.3],
            workers: 2,
            record_pca: false,
            base,
        }
    }

    #[test]
    fn grid_enumeration_is_deterministic_and_complete() {
        let spec = small_spec();
        let cells = spec.cells();
        assert_eq!(cells.len(), 2 * 2 * 2);
        assert_eq!(cells[0].0.index, 0);
        // seeds are the slowest axis; with the trailing axes at their
        // one-element defaults, edge counts vary fastest here
        assert_eq!(cells[0].0.seed, 1);
        assert_eq!(cells[cells.len() - 1].0.seed, 2);
        assert_eq!(cells[0].0.theta, None);
        assert_eq!(cells[1].0.n_edges, 3);
        let again = spec.cells();
        for (a, b) in cells.iter().zip(&again) {
            assert_eq!(a.0, b.0);
        }
    }

    #[test]
    fn new_axes_enumerate_fastest_last() {
        let spec = new_axes_spec();
        let cells = spec.cells();
        assert_eq!(cells.len(), 2 * 2 * 2);
        // teacher error is the fastest axis, then loss, then n_hidden
        assert_eq!(
            (cells[0].0.n_hidden, cells[0].0.loss_prob, cells[0].0.teacher_error),
            (16, 0.0, 0.0)
        );
        assert_eq!(cells[1].0.teacher_error, 0.3);
        assert_eq!(cells[2].0.loss_prob, 0.3);
        assert_eq!(cells[4].0.n_hidden, 24);
        // and each cell's scenario carries the overrides
        for (cell, sc) in &cells {
            assert_eq!(sc.n_hidden, cell.n_hidden);
            assert_eq!(sc.channel.loss_prob, cell.loss_prob);
            assert_eq!(sc.teacher_error, cell.teacher_error);
        }
    }

    #[test]
    fn memoization_fits_data_once_per_config() {
        let spec = small_spec();
        let outcome = run_sweep(&spec).unwrap();
        assert_eq!(outcome.stats.cells, 8);
        // pinned data_seed → one data config across the whole grid
        assert_eq!(outcome.stats.artifact_builds, 1);
        assert_eq!(outcome.stats.artifact_hits, 7);
        // the per-fleet shuffle memoizes per (data key, seed)
        assert_eq!(outcome.stats.shuffle_builds, 2);
        assert_eq!(outcome.stats.shuffle_hits, 6);
    }

    #[test]
    fn derived_data_seed_memoizes_per_simulation_seed() {
        let mut spec = small_spec();
        spec.base.data_seed = None;
        let outcome = run_sweep(&spec).unwrap();
        // one build per distinct sim seed, hits for the rest of the grid
        assert_eq!(outcome.stats.artifact_builds, 2);
        assert_eq!(outcome.stats.artifact_hits, 6);
        assert_eq!(outcome.stats.shuffle_builds, 2);
        assert_eq!(outcome.stats.shuffle_hits, 6);
    }

    #[test]
    fn plan_tracks_artifact_and_shuffle_lifetimes() {
        let spec = small_spec();
        let plan = spec.plan();
        assert_eq!(plan.artifacts.len(), 1);
        let a = &plan.artifacts[0];
        assert_eq!((a.first_cell, a.last_cell, a.uses), (0, 7, 8));
        // seeds are the slowest axis: seed 1 owns cells 0..=3, seed 2
        // cells 4..=7 — the shuffle drop points the engine retires at
        assert_eq!(a.shuffles.len(), 2);
        let s0 = &a.shuffles[0];
        assert_eq!((s0.seed, s0.first_cell, s0.last_cell, s0.uses), (1, 0, 3, 4));
        let s1 = &a.shuffles[1];
        assert_eq!((s1.seed, s1.first_cell, s1.last_cell, s1.uses), (2, 4, 7, 4));
        assert_eq!(
            plan.stats,
            SweepStats {
                cells: 8,
                artifact_builds: 1,
                artifact_hits: 7,
                shuffle_builds: 2,
                shuffle_hits: 6,
            }
        );
        // every cell points at a live slot
        for (i, &(slot, shuf)) in plan.cell_slots.iter().enumerate() {
            let a = &plan.artifacts[slot];
            assert!(a.first_cell <= i && i <= a.last_cell);
            let s = &a.shuffles[shuf];
            assert!(s.first_cell <= i && i <= s.last_cell);
        }
    }

    #[test]
    fn sweep_reports_bitwise_match_individually_built_fleets() {
        let spec = small_spec();
        let outcome = run_sweep(&spec).unwrap();
        for ((cell, report), (_, sc)) in outcome.reports.iter().zip(spec.cells()) {
            let direct = Fleet::new(FleetConfig {
                scenario: sc,
                seed: cell.seed,
            })
            .unwrap()
            .run();
            assert!(
                direct.bitwise_eq(report),
                "cell {} diverged from the individually built fleet",
                cell.index
            );
        }
    }

    #[test]
    fn new_axes_cells_bitwise_match_individually_built_fleets() {
        let spec = new_axes_spec();
        let outcome = run_sweep(&spec).unwrap();
        // model/connectivity/supervision axes are simulation knobs, not
        // data knobs: the pinned data seed still fits the pool once, and
        // one seed means one shuffle
        assert_eq!(outcome.stats.artifact_builds, 1);
        assert_eq!(outcome.stats.shuffle_builds, 1);
        for ((cell, report), (_, sc)) in outcome.reports.iter().zip(spec.cells()) {
            let direct = Fleet::new(FleetConfig {
                scenario: sc,
                seed: cell.seed,
            })
            .unwrap()
            .run();
            assert!(
                direct.bitwise_eq(report),
                "cell {} diverged from the individually built fleet",
                cell.index
            );
        }
        // the axes must actually move the trajectories
        let r = &outcome.reports;
        assert!(!r[0].1.bitwise_eq(&r[1].1), "teacher-error axis is inert");
        assert!(!r[0].1.bitwise_eq(&r[2].1), "loss axis is inert");
        assert!(!r[0].1.bitwise_eq(&r[4].1), "n_hidden axis is inert");
    }

    #[test]
    fn worker_count_never_changes_results() {
        // the shared executor's canonical worker sweep, applied to whole
        // grid runs
        let mut spec = small_spec();
        spec.workers = parallel::WORKER_SWEEP[0];
        let reference = run_sweep(&spec).unwrap();
        for &workers in &parallel::WORKER_SWEEP[1..] {
            spec.workers = workers;
            let got = run_sweep(&spec).unwrap();
            assert_eq!(reference.stats, got.stats);
            for ((_, a), (_, b)) in reference.reports.iter().zip(&got.reports) {
                assert!(a.bitwise_eq(b), "sweep diverged at {workers} workers");
            }
        }
    }

    #[test]
    fn results_file_streams_rows_in_cell_order() {
        let spec = small_spec();
        let dir = std::env::temp_dir().join("odl_har_sweep_test");
        let path = dir.join("sweep.jsonl");
        let outcome = run_sweep_to_file(&spec, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // header + one row per cell + stats trailer
        assert_eq!(lines.len(), outcome.stats.cells + 2);
        let header = Json::parse(lines[0]).unwrap();
        assert_eq!(header.get("schema").unwrap().as_str().unwrap(), SCHEMA);
        assert_eq!(
            header.get("grid_hash").unwrap().as_str().unwrap(),
            format!("{:016x}", spec.plan().grid_hash)
        );
        for (i, line) in lines[1..=outcome.stats.cells].iter().enumerate() {
            let row = Json::parse(line).unwrap();
            assert_eq!(row.get("cell").unwrap().as_usize().unwrap(), i);
            assert!(row.get("final_accuracy").unwrap().as_f64().is_some());
            assert!(row.get("n_hidden").unwrap().as_usize().is_some());
            assert!(row.get("loss_prob").unwrap().as_f64().is_some());
            assert!(row.get("teacher_error").unwrap().as_f64().is_some());
        }
        let trailer = Json::parse(lines[lines.len() - 1]).unwrap();
        let stats = trailer.get("stats").unwrap();
        assert_eq!(
            stats.get("artifact_hits").unwrap().as_usize().unwrap(),
            outcome.stats.artifact_hits
        );
        assert_eq!(
            stats.get("shuffle_builds").unwrap().as_usize().unwrap(),
            outcome.stats.shuffle_builds
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_is_byte_identical_across_cut_points() {
        // the acceptance contract, over a grid exercising the three new
        // axes: resuming from any interruption point reproduces the
        // uninterrupted file byte for byte
        let spec = new_axes_spec();
        let n = spec.cells().len();
        let dir = std::env::temp_dir().join("odl_har_sweep_resume_test");
        std::fs::create_dir_all(&dir).unwrap();
        let full_path = dir.join("full.jsonl");
        run_sweep_to_file(&spec, &full_path).unwrap();
        let full = std::fs::read_to_string(&full_path).unwrap();
        let lines: Vec<&str> = full.lines().collect();
        assert_eq!(lines.len(), n + 2);

        for cut in [0usize, 1, 3, n, n + 2] {
            // keep header + `cut` rows (cut = n + 2 keeps trailer too)
            let keep = (cut + 1).min(lines.len());
            let text: String = lines[..keep].iter().map(|l| format!("{l}\n")).collect();
            let path = dir.join(format!("cut{cut}.jsonl"));
            std::fs::write(&path, &text).unwrap();
            let out = resume_sweep_to_file(&spec, &path).unwrap();
            assert_eq!(
                std::fs::read_to_string(&path).unwrap(),
                full,
                "resume from a {cut}-row prefix must reproduce the full file"
            );
            if cut >= n + 2 {
                assert!(out.already_complete);
                assert_eq!((out.skipped, out.ran), (n, 0));
            } else {
                let done = cut.min(n);
                assert!(!out.already_complete);
                assert_eq!((out.skipped, out.ran), (done, n - done));
            }
        }

        // junk appended after a complete stream is not "already
        // complete": resume must rewrite back to the canonical bytes
        let path = dir.join("appended.jsonl");
        std::fs::write(&path, format!("{full}{{\"cell\":0}}\n")).unwrap();
        let out = resume_sweep_to_file(&spec, &path).unwrap();
        assert!(!out.already_complete);
        assert_eq!((out.skipped, out.ran), (n, 0));
        assert_eq!(std::fs::read_to_string(&path).unwrap(), full);

        // a partial trailing line (kill mid-write) is discarded, never
        // trusted
        let mut text: String = lines[..3].iter().map(|l| format!("{l}\n")).collect();
        text.push_str("{\"cell\":2,\"trunc");
        let path = dir.join("partial.jsonl");
        std::fs::write(&path, &text).unwrap();
        let out = resume_sweep_to_file(&spec, &path).unwrap();
        assert_eq!((out.skipped, out.ran), (2, n - 2));
        assert_eq!(std::fs::read_to_string(&path).unwrap(), full);

        // missing file = fresh full run through the resume entry point
        let path = dir.join("fresh.jsonl");
        let out = resume_sweep_to_file(&spec, &path).unwrap();
        assert_eq!((out.skipped, out.ran), (0, n));
        assert_eq!(std::fs::read_to_string(&path).unwrap(), full);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_rejects_a_mismatched_grid() {
        let spec = small_spec();
        let dir = std::env::temp_dir().join("odl_har_sweep_mismatch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sweep.jsonl");
        run_sweep_to_file(&spec, &path).unwrap();
        // a different grid (extra seed) must refuse the existing file…
        let mut other = spec.clone();
        other.seeds.push(3);
        assert!(resume_sweep_to_file(&other, &path).is_err());
        // …as must a changed base scenario (same axes, different horizon)
        let mut other = spec.clone();
        other.base.horizon_s += 1.0;
        assert!(resume_sweep_to_file(&other, &path).is_err());
        // …and a flipped record_pca (it changes row bytes, so mixing it
        // into an existing file would break byte-identity)
        let mut other = spec.clone();
        other.record_pca = true;
        assert!(resume_sweep_to_file(&other, &path).is_err());
        // …and a file that is not a sweep stream at all
        let garbage = dir.join("garbage.jsonl");
        std::fs::write(&garbage, "{\"schema\":\"odl-har-sweep/v1\",\"cells\":8}\n").unwrap();
        assert!(resume_sweep_to_file(&spec, &garbage).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_pca_adds_eigenvalues_to_rows() {
        let mut spec = small_spec();
        spec.seeds = vec![1];
        spec.thetas = vec![None];
        spec.edge_counts = vec![2];
        spec.record_pca = true;
        let outcome = run_sweep(&spec).unwrap();
        let (cell, sc) = &spec.cells()[0];
        let artifacts = Arc::new(ProvisionArtifacts::build(sc, cell.seed, true));
        let row = cell_row(cell, &outcome.reports[0].1, &artifacts);
        let eig = row.get("pca_eigenvalues").unwrap().as_arr().unwrap();
        assert_eq!(eig.len(), 2);
        assert!(eig[0].as_f64().unwrap() >= eig[1].as_f64().unwrap());
    }
}
