//! Per-edge and fleet-level accounting: queries, energy, accuracy traces.

use crate::hw::PowerState;
use std::collections::BTreeMap;

/// Energy/activity ledger for one edge device.
#[derive(Clone, Debug, Default)]
pub struct EdgeMetrics {
    pub events: u64,
    pub queries: u64,
    pub skips: u64,
    pub trained: u64,
    pub query_failures: u64,
    pub mode_switches: u64,
    /// Core energy by state [mJ].
    pub core_energy_mj: f64,
    /// Radio energy [mJ].
    pub radio_energy_mj: f64,
    /// Time spent per state [s]. A `BTreeMap` so iteration (and therefore
    /// every `values().sum()` fold over it) has one fixed order — part of
    /// the bitwise-reproducibility contract of the fleet reports.
    pub state_time_s: BTreeMap<&'static str, f64>,
    /// (virtual time, rolling accuracy) checkpoints.
    pub accuracy_trace: Vec<(f64, f64)>,
    /// (virtual time, probe accuracy) from the fleet's periodic
    /// evaluation windows (batched predict over a probe set; empty when
    /// `Scenario::eval_period_s` is 0).
    pub eval_trace: Vec<(f64, f64)>,
    /// Rolling prediction-correctness window.
    correct_window: Vec<bool>,
}

impl EdgeMetrics {
    pub fn record_state(&mut self, state: PowerState, secs: f64, power_mw: f64) {
        let name = match state {
            PowerState::Sleep => "sleep",
            PowerState::Idle => "idle",
            PowerState::Predict => "predict",
            PowerState::Train => "train",
        };
        *self.state_time_s.entry(name).or_insert(0.0) += secs;
        self.core_energy_mj += power_mw * secs;
    }

    pub fn record_prediction(&mut self, now_s: f64, correct: bool) {
        self.correct_window.push(correct);
        if self.correct_window.len() >= 50 {
            let acc = self.correct_window.iter().filter(|&&c| c).count() as f64
                / self.correct_window.len() as f64;
            self.accuracy_trace.push((now_s, acc));
            self.correct_window.clear();
        }
    }

    /// Mean power over a horizon [mW].
    pub fn mean_power_mw(&self, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            0.0
        } else {
            (self.core_energy_mj + self.radio_energy_mj) / horizon_s
        }
    }

    /// Communication volume relative to always-querying on every event.
    pub fn comm_fraction(&self) -> f64 {
        let considered = self.queries + self.skips;
        if considered == 0 {
            0.0
        } else {
            self.queries as f64 / considered as f64
        }
    }

    /// Bitwise equality (floats compared by bit pattern) — the contract
    /// `Fleet::run_parallel` must meet against the sequential run.
    pub fn bitwise_eq(&self, o: &EdgeMetrics) -> bool {
        fn feq(a: f64, b: f64) -> bool {
            a.to_bits() == b.to_bits()
        }
        fn trace_eq(a: &[(f64, f64)], b: &[(f64, f64)]) -> bool {
            a.len() == b.len()
                && a.iter()
                    .zip(b)
                    .all(|(x, y)| feq(x.0, y.0) && feq(x.1, y.1))
        }
        self.events == o.events
            && self.queries == o.queries
            && self.skips == o.skips
            && self.trained == o.trained
            && self.query_failures == o.query_failures
            && self.mode_switches == o.mode_switches
            && feq(self.core_energy_mj, o.core_energy_mj)
            && feq(self.radio_energy_mj, o.radio_energy_mj)
            && self.state_time_s.len() == o.state_time_s.len()
            && self
                .state_time_s
                .iter()
                .zip(&o.state_time_s)
                .all(|((ka, va), (kb, vb))| ka == kb && feq(*va, *vb))
            && trace_eq(&self.accuracy_trace, &o.accuracy_trace)
            && trace_eq(&self.eval_trace, &o.eval_trace)
    }
}

/// Fleet-level rollup.
#[derive(Clone, Debug, Default)]
pub struct FleetReport {
    pub horizon_s: f64,
    pub per_edge: Vec<EdgeMetrics>,
    pub teacher_queries: u64,
    pub channel_attempts: u64,
    pub channel_failures: u64,
}

impl FleetReport {
    pub fn total_queries(&self) -> u64 {
        self.per_edge.iter().map(|m| m.queries).sum()
    }

    pub fn total_energy_mj(&self) -> f64 {
        self.per_edge
            .iter()
            .map(|m| m.core_energy_mj + m.radio_energy_mj)
            .sum()
    }

    pub fn mean_edge_power_mw(&self) -> f64 {
        if self.per_edge.is_empty() || self.horizon_s <= 0.0 {
            return 0.0;
        }
        self.total_energy_mj() / self.horizon_s / self.per_edge.len() as f64
    }

    /// Bitwise equality of the whole report — `run_parallel(k)` must
    /// satisfy `report.bitwise_eq(&sequential_report)` for every `k`.
    pub fn bitwise_eq(&self, o: &FleetReport) -> bool {
        self.horizon_s.to_bits() == o.horizon_s.to_bits()
            && self.teacher_queries == o.teacher_queries
            && self.channel_attempts == o.channel_attempts
            && self.channel_failures == o.channel_failures
            && self.per_edge.len() == o.per_edge.len()
            && self
                .per_edge
                .iter()
                .zip(&o.per_edge)
                .all(|(a, b)| a.bitwise_eq(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_accounting_accumulates() {
        let mut m = EdgeMetrics::default();
        m.record_state(PowerState::Sleep, 2.0, 1.33);
        m.record_state(PowerState::Predict, 0.036, 3.39);
        assert!((m.core_energy_mj - (2.0 * 1.33 + 0.036 * 3.39)).abs() < 1e-9);
        assert_eq!(m.state_time_s["sleep"], 2.0);
    }

    #[test]
    fn accuracy_trace_checkpoints_every_50() {
        let mut m = EdgeMetrics::default();
        for i in 0..125 {
            m.record_prediction(i as f64, i % 2 == 0);
        }
        assert_eq!(m.accuracy_trace.len(), 2);
        let (_, acc) = m.accuracy_trace[0];
        assert!((acc - 0.5).abs() < 0.03);
    }

    #[test]
    fn comm_fraction() {
        let m = EdgeMetrics {
            queries: 30,
            skips: 70,
            ..Default::default()
        };
        assert!((m.comm_fraction() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn fleet_rollup() {
        let mut r = FleetReport {
            horizon_s: 10.0,
            ..Default::default()
        };
        r.per_edge.push(EdgeMetrics {
            core_energy_mj: 20.0,
            radio_energy_mj: 10.0,
            queries: 5,
            ..Default::default()
        });
        r.per_edge.push(EdgeMetrics {
            core_energy_mj: 10.0,
            radio_energy_mj: 0.0,
            queries: 2,
            ..Default::default()
        });
        assert_eq!(r.total_queries(), 7);
        assert!((r.total_energy_mj() - 40.0).abs() < 1e-12);
        assert!((r.mean_edge_power_mw() - 2.0).abs() < 1e-12);
    }
}
