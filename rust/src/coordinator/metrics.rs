//! Per-edge and fleet-level accounting: queries, energy, accuracy traces.
//!
//! Two reporting modes ([`MetricsMode`]): `full` keeps one [`EdgeMetrics`]
//! row per edge (the historical report, memory O(n_edges)); `aggregate`
//! folds the fleet into a single fixed-size [`FleetAggregate`] of exact
//! counters plus streaming sketches (`util::sketch`), so report memory is
//! O(1) in fleet size — the mode the ≥100k-edge scale points run in.

use crate::hw::PowerState;
use crate::util::sketch::{Hll, QuantileSketch};

/// How the fleet reports: per-edge rows or O(1)-memory sketches.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MetricsMode {
    /// One `EdgeMetrics` row per edge (the default, and the only mode the
    /// bitwise per-edge determinism pins apply to).
    #[default]
    Full,
    /// Fixed-size `FleetAggregate` only; `FleetReport::per_edge` stays
    /// empty no matter the fleet size.
    Aggregate,
}

impl MetricsMode {
    pub fn name(self) -> &'static str {
        match self {
            MetricsMode::Full => "full",
            MetricsMode::Aggregate => "aggregate",
        }
    }

    /// Parse a config/CLI value. Errors name the offending value — the
    /// caller prefixes the key (`fleet.metrics` / `--metrics`).
    pub fn parse(s: &str) -> Result<MetricsMode, String> {
        match s {
            "full" => Ok(MetricsMode::Full),
            "aggregate" => Ok(MetricsMode::Aggregate),
            other => Err(format!(
                "unknown metrics mode `{other}` (expected `full` or `aggregate`)"
            )),
        }
    }
}

/// Number of power states tracked per edge.
pub const N_STATES: usize = 4;

/// JSON/report key per state slot — alphabetical, matching the iteration
/// order of the `BTreeMap<&'static str, f64>` this array replaced, so
/// every fold and report key sequence is byte-identical to the old ledger.
pub const STATE_NAMES: [&str; N_STATES] = ["idle", "predict", "sleep", "train"];

const fn state_slot(state: PowerState) -> usize {
    match state {
        PowerState::Idle => 0,
        PowerState::Predict => 1,
        PowerState::Sleep => 2,
        PowerState::Train => 3,
    }
}

/// Fixed enum-indexed per-state time ledger [s]. Replaces the old
/// per-edge `BTreeMap<&'static str, f64>`: no allocation, no string-key
/// comparisons on the hot path, same deterministic (alphabetical)
/// iteration order. Slots a run never touches stay exactly `0.0`, which
/// is bitwise-invisible to every nonnegative `values().sum()` fold
/// (IEEE `x + 0.0 == x` bitwise for `x >= 0.0`).
#[derive(Clone, Copy, Debug, Default)]
pub struct StateTimes([f64; N_STATES]);

impl StateTimes {
    pub fn add(&mut self, state: PowerState, secs: f64) {
        self.0[state_slot(state)] += secs;
    }

    pub fn get(&self, state: PowerState) -> f64 {
        self.0[state_slot(state)]
    }

    /// Values in slot (= alphabetical key) order.
    pub fn values(&self) -> std::slice::Iter<'_, f64> {
        self.0.iter()
    }

    /// `(key, seconds)` pairs in alphabetical key order.
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        STATE_NAMES.iter().zip(self.0.iter()).map(|(k, v)| (*k, *v))
    }

    pub fn bitwise_eq(&self, o: &StateTimes) -> bool {
        self.0
            .iter()
            .zip(&o.0)
            .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

impl std::ops::Index<&str> for StateTimes {
    type Output = f64;

    fn index(&self, key: &str) -> &f64 {
        match STATE_NAMES.iter().position(|n| *n == key) {
            Some(i) => &self.0[i],
            None => panic!("unknown power state key `{key}`"),
        }
    }
}

/// Energy/activity ledger for one edge device.
#[derive(Clone, Debug, Default)]
pub struct EdgeMetrics {
    pub events: u64,
    pub queries: u64,
    pub skips: u64,
    pub trained: u64,
    pub query_failures: u64,
    pub mode_switches: u64,
    /// Core energy by state [mJ].
    pub core_energy_mj: f64,
    /// Radio energy [mJ].
    pub radio_energy_mj: f64,
    /// Time spent per state [s], enum-indexed (see [`StateTimes`]).
    pub state_time_s: StateTimes,
    /// (virtual time, rolling accuracy) checkpoints.
    pub accuracy_trace: Vec<(f64, f64)>,
    /// (virtual time, probe accuracy) from the fleet's periodic
    /// evaluation windows (batched predict over a probe set; empty when
    /// `Scenario::eval_period_s` is 0).
    pub eval_trace: Vec<(f64, f64)>,
    /// Rolling prediction-correctness window.
    correct_window: Vec<bool>,
}

impl EdgeMetrics {
    pub fn record_state(&mut self, state: PowerState, secs: f64, power_mw: f64) {
        self.state_time_s.add(state, secs);
        self.core_energy_mj += power_mw * secs;
    }

    pub fn record_prediction(&mut self, now_s: f64, correct: bool) {
        self.correct_window.push(correct);
        if self.correct_window.len() >= 50 {
            let acc = self.correct_window.iter().filter(|&&c| c).count() as f64
                / self.correct_window.len() as f64;
            self.accuracy_trace.push((now_s, acc));
            self.correct_window.clear();
        }
    }

    /// Mean power over a horizon [mW].
    pub fn mean_power_mw(&self, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            0.0
        } else {
            (self.core_energy_mj + self.radio_energy_mj) / horizon_s
        }
    }

    /// Communication volume relative to always-querying on every event.
    pub fn comm_fraction(&self) -> f64 {
        let considered = self.queries + self.skips;
        if considered == 0 {
            0.0
        } else {
            self.queries as f64 / considered as f64
        }
    }

    /// Bitwise equality (floats compared by bit pattern) — the contract
    /// `Fleet::run_parallel` must meet against the sequential run.
    pub fn bitwise_eq(&self, o: &EdgeMetrics) -> bool {
        fn feq(a: f64, b: f64) -> bool {
            a.to_bits() == b.to_bits()
        }
        fn trace_eq(a: &[(f64, f64)], b: &[(f64, f64)]) -> bool {
            a.len() == b.len()
                && a.iter()
                    .zip(b)
                    .all(|(x, y)| feq(x.0, y.0) && feq(x.1, y.1))
        }
        self.events == o.events
            && self.queries == o.queries
            && self.skips == o.skips
            && self.trained == o.trained
            && self.query_failures == o.query_failures
            && self.mode_switches == o.mode_switches
            && feq(self.core_energy_mj, o.core_energy_mj)
            && feq(self.radio_energy_mj, o.radio_energy_mj)
            && self.state_time_s.bitwise_eq(&o.state_time_s)
            && trace_eq(&self.accuracy_trace, &o.accuracy_trace)
            && trace_eq(&self.eval_trace, &o.eval_trace)
    }
}

/// O(1)-memory fleet rollup: exact fleet-wide counters plus streaming
/// sketches over the per-edge distributions. The sketches are fed in a
/// canonical order (HLLs per-chunk + order-invariant merge, quantile
/// sketches on the single-threaded close-of-books walk in edge-id
/// order), so the whole struct is bitwise worker-count-invariant.
#[derive(Clone, Debug, Default)]
pub struct FleetAggregate {
    pub n_edges: u64,
    pub events: u64,
    pub trained: u64,
    pub skips: u64,
    pub query_failures: u64,
    pub mode_switches: u64,
    pub total_queries: u64,
    pub total_energy_mj: f64,
    /// Final rolling accuracy per edge (edges with no checkpoint skipped).
    pub accuracy: QuantileSketch,
    /// Mean power per edge over the horizon [mW].
    pub power_mw: QuantileSketch,
    /// Teacher queries per edge.
    pub queries: QuantileSketch,
    /// Distinct (drift-phase subject, class) cells sensed fleet-wide.
    pub visited_cells: Hll,
    /// Distinct (edge, FSM mode) states occupied at any point.
    pub edge_states: Hll,
}

impl FleetAggregate {
    pub fn bitwise_eq(&self, o: &FleetAggregate) -> bool {
        self.n_edges == o.n_edges
            && self.events == o.events
            && self.trained == o.trained
            && self.skips == o.skips
            && self.query_failures == o.query_failures
            && self.mode_switches == o.mode_switches
            && self.total_queries == o.total_queries
            && self.total_energy_mj.to_bits() == o.total_energy_mj.to_bits()
            && self.accuracy.bitwise_eq(&o.accuracy)
            && self.power_mw.bitwise_eq(&o.power_mw)
            && self.queries.bitwise_eq(&o.queries)
            && self.visited_cells.bitwise_eq(&o.visited_cells)
            && self.edge_states.bitwise_eq(&o.edge_states)
    }
}

/// Fleet-level rollup.
#[derive(Clone, Debug, Default)]
pub struct FleetReport {
    pub horizon_s: f64,
    /// Per-edge rows; empty in [`MetricsMode::Aggregate`].
    pub per_edge: Vec<EdgeMetrics>,
    pub teacher_queries: u64,
    pub channel_attempts: u64,
    pub channel_failures: u64,
    /// Present in [`MetricsMode::Aggregate`] (and only then).
    pub aggregate: Option<FleetAggregate>,
}

impl FleetReport {
    pub fn total_queries(&self) -> u64 {
        if self.per_edge.is_empty() {
            if let Some(agg) = &self.aggregate {
                return agg.total_queries;
            }
        }
        self.per_edge.iter().map(|m| m.queries).sum()
    }

    pub fn total_energy_mj(&self) -> f64 {
        if self.per_edge.is_empty() {
            if let Some(agg) = &self.aggregate {
                return agg.total_energy_mj;
            }
        }
        self.per_edge
            .iter()
            .map(|m| m.core_energy_mj + m.radio_energy_mj)
            .sum()
    }

    pub fn mean_edge_power_mw(&self) -> f64 {
        if self.horizon_s <= 0.0 {
            return 0.0;
        }
        let n = if self.per_edge.is_empty() {
            match &self.aggregate {
                Some(agg) if agg.n_edges > 0 => agg.n_edges as usize,
                _ => return 0.0,
            }
        } else {
            self.per_edge.len()
        };
        self.total_energy_mj() / self.horizon_s / n as f64
    }

    /// Bitwise equality of the whole report — `run_parallel(k)` must
    /// satisfy `report.bitwise_eq(&sequential_report)` for every `k`.
    pub fn bitwise_eq(&self, o: &FleetReport) -> bool {
        let agg_eq = match (&self.aggregate, &o.aggregate) {
            (None, None) => true,
            (Some(a), Some(b)) => a.bitwise_eq(b),
            _ => false,
        };
        self.horizon_s.to_bits() == o.horizon_s.to_bits()
            && self.teacher_queries == o.teacher_queries
            && self.channel_attempts == o.channel_attempts
            && self.channel_failures == o.channel_failures
            && agg_eq
            && self.per_edge.len() == o.per_edge.len()
            && self
                .per_edge
                .iter()
                .zip(&o.per_edge)
                .all(|(a, b)| a.bitwise_eq(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_accounting_accumulates() {
        let mut m = EdgeMetrics::default();
        m.record_state(PowerState::Sleep, 2.0, 1.33);
        m.record_state(PowerState::Predict, 0.036, 3.39);
        assert!((m.core_energy_mj - (2.0 * 1.33 + 0.036 * 3.39)).abs() < 1e-9);
        assert_eq!(m.state_time_s["sleep"], 2.0);
    }

    #[test]
    fn state_times_match_old_btreemap_contract() {
        // alphabetical (key, value) iteration, zero for untouched slots,
        // and a sum fold bitwise-unperturbed by those zeros
        let mut t = StateTimes::default();
        t.add(PowerState::Train, 0.25);
        t.add(PowerState::Predict, 0.125);
        let pairs: Vec<(&str, f64)> = t.iter().collect();
        assert_eq!(
            pairs,
            vec![("idle", 0.0), ("predict", 0.125), ("sleep", 0.0), ("train", 0.25)]
        );
        let sum: f64 = t.values().sum();
        assert_eq!(sum.to_bits(), (0.125f64 + 0.25).to_bits());
        assert_eq!(t["train"], 0.25);
        assert_eq!(t["idle"], 0.0);
        assert_eq!(t.get(PowerState::Predict), 0.125);
    }

    #[test]
    #[should_panic(expected = "unknown power state key")]
    fn state_times_rejects_unknown_key() {
        let t = StateTimes::default();
        let _ = t["awake"];
    }

    #[test]
    fn metrics_mode_parses_and_rejects() {
        assert_eq!(MetricsMode::parse("full").unwrap(), MetricsMode::Full);
        assert_eq!(
            MetricsMode::parse("aggregate").unwrap(),
            MetricsMode::Aggregate
        );
        assert_eq!(MetricsMode::default(), MetricsMode::Full);
        assert_eq!(MetricsMode::Aggregate.name(), "aggregate");
        let err = MetricsMode::parse("sketchy").unwrap_err();
        assert!(err.contains("sketchy"), "{err}");
    }

    #[test]
    fn accuracy_trace_checkpoints_every_50() {
        let mut m = EdgeMetrics::default();
        for i in 0..125 {
            m.record_prediction(i as f64, i % 2 == 0);
        }
        assert_eq!(m.accuracy_trace.len(), 2);
        let (_, acc) = m.accuracy_trace[0];
        assert!((acc - 0.5).abs() < 0.03);
    }

    #[test]
    fn comm_fraction() {
        let m = EdgeMetrics {
            queries: 30,
            skips: 70,
            ..Default::default()
        };
        assert!((m.comm_fraction() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn fleet_rollup() {
        let mut r = FleetReport {
            horizon_s: 10.0,
            ..Default::default()
        };
        r.per_edge.push(EdgeMetrics {
            core_energy_mj: 20.0,
            radio_energy_mj: 10.0,
            queries: 5,
            ..Default::default()
        });
        r.per_edge.push(EdgeMetrics {
            core_energy_mj: 10.0,
            radio_energy_mj: 0.0,
            queries: 2,
            ..Default::default()
        });
        assert_eq!(r.total_queries(), 7);
        assert!((r.total_energy_mj() - 40.0).abs() < 1e-12);
        assert!((r.mean_edge_power_mw() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn aggregate_backs_the_rollup_getters_when_per_edge_is_empty() {
        let r = FleetReport {
            horizon_s: 10.0,
            aggregate: Some(FleetAggregate {
                n_edges: 4,
                total_queries: 12,
                total_energy_mj: 80.0,
                ..Default::default()
            }),
            ..Default::default()
        };
        assert!(r.per_edge.is_empty());
        assert_eq!(r.total_queries(), 12);
        assert!((r.total_energy_mj() - 80.0).abs() < 1e-12);
        assert!((r.mean_edge_power_mw() - 2.0).abs() < 1e-12);
        // bitwise_eq covers the aggregate payload
        let mut other = r.clone();
        assert!(r.bitwise_eq(&other));
        other.aggregate.as_mut().unwrap().total_queries = 13;
        assert!(!r.bitwise_eq(&other));
    }
}
