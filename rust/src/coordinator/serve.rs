//! `odl-har serve` — the coordinator as a long-running teacher/label
//! service, plus `odl-har loadgen`, its deterministic chaos-tested edge
//! client.
//!
//! The server speaks the [`super::proto`] JSONL protocol over plain TCP
//! (std::net + std::thread — tokio is not in the offline vendor set).
//! Edge clients register with `hello`, stream feature vectors as
//! sequence-numbered `event`s, and get back the decision the coordinator
//! made against that client's own OS-ELM core: pruning-gate verdict,
//! predicted class, and (when the gate queried) the teacher's label after
//! a sequential train step.
//!
//! Robustness is the point, end to end:
//!
//! - **Admission control** — at most `max_clients` concurrent
//!   connections; over cap, the accept loop answers with a structured
//!   `busy` carrying `retry_after_ms` and closes, so clients back off
//!   instead of spinning.
//! - **Backpressure** — per-connection input is a bounded byte queue
//!   (`queue_depth` KiB); events ahead of the client's applied watermark
//!   are deterministically refused with `shed`, never buffered or
//!   reordered.
//! - **Deadlines** — every socket carries read/write timeouts
//!   (`read_timeout_ms`) and an idle deadline (`idle_timeout_ms`); a hung
//!   or stalled client is disconnected, it can never pin a worker thread.
//! - **Graceful drain** — a `shutdown` request stops the accept loop,
//!   lets in-flight handlers finish, then publishes every client's full
//!   state (OS-ELM β/P/steps, auto-θ ladder position, teacher RNG
//!   stream, applied watermark) through the crash-consistent temp file +
//!   fsync + rename path shared with the sweep engine. A restarted
//!   server restores the snapshot byte-identically and `welcome` tells
//!   each client exactly where to resume.
//! - **Exactly-once application** — events are applied in sequence
//!   order: replays of already-applied events are acknowledged as
//!   `duplicate` without touching the model, gaps are shed. Any
//!   interleaving of drops, delays, garbles, disconnects, and client
//!   crashes therefore converges to the same final state as an
//!   undisturbed run — the chaos suite asserts snapshot byte-equality.
//!
//! Fault injection rides [`crate::util::faults::FaultPlan`]'s network
//! kinds (`drop`/`delay`/`close`/`garble`, plus `kill` as a client-side
//! process abort). `#1` sites fire on the server's socket end, `#2` on
//! the client's; the serve entry points bind their end themselves, so
//! callers pass the parsed plan straight through.

use crate::coordinator::proto::{bits_of, DecisionAction, EventItem, Request, Response};
use crate::coordinator::teacher::Teacher;
use crate::data::synth::{SynthConfig, SynthHar};
use crate::data::Dataset;
use crate::odl::{AlphaKind, OsElm, OsElmConfig};
use crate::pruning::{
    warmup_for, AutoTheta, AutoThetaState, Decision, Metric, Pruner, ThetaPolicy,
};
use crate::storage::{validate_key, Storage, StorageConfig};
use crate::util::faults::{self, FaultKind, FaultPlan, NET_CLIENT, NET_SERVER};
use crate::util::json::{obj, Json};
use crate::util::rng::{hash_fold, mix64, stream_seed, Rng64};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Snapshot document schema tag.
pub const SNAPSHOT_SCHEMA: &str = "odl-har-serve-snapshot/v1";

// Per-client RNG stream domains (see `util::rng::stream_seed`).
const DOMAIN_TEACHER: u64 = 0x5E21;
const DOMAIN_EVENTS: u64 = 0x5E22;
const DOMAIN_JITTER: u64 = 0x5E23;

/// How long a `delay` network fault stalls one message [ms] — well below
/// the loadgen reply timeout, so a delayed message is late, not lost.
const DELAY_FAULT_MS: u64 = 25;

fn ms(v: u64) -> Duration {
    Duration::from_millis(v)
}

/// Stable 64-bit identity of a client name — keys every per-client RNG
/// stream, so state depends on the name alone, not on arrival order.
fn client_key(name: &str) -> u64 {
    name.bytes().fold(0x5EED_C11E_4775_0001, |acc, b| hash_fold(acc, b as u64))
}

/// Server configuration (the `[serve]` TOML section + scenario base).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address; port 0 picks an ephemeral port.
    pub bind: String,
    /// Admission cap: concurrent connections beyond this get `busy`.
    pub max_clients: usize,
    /// Per-connection input-queue bound in KiB; a connection that
    /// buffers more unconsumed bytes than this is dropped.
    pub queue_depth: usize,
    /// Socket read/write timeout [ms] — the deadline granularity.
    pub read_timeout_ms: u64,
    /// Disconnect a connection with no complete request for this long.
    pub idle_timeout_ms: u64,
    /// Suggested client back-off carried by `busy` and `shed`.
    pub retry_after_ms: u64,
    /// Shard worker threads driving the admitted connections (0 = one
    /// per available core). Connections are assigned round-robin; each
    /// worker runs a readiness loop over its own set, so 64 clients cost
    /// `workers` threads, not 64.
    pub workers: usize,
    /// Largest `events` frame the server accepts (elements per batched
    /// request); bigger frames are refused whole with `error`.
    pub max_batch: usize,
    /// Bench-only escape hatch: the pre-pool execution model, one OS
    /// thread per admitted connection. Not exposed via TOML or CLI —
    /// `bench_serve` uses it as the in-bench scaling baseline.
    pub thread_per_conn: bool,
    /// Pruning warmup override (None = `warmup_for(n_hidden)`).
    pub warmup: Option<usize>,
    /// Snapshot path: restored at startup if present, written on drain.
    pub snapshot: Option<PathBuf>,
    /// Result-storage backend for the snapshot (`[storage]` TOML section,
    /// `--storage` CLI): with a `uri` the snapshot path becomes an object
    /// key inside that backend; without one it stays a plain local path
    /// (routed through the local-dir backend so the atomic publish recipe
    /// is shared, not duplicated).
    pub storage: StorageConfig,
    /// Master seed for every per-client stream.
    pub seed: u64,
    /// Provisioning-pool seed (None = derived as `seed ^ 0xDA7A`).
    pub data_seed: Option<u64>,
    /// Oracle teacher label-error rate.
    pub teacher_error: f64,
    /// Fixed pruning θ (None = the paper's auto-θ ladder).
    pub fixed_theta: Option<f32>,
    /// Hidden width of each client's OS-ELM core.
    pub n_hidden: usize,
    /// Synthetic-HAR generator config (provisioning pool + loadgen).
    pub synth: SynthConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            bind: "127.0.0.1:0".into(),
            max_clients: 8,
            queue_depth: 64,
            read_timeout_ms: 250,
            idle_timeout_ms: 30_000,
            retry_after_ms: 50,
            workers: 0,
            max_batch: 16,
            thread_per_conn: false,
            warmup: None,
            snapshot: None,
            storage: StorageConfig::default(),
            seed: 1,
            data_seed: None,
            teacher_error: 0.0,
            fixed_theta: None,
            n_hidden: 32,
            synth: SynthConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn data_seed(&self) -> u64 {
        self.data_seed.unwrap_or(self.seed ^ 0xDA7A)
    }

    fn warmup_resolved(&self) -> usize {
        self.warmup.unwrap_or_else(|| warmup_for(self.n_hidden))
    }
}

/// One registered edge client's server-side state.
struct ClientState {
    model: OsElm,
    pruner: Pruner,
    teacher: Teacher,
    /// Applied watermark: the next event sequence number to accept.
    next_seq: u64,
    events: u64,
    trained: u64,
    skipped: u64,
}

/// Drain-time totals (everything in the snapshot plus the volatile
/// transport counters that are *deliberately* not snapshotted — they
/// vary with the fault schedule; model state must not).
#[derive(Clone, Debug, Default)]
pub struct ServeSummary {
    pub clients: usize,
    pub events: u64,
    pub trained: u64,
    pub skipped: u64,
    pub teacher_queries: u64,
    pub duplicates: u64,
    pub shed: u64,
    pub busy_rejections: u64,
    pub connections: u64,
    pub restored: bool,
    /// Shard workers the pool ran with (0 = legacy thread-per-connection).
    pub workers: usize,
}

impl ServeSummary {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema", Json::Str("odl-har-serve-summary/v1".into())),
            ("clients", Json::Num(self.clients as f64)),
            ("events", Json::Num(self.events as f64)),
            ("trained", Json::Num(self.trained as f64)),
            ("skipped", Json::Num(self.skipped as f64)),
            ("teacher_queries", Json::Num(self.teacher_queries as f64)),
            ("duplicates", Json::Num(self.duplicates as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("busy_rejections", Json::Num(self.busy_rejections as f64)),
            ("connections", Json::Num(self.connections as f64)),
            ("restored", Json::Bool(self.restored)),
            ("workers", Json::Num(self.workers as f64)),
        ])
    }
}

/// Build the provisioning pool the server batch-initializes every
/// client's core on (the paper's step 1: initial training happens before
/// deployment). Derived from `data_seed` alone, so every server
/// incarnation provisions identically.
fn provision_pool(cfg: &ServeConfig) -> Result<Dataset> {
    let mut rng = Rng64::new(cfg.data_seed());
    let pool = SynthHar::new(cfg.synth.clone(), &mut rng).generate(&mut rng);
    ensure!(
        pool.len() >= cfg.n_hidden,
        "provisioning pool has {} samples but OS-ELM init needs ≥ n_hidden = {} \
         (raise data.samples_per_cell or lower fleet.n_hidden)",
        pool.len(),
        cfg.n_hidden
    );
    Ok(pool)
}

/// The bare (un-provisioned) core for a named client — α comes from the
/// name hash, so restore can rebuild it without replaying `init_batch`.
fn client_shell(cfg: &ServeConfig, pool: &Dataset, name: &str) -> OsElm {
    let key = client_key(name);
    let model_cfg = OsElmConfig {
        n_in: pool.n_features(),
        n_hidden: cfg.n_hidden,
        n_out: pool.n_classes,
        alpha: AlphaKind::Hash,
        ..Default::default()
    };
    // ODLHash α ignores the RNG; the throwaway stream keeps the signature
    let mut rng = Rng64::new(stream_seed(cfg.seed, DOMAIN_TEACHER ^ 1, key));
    OsElm::new(model_cfg, &mut rng, (mix64(key) & 0xFFFF) as u16)
}

fn new_client(cfg: &ServeConfig, pool: &Dataset, name: &str) -> Result<ClientState> {
    let mut model = client_shell(cfg, pool, name);
    model
        .init_batch(&pool.xs, &pool.labels)
        .with_context(|| format!("provisioning client '{name}'"))?;
    let policy = match cfg.fixed_theta {
        Some(t) => ThetaPolicy::Fixed(t),
        None => ThetaPolicy::auto(),
    };
    let key = client_key(name);
    Ok(ClientState {
        model,
        pruner: Pruner::new(policy, Metric::P1P2, cfg.warmup_resolved()),
        teacher: Teacher::oracle(cfg.teacher_error, stream_seed(cfg.seed, DOMAIN_TEACHER, key)),
        next_seq: 0,
        events: 0,
        trained: 0,
        skipped: 0,
    })
}

/// Apply one in-order event to a client: predict → pruning gate →
/// (teacher label + sequential train | skip). Exactly the edge FSM's
/// training-mode step, run server-side against the client's own core.
fn apply_event(st: &mut ClientState, seq: u64, x: &[f32], true_label: usize, n_classes: usize) -> Response {
    let pred = st.model.predict(x);
    let decision =
        st.pruner
            .decide_with_logits(&pred, st.model.last_logits(), st.trained as usize, false);
    st.events += 1;
    st.next_seq = seq + 1;
    match decision {
        Decision::Skip => {
            st.pruner.observe(Decision::Skip, None);
            st.skipped += 1;
            Response::Decision {
                seq,
                action: DecisionAction::Skipped,
                class: pred.class,
                p1_bits: pred.p1.to_bits(),
                p2_bits: pred.p2.to_bits(),
                label: None,
            }
        }
        Decision::Query => {
            let label = st.teacher.respond(x, true_label, n_classes);
            st.pruner.observe(Decision::Query, Some(pred.class == label));
            st.model.train_step(x, label);
            st.trained += 1;
            Response::Decision {
                seq,
                action: DecisionAction::Trained,
                class: pred.class,
                p1_bits: pred.p1.to_bits(),
                p2_bits: pred.p2.to_bits(),
                label: Some(label),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Snapshot: the whole service state as one canonical JSON document.
// ---------------------------------------------------------------------

/// u64 values (RNG states, seeds) don't fit `f64` exactly — they travel
/// as decimal strings in snapshot documents.
fn u64_str(v: u64) -> Json {
    Json::Str(v.to_string())
}

fn parse_u64_str(j: Option<&Json>, what: &str) -> Result<u64> {
    match j {
        Some(Json::Str(s)) => s.parse::<u64>().with_context(|| format!("bad {what} '{s}'")),
        _ => bail!("snapshot missing string field '{what}'"),
    }
}

fn bits_arr(data: &[f32]) -> Json {
    Json::Arr(data.iter().map(|v| Json::Num(v.to_bits() as f64)).collect())
}

fn parse_bits_into(j: Option<&Json>, what: &str, out: &mut [f32]) -> Result<()> {
    let arr = match j {
        Some(Json::Arr(items)) => items,
        _ => bail!("snapshot missing array field '{what}'"),
    };
    ensure!(
        arr.len() == out.len(),
        "snapshot field '{what}' has {} entries, expected {}",
        arr.len(),
        out.len()
    );
    for (slot, v) in out.iter_mut().zip(arr.iter()) {
        let bits = v
            .as_usize()
            .with_context(|| format!("snapshot field '{what}' has a non-integer entry"))?;
        ensure!(bits <= u32::MAX as usize, "'{what}' entry {bits} exceeds u32");
        *slot = f32::from_bits(bits as u32);
    }
    Ok(())
}

fn num_field(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(Json::as_usize)
        .map(|v| v as u64)
        .with_context(|| format!("snapshot missing numeric field '{key}'"))
}

fn client_to_json(st: &ClientState) -> Json {
    let pruner = match &st.pruner.policy {
        ThetaPolicy::Fixed(t) => obj(vec![("fixed", Json::Num(t.to_bits() as f64))]),
        ThetaPolicy::Auto(a) => {
            let s = a.snapshot();
            obj(vec![(
                "auto",
                obj(vec![
                    ("idx", Json::Num(s.idx as f64)),
                    ("streak", Json::Num(s.streak as f64)),
                    ("x_required", Json::Num(s.x_required as f64)),
                    ("mismatch_hysteresis", Json::Num(s.mismatch_hysteresis as f64)),
                    ("mismatch_streak", Json::Num(s.mismatch_streak as f64)),
                    ("decreases", Json::Num(s.decreases as f64)),
                    ("increases", Json::Num(s.increases as f64)),
                ]),
            )])
        }
    };
    obj(vec![
        ("next_seq", Json::Num(st.next_seq as f64)),
        ("events", Json::Num(st.events as f64)),
        ("trained", Json::Num(st.trained as f64)),
        ("skipped", Json::Num(st.skipped as f64)),
        ("steps", Json::Num(st.model.steps as f64)),
        ("beta", bits_arr(&st.model.beta.data)),
        ("p", bits_arr(&st.model.p.data)),
        ("pruner", pruner),
        (
            "teacher",
            obj(vec![
                ("rng_state", u64_str(st.teacher.rng_state())),
                ("queries", Json::Num(st.teacher.queries_served as f64)),
            ]),
        ),
    ])
}

fn snapshot_to_string(cfg: &ServeConfig, pool: &Dataset, clients: &BTreeMap<String, ClientState>) -> String {
    let mut map = BTreeMap::new();
    for (name, st) in clients {
        map.insert(name.clone(), client_to_json(st));
    }
    let doc = obj(vec![
        ("schema", Json::Str(SNAPSHOT_SCHEMA.into())),
        (
            "config",
            obj(vec![
                ("n_in", Json::Num(pool.n_features() as f64)),
                ("n_hidden", Json::Num(cfg.n_hidden as f64)),
                ("n_out", Json::Num(pool.n_classes as f64)),
                ("seed", u64_str(cfg.seed)),
                ("data_seed", u64_str(cfg.data_seed())),
                ("teacher_error_bits", u64_str(cfg.teacher_error.to_bits())),
            ]),
        ),
        ("clients", Json::Obj(map)),
    ]);
    let mut text = doc.to_string();
    text.push('\n');
    text
}

/// Parse a snapshot document back into live client state, validating it
/// against the current config — restoring under a different scenario
/// would silently diverge, so shape/seed mismatches are hard errors.
fn parse_snapshot(text: &str, cfg: &ServeConfig, pool: &Dataset) -> Result<BTreeMap<String, ClientState>> {
    let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("snapshot parse: {e}"))?;
    let schema = doc.get("schema").and_then(Json::as_str).unwrap_or("");
    ensure!(schema == SNAPSHOT_SCHEMA, "snapshot schema '{schema}' != '{SNAPSHOT_SCHEMA}'");
    let sc = doc.get("config").context("snapshot missing 'config'")?;
    for (key, want) in [
        ("n_in", pool.n_features() as u64),
        ("n_hidden", cfg.n_hidden as u64),
        ("n_out", pool.n_classes as u64),
    ] {
        let got = num_field(sc, key)?;
        ensure!(got == want, "snapshot config {key} = {got} but server is configured with {want}");
    }
    for (key, want) in [
        ("seed", cfg.seed),
        ("data_seed", cfg.data_seed()),
        ("teacher_error_bits", cfg.teacher_error.to_bits()),
    ] {
        let got = parse_u64_str(sc.get(key), key)?;
        ensure!(got == want, "snapshot config {key} = {got} but server is configured with {want}");
    }

    let clients_json = match doc.get("clients") {
        Some(Json::Obj(m)) => m,
        _ => bail!("snapshot missing 'clients' object"),
    };
    let mut clients = BTreeMap::new();
    for (name, cj) in clients_json {
        let mut model = client_shell(cfg, pool, name);
        parse_bits_into(cj.get("beta"), "beta", &mut model.beta.data)
            .with_context(|| format!("client '{name}'"))?;
        parse_bits_into(cj.get("p"), "p", &mut model.p.data)
            .with_context(|| format!("client '{name}'"))?;
        model.steps = num_field(cj, "steps")?;

        let pj = cj.get("pruner").with_context(|| format!("client '{name}' missing pruner"))?;
        let policy = match (pj.get("fixed"), pj.get("auto")) {
            (Some(t), None) => {
                let bits = t.as_usize().context("pruner.fixed must be f32 bits")?;
                let theta = f32::from_bits(bits as u32);
                ensure!(
                    cfg.fixed_theta.map(f32::to_bits) == Some(theta.to_bits()),
                    "snapshot has fixed θ = {theta} but server pruning config disagrees"
                );
                ThetaPolicy::Fixed(theta)
            }
            (None, Some(aj)) => {
                ensure!(
                    cfg.fixed_theta.is_none(),
                    "snapshot has auto-θ state but server is configured with a fixed θ"
                );
                ThetaPolicy::Auto(AutoTheta::restore(AutoThetaState {
                    idx: num_field(aj, "idx")? as usize,
                    streak: num_field(aj, "streak")? as u32,
                    x_required: num_field(aj, "x_required")? as u32,
                    mismatch_hysteresis: num_field(aj, "mismatch_hysteresis")? as u32,
                    mismatch_streak: num_field(aj, "mismatch_streak")? as u32,
                    decreases: num_field(aj, "decreases")? as u32,
                    increases: num_field(aj, "increases")? as u32,
                }))
            }
            _ => bail!("client '{name}' pruner must be exactly one of fixed/auto"),
        };

        let tj = cj.get("teacher").with_context(|| format!("client '{name}' missing teacher"))?;
        let teacher = Teacher::oracle_from_state(
            cfg.teacher_error,
            parse_u64_str(tj.get("rng_state"), "teacher.rng_state")?,
            num_field(tj, "queries")?,
        );

        clients.insert(
            name.clone(),
            ClientState {
                model,
                pruner: Pruner::new(policy, Metric::P1P2, cfg.warmup_resolved()),
                teacher,
                next_seq: num_field(cj, "next_seq")?,
                events: num_field(cj, "events")?,
                trained: num_field(cj, "trained")?,
                skipped: num_field(cj, "skipped")?,
            },
        );
    }
    Ok(clients)
}

/// Resolve where the snapshot lives: `(backend, key)`, or `None` when no
/// snapshot is configured. Without a storage URI the snapshot's own
/// directory becomes a local-dir backend with the file name as the key,
/// so the crash-consistent publish recipe (temp sibling, fsync, atomic
/// rename, parent-dir fsync) is exactly the pre-storage behavior. With a
/// URI the snapshot path is reinterpreted as an object key inside that
/// backend — which is why it must be relative.
fn snapshot_storage(cfg: &ServeConfig) -> Result<Option<(Storage, String)>> {
    let Some(path) = &cfg.snapshot else {
        return Ok(None);
    };
    match &cfg.storage.uri {
        Some(uri) => {
            ensure!(
                path.is_relative(),
                "snapshot path {} must be relative when routed to storage '{uri}' \
                 (it becomes an object key)",
                path.display()
            );
            let key = path
                .to_str()
                .with_context(|| format!("snapshot key {} must be UTF-8", path.display()))?
                .to_string();
            validate_key(&key).map_err(|e| anyhow::anyhow!("snapshot key '{key}': {e}"))?;
            let st = Storage::open_uri(uri, &cfg.storage, &FaultPlan::default())?;
            Ok(Some((st, key)))
        }
        None => {
            let parent = match path.parent() {
                Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
                _ => PathBuf::from("."),
            };
            let key = path
                .file_name()
                .and_then(|n| n.to_str())
                .with_context(|| format!("snapshot path {} has no file name", path.display()))?
                .to_string();
            Ok(Some((Storage::local_dir(&parent, &cfg.storage), key)))
        }
    }
}

// ---------------------------------------------------------------------
// Transport: bounded line reading and fault-injected line writing.
// ---------------------------------------------------------------------

enum ReadOutcome {
    Line(String),
    TimedOut,
    Eof,
}

/// Line assembly over a timeout-carrying socket. `std`'s `read_line`
/// documents buffer contents as unspecified after an error, which a
/// read-timeout deadline hits constantly — so accumulation is explicit
/// here, and bounded: a peer that streams bytes without ever finishing a
/// line (or past the queue bound) is an error, not an allocation.
struct LineReader {
    acc: Vec<u8>,
    max_bytes: usize,
}

impl LineReader {
    fn new(max_bytes: usize) -> LineReader {
        LineReader { acc: Vec::new(), max_bytes }
    }

    fn read_line(&mut self, stream: &mut TcpStream) -> std::io::Result<ReadOutcome> {
        loop {
            if let Some(pos) = self.acc.iter().position(|&b| b == b'\n') {
                let raw: Vec<u8> = self.acc.drain(..=pos).collect();
                let line = String::from_utf8_lossy(&raw).trim().to_string();
                return Ok(ReadOutcome::Line(line));
            }
            if self.acc.len() > self.max_bytes {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("request queue over {} bytes without a newline", self.max_bytes),
                ));
            }
            let mut chunk = [0u8; 4096];
            match stream.read(&mut chunk) {
                Ok(0) => return Ok(ReadOutcome::Eof),
                Ok(n) => self.acc.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(ReadOutcome::TimedOut)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

/// Overwrite the line's head with bytes that cannot parse as JSON — the
/// deterministic stand-in for on-the-wire corruption. The newline
/// survives so framing holds and the peer sees exactly one bad message.
fn garble(line: &mut [u8]) {
    let n = line.len().saturating_sub(1).min(8);
    for b in &mut line[..n] {
        *b = b'#';
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SendOutcome {
    Sent,
    /// A `drop` fault swallowed the message (the peer times out).
    Dropped,
    /// A `close` fault tore the connection down instead of writing.
    Closed,
}

/// Write one protocol line, applying this end's network fault schedule.
/// `idx` is the sender's monotone message counter — explicit `KIND@idx`
/// sites key on it. `kill` aborts the process (client-side crash).
fn send_line(
    stream: &mut TcpStream,
    line: &str,
    plan: &FaultPlan,
    idx: &mut usize,
) -> std::io::Result<SendOutcome> {
    let my_idx = *idx;
    *idx += 1;
    let mut bytes = line.as_bytes().to_vec();
    bytes.push(b'\n');
    if !plan.is_noop() {
        match plan.net_fault(my_idx) {
            Some(FaultKind::Kill) => faults::die("net kill site"),
            Some(FaultKind::Drop) => return Ok(SendOutcome::Dropped),
            Some(FaultKind::Delay) => std::thread::sleep(ms(DELAY_FAULT_MS)),
            Some(FaultKind::Close) => {
                let _ = stream.shutdown(Shutdown::Both);
                return Ok(SendOutcome::Closed);
            }
            Some(FaultKind::Garble) => garble(&mut bytes),
            _ => {}
        }
    }
    stream.write_all(&bytes)?;
    Ok(SendOutcome::Sent)
}

// ---------------------------------------------------------------------
// The server.
// ---------------------------------------------------------------------

struct Shared {
    clients: Mutex<BTreeMap<String, ClientState>>,
    active: AtomicUsize,
    draining: AtomicBool,
    busy_rejections: AtomicU64,
    duplicates: AtomicU64,
    shed: AtomicU64,
    connections: AtomicU64,
    /// Global response counter: network fault sites on the server end key
    /// on it, so a schedule keeps advancing across reconnects instead of
    /// re-firing the same site on every fresh connection.
    resp_idx: AtomicUsize,
}

/// Run the service until a `shutdown` request drains it. `on_ready` fires
/// with the bound address before the first accept — the hook the binary
/// prints the port with and tests/benches grab it from.
pub fn serve_with<F: FnOnce(SocketAddr)>(
    cfg: &ServeConfig,
    faults: &FaultPlan,
    on_ready: F,
) -> Result<ServeSummary> {
    let plan = faults.for_shard(NET_SERVER);
    let pool = provision_pool(cfg)?;

    let mut restored = false;
    let snap = snapshot_storage(cfg)?;
    let initial = match &snap {
        Some((st, key)) => match st.get_bytes(key)? {
            Some(bytes) => {
                let text = String::from_utf8(bytes)
                    .map_err(|_| anyhow::anyhow!("snapshot object '{key}' is not UTF-8"))?;
                restored = true;
                parse_snapshot(&text, cfg, &pool).with_context(|| {
                    format!("restoring snapshot '{key}' from {} storage", st.backend_name())
                })?
            }
            None => BTreeMap::new(),
        },
        None => BTreeMap::new(),
    };

    let listener = TcpListener::bind(&cfg.bind)
        .with_context(|| format!("binding serve listener on {}", cfg.bind))?;
    listener.set_nonblocking(true).context("non-blocking accept loop")?;
    let addr = listener.local_addr()?;
    on_ready(addr);

    let shared = Shared {
        clients: Mutex::new(initial),
        active: AtomicUsize::new(0),
        draining: AtomicBool::new(false),
        busy_rejections: AtomicU64::new(0),
        duplicates: AtomicU64::new(0),
        shed: AtomicU64::new(0),
        connections: AtomicU64::new(0),
        resp_idx: AtomicUsize::new(0),
    };

    let n_workers =
        if cfg.thread_per_conn { 0 } else { crate::util::auto_workers(cfg.workers).max(1) };
    let accept_res: Result<()> = std::thread::scope(|scope| {
        // the shard pool: each worker owns a disjoint set of connections
        // as nonblocking streams and drives them in a readiness loop
        let mut senders: Vec<std::sync::mpsc::Sender<TcpStream>> = Vec::new();
        for _ in 0..n_workers {
            let (tx, rx) = std::sync::mpsc::channel::<TcpStream>();
            senders.push(tx);
            let (sh, cf, pl, fp) = (&shared, cfg, &pool, &plan);
            scope.spawn(move || worker_loop(sh, cf, pl, fp, &rx));
        }
        let mut rr = 0usize;
        loop {
            if shared.draining.load(Ordering::SeqCst) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    if shared.active.load(Ordering::SeqCst) >= cfg.max_clients.max(1) {
                        shared.busy_rejections.fetch_add(1, Ordering::Relaxed);
                        reject_busy(stream, cfg, &shared, &plan);
                        continue;
                    }
                    shared.active.fetch_add(1, Ordering::SeqCst);
                    shared.connections.fetch_add(1, Ordering::Relaxed);
                    if senders.is_empty() {
                        // bench-only legacy model: one thread per connection
                        let (sh, cf, pl, fp) = (&shared, cfg, &pool, &plan);
                        scope.spawn(move || {
                            let _ = handle_conn(sh, cf, pl, fp, stream);
                            sh.active.fetch_sub(1, Ordering::SeqCst);
                        });
                    } else {
                        // round-robin shard assignment; a send can only
                        // fail if the worker died, which aborts the run
                        senders[rr % senders.len()]
                            .send(stream)
                            .expect("shard worker alive");
                        rr += 1;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ms(5));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    // release in-flight workers before reporting: they
                    // poll the drain flag, not the listener
                    shared.draining.store(true, Ordering::SeqCst);
                    return Err(e).context("accepting connection");
                }
            }
        }
        Ok(())
        // dropping the senders (closure exit) tells every worker no more
        // connections are coming; scope exit = the drain barrier: workers
        // and legacy handlers see the draining flag within one readiness
        // tick, flush their goodbyes, and finish
    });
    accept_res?;

    let clients = shared.clients.into_inner().expect("no handler may hold the lock here");
    if let Some((st, key)) = &snap {
        st.put_bytes(key, snapshot_to_string(cfg, &pool, &clients).as_bytes())?;
    }

    let mut summary = ServeSummary {
        clients: clients.len(),
        duplicates: shared.duplicates.load(Ordering::Relaxed),
        shed: shared.shed.load(Ordering::Relaxed),
        busy_rejections: shared.busy_rejections.load(Ordering::Relaxed),
        connections: shared.connections.load(Ordering::Relaxed),
        restored,
        workers: n_workers,
        ..ServeSummary::default()
    };
    for st in clients.values() {
        summary.events += st.events;
        summary.trained += st.trained;
        summary.skipped += st.skipped;
        summary.teacher_queries += st.teacher.queries_served;
    }
    Ok(summary)
}

/// [`serve_with`] without the readiness hook.
pub fn serve(cfg: &ServeConfig, faults: &FaultPlan) -> Result<ServeSummary> {
    serve_with(cfg, faults, |_| {})
}

/// Over-cap connection: structured rejection, best effort, then drop.
fn reject_busy(mut stream: TcpStream, cfg: &ServeConfig, shared: &Shared, plan: &FaultPlan) {
    let _ = stream.set_write_timeout(Some(ms(cfg.read_timeout_ms.max(50))));
    let mut idx = shared.resp_idx.fetch_add(1, Ordering::Relaxed);
    let line = Response::Busy { retry_after_ms: cfg.retry_after_ms }.to_line();
    let _ = send_line(&mut stream, &line, plan, &mut idx);
}

// ---------------------------------------------------------------------
// The shard worker pool: each worker drives its own set of nonblocking
// connections through per-connection protocol state machines, so N
// workers serve any number of admitted clients.
// ---------------------------------------------------------------------

/// Cap on protocol lines processed per connection per readiness pass —
/// a firehosing client makes progress but cannot starve its shardmates.
const LINES_PER_PASS: usize = 32;

/// One admitted connection's state machine inside a shard worker.
struct Conn {
    stream: TcpStream,
    reader: LineReader,
    /// Bytes enqueued for the peer but not yet accepted by the socket.
    out: Vec<u8>,
    /// The client name once `hello` registered it.
    hello: Option<String>,
    /// Last time a complete request arrived — the idle deadline's anchor.
    idle: Instant,
    /// Goodbye state: flush `out`, then close (entered on bye/draining).
    closing: Option<Instant>,
}

fn adopt_conn(cfg: &ServeConfig, stream: TcpStream) -> Option<Conn> {
    stream.set_nonblocking(true).ok()?;
    let _ = stream.set_nodelay(true);
    Some(Conn {
        stream,
        reader: LineReader::new(cfg.queue_depth.max(1) * 1024),
        out: Vec::new(),
        hello: None,
        idle: Instant::now(),
        closing: None,
    })
}

/// Serialize one response into the connection's output queue, applying
/// the server end's fault schedule exactly as the per-connection engine's
/// `send_line` did: one response = one global fault-site index. Returns
/// `false` when the connection must be torn down (a `close` fault).
fn enqueue_response(shared: &Shared, plan: &FaultPlan, conn: &mut Conn, resp: &Response) -> bool {
    let idx = shared.resp_idx.fetch_add(1, Ordering::Relaxed);
    let mut bytes = resp.to_line().into_bytes();
    bytes.push(b'\n');
    if !plan.is_noop() {
        match plan.net_fault(idx) {
            Some(FaultKind::Kill) => faults::die("net kill site"),
            Some(FaultKind::Drop) => return true,
            Some(FaultKind::Delay) => std::thread::sleep(ms(DELAY_FAULT_MS)),
            Some(FaultKind::Close) => {
                let _ = conn.stream.shutdown(Shutdown::Both);
                return false;
            }
            Some(FaultKind::Garble) => garble(&mut bytes),
            _ => {}
        }
    }
    conn.out.extend_from_slice(&bytes);
    true
}

/// Push queued bytes into the socket without blocking. `Ok(true)` when
/// any byte moved; `Err` when the connection is dead.
fn flush_out(conn: &mut Conn) -> std::io::Result<bool> {
    let mut moved = false;
    while !conn.out.is_empty() {
        match conn.stream.write(&conn.out) {
            Ok(0) => return Err(std::io::ErrorKind::WriteZero.into()),
            Ok(n) => {
                conn.out.drain(..n);
                moved = true;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                break;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(moved)
}

fn begin_close(cfg: &ServeConfig, conn: &mut Conn) {
    // grace period to flush the goodbye — the legacy engine's write timeout
    conn.closing = Some(Instant::now() + ms(cfg.read_timeout_ms.max(50)));
}

/// One readiness pass over one connection. Returns `true` when the
/// connection is finished (peer gone, deadline hit, or goodbye flushed);
/// sets `progressed` when any byte moved in either direction.
fn service_conn(
    shared: &Shared,
    cfg: &ServeConfig,
    pool: &Dataset,
    plan: &FaultPlan,
    conn: &mut Conn,
    progressed: &mut bool,
) -> bool {
    if shared.draining.load(Ordering::SeqCst) && conn.closing.is_none() {
        if !enqueue_response(shared, plan, conn, &Response::Draining) {
            return true;
        }
        begin_close(cfg, conn);
    }
    match flush_out(conn) {
        Ok(moved) => *progressed |= moved,
        Err(_) => return true,
    }
    if let Some(deadline) = conn.closing {
        return conn.out.is_empty() || Instant::now() >= deadline;
    }
    for _ in 0..LINES_PER_PASS {
        match conn.reader.read_line(&mut conn.stream) {
            Err(_) | Ok(ReadOutcome::Eof) => return true,
            Ok(ReadOutcome::TimedOut) => {
                // no complete line ready: the idle deadline is the only
                // way a silent client leaves the shard
                if conn.idle.elapsed() >= ms(cfg.idle_timeout_ms.max(1)) {
                    return true;
                }
                break;
            }
            Ok(ReadOutcome::Line(line)) => {
                if line.is_empty() {
                    continue;
                }
                *progressed = true;
                conn.idle = Instant::now();
                let resp = match Request::parse(&line) {
                    Err(e) => Some(Response::Error { reason: format!("{e:#}") }),
                    Ok(req) => handle_request(shared, cfg, pool, req, &mut conn.hello),
                };
                let Some(resp) = resp else {
                    begin_close(cfg, conn); // bye: flush what's queued, close
                    break;
                };
                let last = matches!(resp, Response::Draining);
                if !enqueue_response(shared, plan, conn, &resp) {
                    return true;
                }
                if last {
                    begin_close(cfg, conn);
                    break;
                }
            }
        }
    }
    match flush_out(conn) {
        Ok(moved) => *progressed |= moved,
        Err(_) => return true,
    }
    if let Some(deadline) = conn.closing {
        return conn.out.is_empty() || Instant::now() >= deadline;
    }
    false
}

/// One shard worker: adopt connections round-robined to this shard, run
/// a readiness pass over each, sleep a tick when nothing moved. Exits
/// when the acceptor is done (channel disconnected) and the shard is
/// empty — with the draining flag set, every pass drives connections to
/// their goodbye.
fn worker_loop(
    shared: &Shared,
    cfg: &ServeConfig,
    pool: &Dataset,
    plan: &FaultPlan,
    rx: &std::sync::mpsc::Receiver<TcpStream>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut acceptor_done = false;
    loop {
        loop {
            match rx.try_recv() {
                Ok(stream) => match adopt_conn(cfg, stream) {
                    Some(conn) => conns.push(conn),
                    None => {
                        shared.active.fetch_sub(1, Ordering::SeqCst);
                    }
                },
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    acceptor_done = true;
                    break;
                }
            }
        }
        let mut progressed = false;
        let mut i = 0;
        while i < conns.len() {
            if service_conn(shared, cfg, pool, plan, &mut conns[i], &mut progressed) {
                conns.swap_remove(i);
                shared.active.fetch_sub(1, Ordering::SeqCst);
            } else {
                i += 1;
            }
        }
        if acceptor_done && conns.is_empty() {
            return;
        }
        if !progressed {
            std::thread::sleep(ms(1));
        }
    }
}

fn handle_conn(
    shared: &Shared,
    cfg: &ServeConfig,
    pool: &Dataset,
    plan: &FaultPlan,
    mut stream: TcpStream,
) -> Result<()> {
    stream.set_read_timeout(Some(ms(cfg.read_timeout_ms.max(1))))?;
    stream.set_write_timeout(Some(ms(cfg.read_timeout_ms.max(50))))?;
    let _ = stream.set_nodelay(true);
    let mut reader = LineReader::new(cfg.queue_depth.max(1) * 1024);
    let mut hello: Option<String> = None;
    let mut idle = Instant::now();
    loop {
        if shared.draining.load(Ordering::SeqCst) {
            let mut idx = shared.resp_idx.fetch_add(1, Ordering::Relaxed);
            let _ = send_line(&mut stream, &Response::Draining.to_line(), plan, &mut idx);
            return Ok(());
        }
        match reader.read_line(&mut stream)? {
            ReadOutcome::Eof => return Ok(()),
            ReadOutcome::TimedOut => {
                // the idle deadline: a stalled client cannot pin this thread
                if idle.elapsed() >= ms(cfg.idle_timeout_ms.max(1)) {
                    return Ok(());
                }
            }
            ReadOutcome::Line(line) => {
                if line.is_empty() {
                    continue;
                }
                idle = Instant::now();
                let resp = match Request::parse(&line) {
                    Err(e) => Some(Response::Error { reason: format!("{e:#}") }),
                    Ok(req) => handle_request(shared, cfg, pool, req, &mut hello),
                };
                let Some(resp) = resp else {
                    return Ok(()); // bye
                };
                let last = matches!(resp, Response::Draining);
                let mut idx = shared.resp_idx.fetch_add(1, Ordering::Relaxed);
                send_line(&mut stream, &resp.to_line(), plan, &mut idx)?;
                if last {
                    return Ok(());
                }
            }
        }
    }
}

/// Shape checks an event must pass before it can touch client state.
fn validate_item(pool: &Dataset, item: &EventItem) -> std::result::Result<(), String> {
    if item.label >= pool.n_classes {
        return Err(format!(
            "label {} out of range (n_classes {})",
            item.label, pool.n_classes
        ));
    }
    if item.x_bits.len() != pool.n_features() {
        return Err(format!(
            "feature vector has {} entries, expected {}",
            item.x_bits.len(),
            pool.n_features()
        ));
    }
    Ok(())
}

/// The watermark rules for one validated event — shared verbatim by the
/// single-event and batched paths, so a batched element decides exactly
/// as its unbatched twin would.
fn decide_one(
    shared: &Shared,
    cfg: &ServeConfig,
    pool: &Dataset,
    st: &mut ClientState,
    item: &EventItem,
) -> Response {
    if item.seq < st.next_seq {
        // already applied: acknowledge, never re-train
        shared.duplicates.fetch_add(1, Ordering::Relaxed);
        Response::Decision {
            seq: item.seq,
            action: DecisionAction::Duplicate,
            class: 0,
            p1_bits: 0,
            p2_bits: 0,
            label: None,
        }
    } else if item.seq > st.next_seq {
        // a gap: applying out of order would fork the trajectory —
        // deterministically shed instead
        shared.shed.fetch_add(1, Ordering::Relaxed);
        Response::Shed { seq: item.seq, retry_after_ms: cfg.retry_after_ms }
    } else {
        let x: Vec<f32> = item.x_bits.iter().map(|&b| f32::from_bits(b)).collect();
        apply_event(st, item.seq, &x, item.label, pool.n_classes)
    }
}

/// Dispatch one parsed request; `None` means close the connection.
fn handle_request(
    shared: &Shared,
    cfg: &ServeConfig,
    pool: &Dataset,
    req: Request,
    hello: &mut Option<String>,
) -> Option<Response> {
    match req {
        Request::Hello { client } => {
            let mut map = shared.clients.lock().expect("clients lock");
            let known = map.contains_key(&client);
            if !known {
                match new_client(cfg, pool, &client) {
                    Ok(st) => {
                        map.insert(client.clone(), st);
                    }
                    Err(e) => return Some(Response::Error { reason: format!("{e:#}") }),
                }
            }
            let next_seq = map[&client].next_seq;
            *hello = Some(client.clone());
            Some(Response::Welcome { client, restored: known, next_seq })
        }
        Request::Event { seq, label, x_bits } => {
            let Some(name) = hello.as_ref() else {
                return Some(Response::Error { reason: "event before hello".into() });
            };
            let item = EventItem { seq, label, x_bits };
            if let Err(reason) = validate_item(pool, &item) {
                return Some(Response::Error { reason });
            }
            let mut map = shared.clients.lock().expect("clients lock");
            let st = map.get_mut(name).expect("hello registered this client");
            Some(decide_one(shared, cfg, pool, st, &item))
        }
        Request::Events { items } => {
            let Some(name) = hello.as_ref() else {
                return Some(Response::Error { reason: "events before hello".into() });
            };
            let cap = cfg.max_batch.max(1);
            if items.len() > cap {
                return Some(Response::Error {
                    reason: format!("batch of {} exceeds max_batch {cap}", items.len()),
                });
            }
            // validate the whole frame before applying any element: a
            // malformed frame is refused whole, nothing in it is applied
            for item in &items {
                if let Err(reason) = validate_item(pool, item) {
                    return Some(Response::Error { reason });
                }
            }
            let mut map = shared.clients.lock().expect("clients lock");
            let st = map.get_mut(name).expect("hello registered this client");
            // each element runs the single-event watermark rules in frame
            // order — in-order elements advance the watermark, so a whole
            // in-order frame applies; duplicates ack, gaps shed
            let out = items.iter().map(|item| decide_one(shared, cfg, pool, st, item)).collect();
            Some(Response::Decisions { items: out })
        }
        Request::Ping => Some(Response::Pong),
        Request::Bye => None,
        Request::Shutdown => {
            shared.draining.store(true, Ordering::SeqCst);
            Some(Response::Draining)
        }
    }
}

// ---------------------------------------------------------------------
// The loadgen edge client.
// ---------------------------------------------------------------------

/// Loadgen configuration (CLI flags over the shared scenario config).
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:4710`.
    pub addr: String,
    /// Client name — the identity every per-client stream keys on.
    pub client: String,
    /// Events to deliver. The event stream is a deterministic function of
    /// `(seed, data_seed, synth, client)`; `events` only truncates it, so
    /// a rerun replays the same prefix.
    pub events: usize,
    pub seed: u64,
    pub data_seed: u64,
    pub synth: SynthConfig,
    /// Reconnect attempts per outage before giving up (offline).
    pub retry_budget: u32,
    /// Reconnect back-off base/cap [ms] — doubles per attempt, capped,
    /// plus seeded jitter; mirrors the sweep supervisor's retire curve.
    pub backoff_base_ms: u64,
    pub backoff_cap_ms: u64,
    /// Events per wire frame: 1 sends plain `event` requests; >1 fills
    /// batched `events` frames from the stream. Must not exceed the
    /// server's `max_batch` (the CLI clamps it against the shared config).
    pub batch: usize,
    /// How long to wait for each response before resending.
    pub reply_timeout_ms: u64,
    /// Send `shutdown` (drain the server) after the last ack.
    pub send_shutdown: bool,
    /// Network fault schedule; bound to the client socket end here.
    pub faults: FaultPlan,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            client: "edge-0".into(),
            events: 64,
            seed: 1,
            data_seed: 1 ^ 0xDA7A,
            synth: SynthConfig::default(),
            retry_budget: 5,
            backoff_base_ms: 10,
            backoff_cap_ms: 400,
            batch: 1,
            reply_timeout_ms: 500,
            send_shutdown: false,
            faults: FaultPlan::default(),
        }
    }
}

/// What one loadgen run did (all transport-level; the authoritative
/// model state lives server-side).
#[derive(Clone, Debug, Default)]
pub struct LoadgenSummary {
    pub client: String,
    pub events: usize,
    /// Final applied watermark — `events` on success.
    pub delivered: usize,
    pub acked: u64,
    pub trained: u64,
    pub skipped: u64,
    pub duplicates: u64,
    pub reconnects: u64,
    pub busy_waits: u64,
    pub shed_retries: u64,
    pub resends: u64,
    /// Events per frame this run used (1 = unbatched).
    pub batch: usize,
    /// Batched `events` frames sent (0 when unbatched).
    pub frames: u64,
    /// Outages survived (connect retries that eventually succeeded).
    pub offline_spells: u64,
    /// Events sitting in the local buffer when an outage began —
    /// pruning-only degraded mode; they replay on reconnect.
    pub max_buffered: usize,
}

impl LoadgenSummary {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema", Json::Str("odl-har-loadgen/v1".into())),
            ("client", Json::Str(self.client.clone())),
            ("events", Json::Num(self.events as f64)),
            ("delivered", Json::Num(self.delivered as f64)),
            ("acked", Json::Num(self.acked as f64)),
            ("trained", Json::Num(self.trained as f64)),
            ("skipped", Json::Num(self.skipped as f64)),
            ("duplicates", Json::Num(self.duplicates as f64)),
            ("reconnects", Json::Num(self.reconnects as f64)),
            ("busy_waits", Json::Num(self.busy_waits as f64)),
            ("shed_retries", Json::Num(self.shed_retries as f64)),
            ("resends", Json::Num(self.resends as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("frames", Json::Num(self.frames as f64)),
            ("offline_spells", Json::Num(self.offline_spells as f64)),
            ("max_buffered", Json::Num(self.max_buffered as f64)),
        ])
    }
}

/// The deterministic event stream for one client: class/subject draws and
/// synth samples from RNG streams keyed on the client name. The same
/// `(seed, data_seed, synth, client)` always yields the same stream, and
/// `n` only truncates it — the replay-after-crash contract.
pub fn gen_events(
    synth: &SynthConfig,
    data_seed: u64,
    seed: u64,
    client: &str,
    n: usize,
) -> Vec<(Vec<f32>, usize)> {
    let mut drng = Rng64::new(data_seed);
    let gen = SynthHar::new(synth.clone(), &mut drng);
    let mut rng = Rng64::new(stream_seed(seed, DOMAIN_EVENTS, client_key(client)));
    (0..n)
        .map(|_| {
            let class = rng.below(synth.n_classes);
            let subject = 1 + rng.below(synth.n_subjects);
            let x = gen.sample(class, subject, &mut rng);
            (x, class)
        })
        .collect()
}

/// Bounded exponential back-off with seeded jitter — the supervisor's
/// retire curve (`base << (attempt-1)`, capped) plus up to one base-unit
/// of deterministic jitter so synchronized clients don't stampede.
fn backoff_sleep(attempt: u32, base_ms: u64, cap_ms: u64, jrng: &mut Rng64) {
    let shift = (attempt.saturating_sub(1)).min(20);
    let backoff = base_ms.saturating_mul(1u64 << shift).min(cap_ms);
    let jitter = if base_ms > 0 { jrng.below(base_ms as usize + 1) as u64 } else { 0 };
    std::thread::sleep(ms(backoff + jitter));
}

enum ConnectOutcome {
    Ready(TcpStream, LineReader, u64),
    Busy(u64),
    Failed,
}

fn try_connect_hello(cfg: &LoadgenConfig, plan: &FaultPlan, req_idx: &mut usize) -> ConnectOutcome {
    let Ok(mut stream) = TcpStream::connect(&cfg.addr) else {
        return ConnectOutcome::Failed;
    };
    let _ = stream.set_nodelay(true);
    let poll = cfg.reply_timeout_ms.clamp(1, 100);
    if stream.set_read_timeout(Some(ms(poll))).is_err() {
        return ConnectOutcome::Failed;
    }
    let _ = stream.set_write_timeout(Some(ms(cfg.reply_timeout_ms.max(50))));
    let mut reader = LineReader::new(1 << 20);
    let line = Request::Hello { client: cfg.client.clone() }.to_line();
    match send_line(&mut stream, &line, plan, req_idx) {
        Ok(SendOutcome::Sent) | Ok(SendOutcome::Dropped) => {}
        _ => return ConnectOutcome::Failed,
    }
    match read_response(&mut reader, &mut stream, cfg.reply_timeout_ms) {
        Ok(Some(Response::Welcome { next_seq, .. })) => {
            ConnectOutcome::Ready(stream, reader, next_seq)
        }
        Ok(Some(Response::Busy { retry_after_ms })) => ConnectOutcome::Busy(retry_after_ms),
        _ => ConnectOutcome::Failed,
    }
}

/// Wait up to `timeout_ms` for one well-formed response. `Ok(None)` is a
/// deadline or a garbled line — either way the caller resends.
fn read_response(
    reader: &mut LineReader,
    stream: &mut TcpStream,
    timeout_ms: u64,
) -> std::io::Result<Option<Response>> {
    let deadline = Instant::now() + ms(timeout_ms.max(1));
    loop {
        match reader.read_line(stream)? {
            ReadOutcome::Line(line) => {
                if line.is_empty() {
                    continue;
                }
                return Ok(Response::parse(&line).ok());
            }
            ReadOutcome::TimedOut => {
                if Instant::now() >= deadline {
                    return Ok(None);
                }
            }
            ReadOutcome::Eof => {
                return Err(std::io::ErrorKind::UnexpectedEof.into());
            }
        }
    }
}

/// Run the edge client: connect (with back-off), replay the event stream
/// from the server's watermark, resend on every transport fault, survive
/// disconnects by reconnecting, and optionally drain the server at the
/// end. Errors only when an outage outlives the retry budget (the
/// buffered events stay deliverable by a rerun — same stream, fresh
/// budget) or the server sheds without progress.
pub fn loadgen(cfg: &LoadgenConfig) -> Result<LoadgenSummary> {
    let plan = cfg.faults.for_shard(NET_CLIENT);
    let events = gen_events(&cfg.synth, cfg.data_seed, cfg.seed, &cfg.client, cfg.events);
    let mut jrng = Rng64::new(stream_seed(cfg.seed, DOMAIN_JITTER, client_key(&cfg.client)));
    let batch = cfg.batch.max(1);
    let mut sum = LoadgenSummary {
        client: cfg.client.clone(),
        events: events.len(),
        batch,
        ..LoadgenSummary::default()
    };
    let mut next: usize = 0;
    let mut req_idx: usize = 0;
    let mut connected_before = false;
    let mut conn: Option<(TcpStream, LineReader)> = None;

    'outer: loop {
        // connect + handshake, backing off per attempt up to the budget
        let mut attempt = 0u32;
        let mut busy_spins = 0u64;
        let (mut stream, mut reader) = loop {
            match try_connect_hello(cfg, &plan, &mut req_idx) {
                ConnectOutcome::Ready(stream, reader, next_seq) => {
                    if connected_before {
                        sum.reconnects += 1;
                    }
                    if attempt > 0 {
                        sum.offline_spells += 1;
                    }
                    connected_before = true;
                    // fast-forward past events the server already applied
                    // (our resends from before the disconnect landed)
                    next = next.max((next_seq as usize).min(events.len()));
                    break (stream, reader);
                }
                ConnectOutcome::Busy(retry_after_ms) => {
                    // admission pushback is not an outage (no budget
                    // charge), but a permanently full server must not
                    // spin forever either
                    sum.busy_waits += 1;
                    busy_spins += 1;
                    if busy_spins > (cfg.retry_budget as u64 + 1) * 64 {
                        bail!("server stayed at its admission cap for {busy_spins} retries");
                    }
                    std::thread::sleep(ms(retry_after_ms.max(1)));
                }
                ConnectOutcome::Failed => {
                    attempt += 1;
                    sum.max_buffered = sum.max_buffered.max(events.len() - next);
                    if attempt > cfg.retry_budget {
                        bail!(
                            "teacher service unreachable after {attempt} attempts — degraded to \
                             pruning-only with {} events buffered (rerun replays them)",
                            events.len() - next
                        );
                    }
                    backoff_sleep(attempt, cfg.backoff_base_ms, cfg.backoff_cap_ms, &mut jrng);
                }
            }
        };

        let mut shed_streak = 0u32;
        while next < events.len() {
            if batch > 1 {
                // fill one frame from the watermark; the last frame of the
                // stream may be short
                let k = batch.min(events.len() - next);
                let items = (next..next + k)
                    .map(|i| EventItem {
                        seq: i as u64,
                        label: events[i].1,
                        x_bits: bits_of(&events[i].0),
                    })
                    .collect();
                sum.frames += 1;
                match send_line(&mut stream, &Request::Events { items }.to_line(), &plan, &mut req_idx)
                {
                    Ok(SendOutcome::Sent) | Ok(SendOutcome::Dropped) => {}
                    Ok(SendOutcome::Closed) | Err(_) => continue 'outer,
                }
                // await the frame's decisions: elements at the watermark
                // advance it in order (resent frames ack as duplicates, so
                // a lost response still converges); anything else resends
                // the frame from wherever the watermark now stands
                loop {
                    match read_response(&mut reader, &mut stream, cfg.reply_timeout_ms) {
                        Err(_) => continue 'outer, // disconnected mid-await
                        Ok(None) => {
                            sum.resends += 1; // deadline or garbled reply
                            break;
                        }
                        Ok(Some(Response::Decisions { items })) => {
                            let mut progressed = false;
                            let mut shed_wait: Option<u64> = None;
                            for r in &items {
                                match r {
                                    Response::Decision { seq, action, .. }
                                        if *seq == next as u64 =>
                                    {
                                        match action {
                                            DecisionAction::Trained => sum.trained += 1,
                                            DecisionAction::Skipped => sum.skipped += 1,
                                            DecisionAction::Duplicate => sum.duplicates += 1,
                                        }
                                        sum.acked += 1;
                                        next += 1;
                                        progressed = true;
                                    }
                                    Response::Shed { seq, retry_after_ms }
                                        if *seq == next as u64 =>
                                    {
                                        sum.shed_retries += 1;
                                        shed_wait = Some(*retry_after_ms);
                                    }
                                    _ => {} // stale elements of a resent frame
                                }
                            }
                            if progressed {
                                shed_streak = 0;
                            } else if let Some(wait) = shed_wait {
                                // same non-convergence tripwire as the
                                // single-event path: our watermark event
                                // shed means the server is behind us
                                shed_streak += 1;
                                if shed_streak > 16 {
                                    bail!(
                                        "server keeps shedding seq {next} — its watermark is \
                                         behind this client's (restarted without the snapshot?)"
                                    );
                                }
                                std::thread::sleep(ms(wait.max(1)));
                            } else {
                                sum.resends += 1; // the whole frame was stale
                            }
                            break;
                        }
                        Ok(Some(Response::Error { .. })) => {
                            sum.resends += 1; // e.g. our garbled frame
                            break;
                        }
                        Ok(Some(Response::Draining)) => continue 'outer,
                        Ok(Some(_)) => {} // pong/welcome replays: read through
                    }
                }
                continue;
            }
            let (x, label) = &events[next];
            let req = Request::Event { seq: next as u64, label: *label, x_bits: bits_of(x) };
            match send_line(&mut stream, &req.to_line(), &plan, &mut req_idx) {
                Ok(SendOutcome::Sent) => {}
                Ok(SendOutcome::Dropped) => {} // the await below times out → resend
                Ok(SendOutcome::Closed) | Err(_) => continue 'outer,
            }
            // await the matching ack; stale acks (from resends) are read
            // through, everything else resends the same event
            loop {
                match read_response(&mut reader, &mut stream, cfg.reply_timeout_ms) {
                    Err(_) => continue 'outer, // disconnected mid-await
                    Ok(None) => {
                        sum.resends += 1; // deadline or garbled reply
                        break;
                    }
                    Ok(Some(Response::Decision { seq, action, .. })) => {
                        if seq == next as u64 {
                            match action {
                                DecisionAction::Trained => sum.trained += 1,
                                DecisionAction::Skipped => sum.skipped += 1,
                                DecisionAction::Duplicate => sum.duplicates += 1,
                            }
                            sum.acked += 1;
                            next += 1;
                            shed_streak = 0;
                            break;
                        }
                        // stale ack for an earlier seq: keep reading
                    }
                    Ok(Some(Response::Shed { retry_after_ms, .. })) => {
                        // a shed of our watermark event means the server's
                        // watermark is *behind* ours — it lost state we
                        // already had acknowledged (restarted without its
                        // snapshot). Retrying cannot converge; say so.
                        sum.shed_retries += 1;
                        shed_streak += 1;
                        if shed_streak > 16 {
                            bail!(
                                "server keeps shedding seq {next} — its watermark is behind \
                                 this client's (restarted without the snapshot?)"
                            );
                        }
                        std::thread::sleep(ms(retry_after_ms.max(1)));
                        break;
                    }
                    Ok(Some(Response::Error { .. })) => {
                        sum.resends += 1; // e.g. our garbled request
                        break;
                    }
                    Ok(Some(Response::Draining)) => continue 'outer,
                    Ok(Some(_)) => {} // pong/welcome replays: read through
                }
            }
        }
        conn = Some((stream, reader));
        break;
    }
    sum.delivered = next;

    if cfg.send_shutdown {
        // drain the server: reuse the live connection, or dial a fresh one
        let (mut stream, mut reader) = match conn {
            Some(c) => c,
            None => match try_connect_hello(cfg, &plan, &mut req_idx) {
                ConnectOutcome::Ready(stream, reader, _) => (stream, reader),
                _ => bail!("could not reach the server to request shutdown"),
            },
        };
        for _ in 0..=cfg.retry_budget {
            match send_line(&mut stream, &Request::Shutdown.to_line(), &plan, &mut req_idx) {
                Ok(SendOutcome::Sent) | Ok(SendOutcome::Dropped) => {}
                _ => break,
            }
            match read_response(&mut reader, &mut stream, cfg.reply_timeout_ms) {
                Ok(Some(Response::Draining)) => break,
                Ok(Some(_)) | Ok(None) => continue,
                Err(_) => break, // connection died: the drain flag is set server-side
            }
        }
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    /// Small scenario: 72-row pool over 12 features, 3 classes — enough
    /// for n_hidden = 16 provisioning and fast event streams.
    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            n_hidden: 16,
            warmup: Some(4),
            seed: 11,
            read_timeout_ms: 20,
            idle_timeout_ms: 2_000,
            retry_after_ms: 5,
            synth: SynthConfig {
                n_features: 12,
                n_classes: 3,
                n_subjects: 2,
                samples_per_cell: 12,
                ..SynthConfig::default()
            },
            ..ServeConfig::default()
        }
    }

    fn lg_cfg(addr: SocketAddr, cfg: &ServeConfig, client: &str, events: usize) -> LoadgenConfig {
        LoadgenConfig {
            addr: addr.to_string(),
            client: client.into(),
            events,
            seed: cfg.seed,
            data_seed: cfg.data_seed(),
            synth: cfg.synth.clone(),
            retry_budget: 3,
            backoff_base_ms: 2,
            backoff_cap_ms: 20,
            reply_timeout_ms: 400,
            ..LoadgenConfig::default()
        }
    }

    /// Run the server in a scoped thread, hand its address to the
    /// closure, and return (server summary, closure result).
    fn with_server<T>(
        cfg: &ServeConfig,
        faults: &FaultPlan,
        f: impl FnOnce(SocketAddr) -> T,
    ) -> (ServeSummary, T) {
        std::thread::scope(|scope| {
            let (tx, rx) = mpsc::channel();
            let server = scope.spawn(|| serve_with(cfg, faults, move |a| tx.send(a).unwrap()));
            let addr = rx.recv().expect("server ready");
            let out = f(addr);
            (server.join().expect("server thread").expect("serve ok"), out)
        })
    }

    fn raw_connect(addr: SocketAddr) -> (TcpStream, LineReader) {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(ms(50))).unwrap();
        (stream, LineReader::new(1 << 20))
    }

    fn roundtrip(stream: &mut TcpStream, reader: &mut LineReader, req: &Request) -> Response {
        let mut line = req.to_line();
        line.push('\n');
        stream.write_all(line.as_bytes()).unwrap();
        read_response(reader, stream, 2_000).unwrap().expect("response")
    }

    #[test]
    fn loadgen_against_server_delivers_everything() {
        let cfg = tiny_cfg();
        let (summary, lg) = with_server(&cfg, &FaultPlan::default(), |addr| {
            let mut lc = lg_cfg(addr, &cfg, "edge-a", 30);
            lc.send_shutdown = true;
            loadgen(&lc).expect("loadgen ok")
        });
        assert_eq!(lg.delivered, 30);
        assert_eq!(lg.acked, 30);
        assert_eq!(summary.events, 30);
        assert_eq!(summary.clients, 1);
        assert_eq!(summary.trained + summary.skipped, 30);
        assert_eq!(lg.trained, summary.trained);
        // warmup 4 guarantees at least the first events trained
        assert!(summary.trained >= 4, "trained {}", summary.trained);
        assert!(!summary.restored);
    }

    #[test]
    fn duplicates_ack_and_gaps_shed_without_touching_state() {
        let cfg = tiny_cfg();
        let events = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, "edge-b", 2);
        let (summary, ()) = with_server(&cfg, &FaultPlan::default(), |addr| {
            let (mut s, mut r) = raw_connect(addr);
            let welcome = roundtrip(&mut s, &mut r, &Request::Hello { client: "edge-b".into() });
            assert!(matches!(welcome, Response::Welcome { next_seq: 0, restored: false, .. }));

            // a gap ahead of the watermark is shed, not applied
            let ev =
                |i: usize| Request::Event { seq: i as u64, label: events[i % 2].1, x_bits: bits_of(&events[i % 2].0) };
            assert!(matches!(
                roundtrip(&mut s, &mut r, &ev(1)),
                Response::Shed { seq: 1, .. }
            ));

            // in-order applies; replay of the same seq is a duplicate ack
            let first = roundtrip(&mut s, &mut r, &ev(0));
            assert!(
                matches!(first, Response::Decision { seq: 0, action, .. } if action != DecisionAction::Duplicate)
            );
            let replay = roundtrip(&mut s, &mut r, &ev(0));
            assert!(matches!(
                replay,
                Response::Decision { seq: 0, action: DecisionAction::Duplicate, .. }
            ));

            // events before hello on a fresh connection are refused
            let (mut s2, mut r2) = raw_connect(addr);
            assert!(matches!(
                roundtrip(&mut s2, &mut r2, &ev(0)),
                Response::Error { .. }
            ));

            assert!(matches!(roundtrip(&mut s, &mut r, &Request::Ping), Response::Pong));
            assert!(matches!(
                roundtrip(&mut s, &mut r, &Request::Shutdown),
                Response::Draining
            ));
        });
        assert_eq!(summary.events, 1, "only the in-order event applied");
        assert_eq!(summary.duplicates, 1);
        assert_eq!(summary.shed, 1);
    }

    #[test]
    fn batched_loadgen_matches_unbatched_state_exactly() {
        // decisions depend only on applied event order, so a batched clean
        // run must snapshot byte-identically to an unbatched clean run
        let run = |batch: usize| -> (String, ServeSummary, LoadgenSummary) {
            let cfg = {
                let mut c = tiny_cfg();
                let dir = std::env::temp_dir()
                    .join(format!("odl-serve-batch-{}-{batch}", std::process::id()));
                std::fs::create_dir_all(&dir).unwrap();
                c.snapshot = Some(dir.join("snap.json"));
                let _ = std::fs::remove_file(c.snapshot.as_ref().unwrap());
                c
            };
            let (summary, lg) = with_server(&cfg, &FaultPlan::default(), |addr| {
                let mut lc = lg_cfg(addr, &cfg, "edge-a", 30);
                lc.batch = batch;
                lc.send_shutdown = true;
                loadgen(&lc).expect("loadgen ok")
            });
            let snap = cfg.snapshot.unwrap();
            let text = std::fs::read_to_string(&snap).unwrap();
            let _ = std::fs::remove_file(&snap);
            (text, summary, lg)
        };
        let (plain, _, lg1) = run(1);
        let (batched, summary, lg6) = run(6);
        assert_eq!(batched, plain, "batching must not change final state");
        assert_eq!(lg1.acked, 30);
        assert_eq!(lg1.frames, 0);
        assert_eq!(lg6.acked, 30);
        assert_eq!(lg6.frames, 5, "30 events at batch 6");
        assert!(summary.workers >= 1, "the pool engine served this run");
    }

    #[test]
    fn oversized_batches_are_refused_whole() {
        let mut cfg = tiny_cfg();
        cfg.max_batch = 2;
        let events = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, "edge-c", 3);
        let (summary, ()) = with_server(&cfg, &FaultPlan::default(), |addr| {
            let item = |i: usize| EventItem {
                seq: i as u64,
                label: events[i].1,
                x_bits: bits_of(&events[i].0),
            };
            // a batch before hello is refused like a bare event is
            let (mut s0, mut r0) = raw_connect(addr);
            assert!(matches!(
                roundtrip(&mut s0, &mut r0, &Request::Events { items: vec![item(0)] }),
                Response::Error { .. }
            ));

            let (mut s, mut r) = raw_connect(addr);
            let _ = roundtrip(&mut s, &mut r, &Request::Hello { client: "edge-c".into() });
            let all: Vec<EventItem> = (0..3).map(item).collect();
            let resp = roundtrip(&mut s, &mut r, &Request::Events { items: all.clone() });
            assert!(matches!(resp, Response::Error { .. }), "3 > max_batch 2: {resp:?}");
            // under the cap the same elements apply, one outcome each
            let resp = roundtrip(&mut s, &mut r, &Request::Events { items: all[..2].to_vec() });
            match resp {
                Response::Decisions { items } => {
                    assert_eq!(items.len(), 2);
                    assert!(items.iter().all(|d| matches!(
                        d,
                        Response::Decision { action, .. } if *action != DecisionAction::Duplicate
                    )));
                }
                other => panic!("expected decisions, got {other:?}"),
            }
            let _ = roundtrip(&mut s, &mut r, &Request::Shutdown);
        });
        assert_eq!(summary.events, 2, "the oversized frame applied nothing");
    }

    #[test]
    fn batched_frames_run_watermark_rules_per_element() {
        let cfg = tiny_cfg();
        let events = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, "edge-d", 4);
        let (summary, ()) = with_server(&cfg, &FaultPlan::default(), |addr| {
            let item = |i: usize| EventItem {
                seq: i as u64,
                label: events[i].1,
                x_bits: bits_of(&events[i].0),
            };
            let (mut s, mut r) = raw_connect(addr);
            let _ = roundtrip(&mut s, &mut r, &Request::Hello { client: "edge-d".into() });
            let first =
                roundtrip(&mut s, &mut r, &Request::Events { items: vec![item(0), item(1)] });
            assert!(matches!(first, Response::Decisions { ref items } if items.len() == 2));
            // one frame: a replay (duplicate), the watermark event
            // (applies), and a far-future seq (gap → shed)
            let mut far = item(3);
            far.seq = 5;
            let resp = roundtrip(
                &mut s,
                &mut r,
                &Request::Events { items: vec![item(1), item(2), far] },
            );
            match resp {
                Response::Decisions { items } => {
                    assert!(matches!(
                        items[0],
                        Response::Decision { seq: 1, action: DecisionAction::Duplicate, .. }
                    ));
                    assert!(matches!(
                        items[1],
                        Response::Decision { seq: 2, action, .. }
                            if action != DecisionAction::Duplicate
                    ));
                    assert!(matches!(items[2], Response::Shed { seq: 5, .. }));
                }
                other => panic!("expected decisions, got {other:?}"),
            }
            let _ = roundtrip(&mut s, &mut r, &Request::Shutdown);
        });
        assert_eq!(summary.events, 3, "seqs 0..3 applied exactly once");
        assert_eq!(summary.duplicates, 1);
        assert_eq!(summary.shed, 1);
    }

    #[test]
    fn legacy_thread_per_conn_engine_still_serves() {
        let mut cfg = tiny_cfg();
        cfg.thread_per_conn = true;
        let (summary, lg) = with_server(&cfg, &FaultPlan::default(), |addr| {
            let mut lc = lg_cfg(addr, &cfg, "edge-a", 12);
            lc.send_shutdown = true;
            loadgen(&lc).expect("loadgen ok")
        });
        assert_eq!(lg.delivered, 12);
        assert_eq!(summary.events, 12);
        assert_eq!(summary.workers, 0, "legacy mode runs no shard workers");
    }

    #[test]
    fn admission_cap_answers_busy_with_retry_hint() {
        let mut cfg = tiny_cfg();
        cfg.max_clients = 1;
        let (summary, ()) = with_server(&cfg, &FaultPlan::default(), |addr| {
            let (mut s, mut r) = raw_connect(addr);
            let _ = roundtrip(&mut s, &mut r, &Request::Hello { client: "holder".into() });
            // the cap is reached: the next connection gets a structured busy
            let (mut s2, mut r2) = raw_connect(addr);
            let resp = read_response(&mut r2, &mut s2, 2_000).unwrap().expect("busy line");
            assert!(
                matches!(resp, Response::Busy { retry_after_ms } if retry_after_ms == cfg.retry_after_ms)
            );
            let _ = roundtrip(&mut s, &mut r, &Request::Shutdown);
        });
        assert_eq!(summary.busy_rejections, 1);
    }

    #[test]
    fn stalled_client_hits_idle_deadline_and_is_disconnected() {
        let mut cfg = tiny_cfg();
        cfg.idle_timeout_ms = 80;
        cfg.max_clients = 1;
        let (_summary, ()) = with_server(&cfg, &FaultPlan::default(), |addr| {
            // connect, say hello, then stall — never send another byte
            let (mut s, mut r) = raw_connect(addr);
            let _ = roundtrip(&mut s, &mut r, &Request::Hello { client: "staller".into() });
            // the server must disconnect us (EOF), freeing the only slot...
            let deadline = Instant::now() + ms(5_000);
            loop {
                match r.read_line(&mut s).unwrap() {
                    ReadOutcome::Eof => break,
                    _ => assert!(Instant::now() < deadline, "idle deadline never fired"),
                }
            }
            // ...so a new client is admitted and served
            let (mut s2, mut r2) = raw_connect(addr);
            let resp = roundtrip(&mut s2, &mut r2, &Request::Hello { client: "next".into() });
            assert!(matches!(resp, Response::Welcome { .. }));
            let _ = roundtrip(&mut s2, &mut r2, &Request::Shutdown);
        });
    }

    #[test]
    fn snapshot_roundtrips_byte_identically() {
        let cfg = tiny_cfg();
        let pool = provision_pool(&cfg).unwrap();
        let mut clients = BTreeMap::new();
        for name in ["edge-a", "edge-b"] {
            let mut st = new_client(&cfg, &pool, name).unwrap();
            let events = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, name, 25);
            for (i, (x, label)) in events.iter().enumerate() {
                apply_event(&mut st, i as u64, x, *label, cfg.synth.n_classes);
            }
            clients.insert(name.to_string(), st);
        }
        let text = snapshot_to_string(&cfg, &pool, &clients);
        let restored = parse_snapshot(&text, &cfg, &pool).unwrap();
        assert_eq!(snapshot_to_string(&cfg, &pool, &restored), text);

        // the restored state continues the trajectory bit-exactly
        let mut live = clients.remove("edge-a").unwrap();
        let mut back = restored.into_iter().find(|(n, _)| n == "edge-a").unwrap().1;
        let more = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, "edge-a", 40);
        for (i, (x, label)) in more.iter().enumerate().skip(25) {
            let a = apply_event(&mut live, i as u64, x, *label, cfg.synth.n_classes);
            let b = apply_event(&mut back, i as u64, x, *label, cfg.synth.n_classes);
            assert_eq!(a, b, "restored client diverged at event {i}");
        }
    }

    #[test]
    fn snapshot_rejects_mismatched_scenario() {
        let cfg = tiny_cfg();
        let pool = provision_pool(&cfg).unwrap();
        let mut clients = BTreeMap::new();
        clients.insert("edge-a".to_string(), new_client(&cfg, &pool, "edge-a").unwrap());
        let text = snapshot_to_string(&cfg, &pool, &clients);

        let mut other = cfg.clone();
        other.seed = cfg.seed + 1;
        let err = parse_snapshot(&text, &other, &pool).unwrap_err().to_string();
        assert!(err.contains("seed"), "{err}");

        let mut wider = cfg.clone();
        wider.n_hidden = 24;
        assert!(parse_snapshot(&text, &wider, &pool).is_err());

        let mut fixed = cfg.clone();
        fixed.fixed_theta = Some(0.16);
        let err = parse_snapshot(&text, &fixed, &pool).unwrap_err().to_string();
        assert!(err.contains("auto"), "{err}");
    }

    #[test]
    fn snapshot_routed_through_storage_matches_local_and_restores() {
        // identical trajectory, two snapshot routes — the drained
        // snapshot must be byte-identical whether it goes to a plain
        // local path or through a [storage] backend, and a restart must
        // restore from the backend (resuming the drained state exactly)
        let base = std::env::temp_dir().join(format!("odl-serve-storage-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();

        let run = |cfg: &ServeConfig, n: usize| -> ServeSummary {
            let (summary, _lg) = with_server(cfg, &FaultPlan::default(), |addr| {
                let mut lc = lg_cfg(addr, cfg, "edge-a", n);
                lc.send_shutdown = true;
                loadgen(&lc).expect("loadgen ok")
            });
            summary
        };

        let mut plain = tiny_cfg();
        plain.snapshot = Some(base.join("plain").join("snap.json"));
        std::fs::create_dir_all(base.join("plain")).unwrap();
        run(&plain, 24);
        let want = std::fs::read(plain.snapshot.as_ref().unwrap()).unwrap();

        let mut routed = tiny_cfg();
        routed.snapshot = Some(PathBuf::from("snap.json"));
        routed.storage.uri = Some(base.join("store").to_str().unwrap().to_string());
        run(&routed, 24);
        let obj = base.join("store").join("snap.json");
        assert_eq!(std::fs::read(&obj).unwrap(), want, "storage-routed snapshot differs");

        // restart: the server restores from the backend; the replayed
        // seeded stream brings nothing new, so the re-drained snapshot
        // is byte-identical to the first one
        let summary = run(&routed, 24);
        assert!(summary.restored, "restart did not restore from storage");
        assert_eq!(std::fs::read(&obj).unwrap(), want);

        // an absolute snapshot path cannot become an object key
        let mut bad = tiny_cfg();
        bad.snapshot = Some(base.join("abs.json"));
        bad.storage.uri = routed.storage.uri.clone();
        let err = snapshot_storage(&bad).unwrap_err().to_string();
        assert!(err.contains("must be relative"), "{err}");
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn event_stream_is_deterministic_and_prefix_stable() {
        let cfg = tiny_cfg();
        let a = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, "edge-a", 30);
        let b = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, "edge-a", 30);
        assert_eq!(a, b);
        // truncation yields the same prefix — the crash-rerun contract
        let short = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, "edge-a", 12);
        assert_eq!(&a[..12], &short[..]);
        // a different client name is a different stream
        let c = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, "edge-b", 30);
        assert_ne!(a, c);
    }

    #[test]
    fn chaos_on_the_wire_converges_to_the_undisturbed_state() {
        // the tentpole property in miniature: drops, delays, garbles and
        // closes on both socket ends change transport effort only — the
        // final snapshot text is byte-identical to the undisturbed run's
        let run = |faults: &str| -> (String, LoadgenSummary) {
            let mut cfg = tiny_cfg();
            let dir = std::env::temp_dir().join(format!(
                "odl-serve-unit-{}-{}",
                std::process::id(),
                faults.len()
            ));
            std::fs::create_dir_all(&dir).unwrap();
            let snap = dir.join("snap.json");
            let _ = std::fs::remove_file(&snap);
            cfg.snapshot = Some(snap.clone());
            let plan = if faults.is_empty() {
                FaultPlan::default()
            } else {
                FaultPlan::parse(faults).unwrap()
            };
            let (_summary, lg) = with_server(&cfg, &plan, |addr| {
                let mut lc = lg_cfg(addr, &cfg, "edge-a", 24);
                lc.send_shutdown = true;
                lc.faults = plan.clone();
                lc.reply_timeout_ms = 150;
                loadgen(&lc).expect("loadgen survives the schedule")
            });
            let text = std::fs::read_to_string(&snap).unwrap();
            let _ = std::fs::remove_file(&snap);
            (text, lg)
        };
        let (clean, _) = run("");
        // explicit sites on both ends: server drops+garbles, client closes
        let (chaotic, lg) =
            run("5:drop@2#1,garble@5#1,delay@7#1,close@9#2,garble@12#2,drop@15#2");
        assert_eq!(chaotic, clean, "fault schedule must not change final state");
        assert!(
            lg.resends + lg.reconnects + lg.duplicates > 0,
            "schedule was supposed to disturb transport: {lg:?}"
        );
    }
}
