//! Deterministic fleet simulator — Figure 2(a) at system scale: one
//! teacher, many edges, a lossy BLE channel, virtual time, full energy
//! accounting via the [`crate::hw`] models.
//!
//! Each edge senses one sample per `event_period_s` (phases staggered so
//! the teacher sees interleaved load). A scripted drift moment switches
//! every edge's sampling distribution from its in-distribution subject to
//! a held-out subject (the paper's deployment story). Detection is either
//! scripted (oracle) or organic (centroid detector). Queries ride the
//! channel with latency/loss/retry; teacher replies complete the edge's
//! pending training step.
//!
//! # The sharded engine
//!
//! The simulator is decomposed into per-edge [`EdgeSim`] shards. Each
//! shard owns *everything* its edge touches — the FSM + ODL core, the
//! metrics ledger, and four private [`CounterRng`] streams (sense draws,
//! eval probes, channel loss, teacher noise) keyed by `(seed, domain,
//! edge)` via [`crate::util::rng::stream_seed`]. Shared resources are
//! resolved without cross-shard communication:
//!
//! * the **drift moment** is a pure function of virtual time, applied in
//!   exactly the order the old global event gave it (before the first
//!   event at or after `drift_at_s`);
//! * **channel** and **teacher** state per shard is a counter stream plus
//!   integer counters, merged by summation when the books close;
//! * the report merge walks shards in edge order on one thread, so every
//!   `f64` fold has a single association order.
//!
//! Because no f32/f64 operation ever depends on cross-edge interleaving,
//! [`Fleet::run_parallel`] (contiguous shard chunks over
//! [`crate::util::parallel::map_shard_chunks`]) produces a
//! [`FleetReport`] **bitwise identical** to the sequential
//! [`Fleet::run`] for the same seed — asserted by
//! `tests/fleet_determinism.rs` and re-checked by `bench_fleet_scale`
//! before it times anything. `run_threaded()` remains the live-system
//! flavour over std mpsc channels (event counts instead of virtual time).
//!
//! # The time wheel
//!
//! Events are dispatched by one [`WheelEngine`] per shard, not by
//! per-edge `BinaryHeap`s: the wheel is a calendar queue of
//! `Vec<Vec<u32>>` buckets (one bucket per `event_period_s` of virtual
//! time) holding *edge indices*, and each edge keeps its tiny pending
//! event list sorted so the earliest `(at, seq)` entry pops from the
//! back. The hot loop is a cache-friendly bucket walk — take a bucket,
//! drain each resident edge's due events in `(at, seq)` order, move the
//! edge to the bucket of its next event — instead of `n_edges`
//! independent heap pops. Per-edge pop order is exactly the retired
//! heap's min-`(at, seq)` order (pinned by the `wheel_*` tests below),
//! and cross-edge interleaving was never observable, so the wheel is
//! bitwise invisible to every recorded trajectory.
//!
//! # Aggregate metrics
//!
//! [`Scenario::metrics`] picks the reporting mode. `full` (default)
//! keeps the historical per-edge rows. `aggregate` keeps
//! [`FleetReport::per_edge`] empty and carries one O(1)
//! [`FleetAggregate`]: exact fleet-wide counters, P² quantile sketches
//! over the per-edge accuracy/power/query distributions (fed on the
//! single-threaded close-of-books walk in edge-id order), and
//! HyperLogLog sketches of distinct visited (subject, class) cells and
//! (edge, mode) states (fed per shard during the run; register-max
//! merge is partition-invariant, so worker counts cannot move a bit).
//!
//! # Sharded provisioning
//!
//! Construction is staged the same way the event loop is:
//!
//! 1. **Shared artifacts** ([`ProvisionArtifacts`]): the synthetic pool,
//!    the in-distribution split, the standardization stats (and optional
//!    PCA summary) are a pure function of `(synth config, data seed)` —
//!    built once, hashed by [`ProvisionArtifacts::data_key`], and shared
//!    read-only by every fleet whose data config matches (the
//!    [`super::sweep`] engine memoizes them across a scenario grid).
//! 2. **Per-edge provisioning**: each edge's model build + `init_batch`
//!    reads only the shared artifacts and its own id, so
//!    [`Fleet::new_parallel`] fans edge construction over the shared
//!    executor's keyed streams
//!    ([`crate::util::parallel::parallel_map_keyed`], per-edge
//!    `stream_seed(seed, PROVISION, edge)`) — bitwise identical to the
//!    sequential [`Fleet::new`] for every worker count, by the same
//!    no-shared-mutable-state argument as the event loop.
//! 3. **Edge-state sharing**: the provisioned core itself
//!    ([`provisioned_edge_model`]) is independent of `n_edges` and of
//!    every pure-simulation knob (θ, detector, channel, teacher), so the
//!    [`super::sweep`] engine memoizes it per `(data key, seed,
//!    n_hidden)` and [`Fleet::with_edge_models`] clones the shared cores
//!    instead of re-running `init_batch` per cell — bitwise invisible by
//!    the purity of the build.

use super::channel::{Channel, ChannelConfig};
use super::edge::{EdgeDevice, Mode, StepAction};
use super::metrics::{EdgeMetrics, FleetAggregate, FleetReport, MetricsMode};
use super::teacher::Teacher;
use crate::data::pca::Pca;
use crate::data::synth::{SynthConfig, SynthHar};
use crate::data::{Dataset, Standardizer, HELD_OUT_SUBJECTS};
use crate::drift::{CentroidDetector, DriftDetector, OracleDetector};
use crate::hw::{CycleModel, PowerModel, PowerState};
use crate::linalg::Mat;
use crate::odl::{AlphaKind, OsElm, OsElmConfig};
use crate::pruning::{Metric, Pruner, ThetaPolicy};
use crate::util::parallel;
use crate::util::rng::{hash_fold, stream_seed, CounterRng, Rng64, RngStream};
use crate::util::sketch::Hll;
use anyhow::{ensure, Result};
use std::cmp::Ordering;
use std::sync::Arc;

/// Domain tags separating each shard's RNG streams (see
/// [`crate::util::rng::stream_seed`]). Frozen: changing any of these
/// changes every recorded fleet trajectory.
mod domain {
    /// Sense-path sample draws.
    pub const SENSE: u64 = 0x5E;
    /// Evaluation-window probe draws.
    pub const EVAL: u64 = 0xE7A1;
    /// Channel loss/retry coin flips.
    pub const CHANNEL: u64 = 0xC4A7;
    /// Teacher label-noise draws.
    pub const TEACHER: u64 = 0x7EAC;
    /// Per-edge provisioning streams (model construction). Construction
    /// draws nothing from these under `AlphaKind::Hash` (the fleet's α
    /// scheme), but giving every edge its own stream keeps the
    /// provisioning shards independent if a future α kind samples here.
    pub const PROVISION: u64 = 0xB007;
}

/// Drift-detector selection for the scenario.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DetectorKind {
    /// Scripted: the fleet flips edges into training mode at the drift moment.
    Oracle,
    /// Organic: the centroid detector must notice the shift by itself.
    Centroid,
}

impl DetectorKind {
    /// The canonical config/results-file name (the single source for the
    /// TOML parsers and sweep rows).
    pub fn name(self) -> &'static str {
        match self {
            DetectorKind::Oracle => "oracle",
            DetectorKind::Centroid => "centroid",
        }
    }

    /// Inverse of [`Self::name`]; `None` for unknown names.
    pub fn parse(name: &str) -> Option<DetectorKind> {
        match name {
            "oracle" => Some(DetectorKind::Oracle),
            "centroid" => Some(DetectorKind::Centroid),
            _ => None,
        }
    }
}

/// Fleet scenario description.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub n_edges: usize,
    pub n_hidden: usize,
    pub event_period_s: f64,
    pub horizon_s: f64,
    /// Virtual time at which the data distribution shifts.
    pub drift_at_s: f64,
    pub detector: DetectorKind,
    /// θ policy: None = auto ladder, Some(t) = fixed.
    pub fixed_theta: Option<f32>,
    pub teacher_error: f64,
    pub channel: ChannelConfig,
    pub synth: SynthConfig,
    /// Training-phase length (IsTrainDone target).
    pub train_target: usize,
    /// Periodic evaluation window: every `eval_period_s` of virtual time,
    /// each edge's model is evaluated on a fresh probe batch drawn from
    /// its *current* distribution via the batched predict path
    /// (`OsElm::accuracy`). 0 disables (the default — evaluation windows
    /// are telemetry, not part of the paper's protocol).
    pub eval_period_s: f64,
    /// Probe-batch size per edge per evaluation window.
    pub eval_samples: usize,
    /// When true, evaluation probes cost energy like real on-device
    /// inference: each window books `eval_samples` predict-state slots
    /// through the power ledger (a deployed fleet runs its probes on the
    /// edge core). Off by default so the windows stay pure telemetry and
    /// seeded trajectories keep their historical energy books.
    pub eval_costs_power: bool,
    /// Seed of the data-generation stream (pool, standardizer, PCA).
    /// `None` (the default, and every historical trajectory) derives it
    /// from the fleet seed as `seed ^ 0xDA7A`; a sweep pins it explicitly
    /// so cells that differ only in simulation seed share one
    /// [`ProvisionArtifacts`] build.
    pub data_seed: Option<u64>,
    /// Reporting mode: `Full` (default) keeps one [`EdgeMetrics`] row per
    /// edge; `Aggregate` keeps `per_edge` empty and carries one O(1)
    /// [`FleetAggregate`] of counters + sketches — the mode ≥100k-edge
    /// fleets run in. A wall-memory knob only for the rollup getters
    /// (`total_queries` etc. agree between modes bit for bit); the
    /// simulated trajectories are identical in both modes.
    pub metrics: MetricsMode,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            n_edges: 4,
            n_hidden: 128,
            event_period_s: 1.0,
            horizon_s: 600.0,
            drift_at_s: 120.0,
            detector: DetectorKind::Oracle,
            fixed_theta: None,
            teacher_error: 0.0,
            channel: ChannelConfig::default(),
            synth: SynthConfig::default(),
            train_target: 400,
            eval_period_s: 0.0,
            eval_samples: 64,
            eval_costs_power: false,
            data_seed: None,
            metrics: MetricsMode::Full,
        }
    }
}

/// Salt for the PCA power-iteration stream inside an artifact build.
const PCA_SEED_SALT: u64 = 0x9CA1;

/// The provisioning artifacts every edge of a fleet shares read-only: the
/// calibrated generator, the standardized in-distribution pool (pre-
/// shuffle — each fleet derives its own seed-keyed row order from it),
/// the standardization stats, the in-distribution subject list, and an
/// optional 2-component PCA summary of the pool. All of it is a pure
/// function of `(synth config, data seed)`, hashed into [`Self::key`] —
/// the memoization key the scenario-sweep engine uses to fit the data
/// once per data config instead of once per grid cell.
pub struct ProvisionArtifacts {
    /// The resolved data-stream seed this build used.
    pub data_seed: u64,
    /// `data_key` of the `(synth, data_seed)` pair that produced this.
    pub key: u64,
    pub generator: SynthHar,
    pub standardizer: Standardizer,
    /// Standardized in-distribution pool, in generation order (unshuffled).
    pub train: Dataset,
    /// 1-based in-distribution subject ids (pre-drift assignments).
    pub in_subjects: Vec<usize>,
    /// 2-component PCA of the standardized pool (telemetry fingerprint;
    /// costs one covariance build, so it is opt-in).
    pub pca: Option<Pca>,
}

impl ProvisionArtifacts {
    /// The data seed a scenario resolves to under fleet seed `seed`.
    pub fn effective_data_seed(sc: &Scenario, seed: u64) -> u64 {
        sc.data_seed.unwrap_or(seed ^ 0xDA7A)
    }

    /// Memoization key: a mix64 fold over every field of the synth config
    /// plus the resolved data seed. Two scenarios with equal keys generate
    /// bitwise-identical pools, standardizers, and PCA summaries.
    pub fn data_key(sc: &Scenario, seed: u64) -> u64 {
        // exhaustive destructuring (no `..` rest pattern): adding a
        // SynthConfig field without extending this hash is a compile
        // error, not a silent memoization collision
        let SynthConfig {
            n_features,
            n_classes,
            n_subjects,
            samples_per_cell,
            variation_rank,
            subject_sigma,
            drift_scale,
            noise_sigma,
            proto_sigma,
            variation_sigma,
            confuse_frac,
            confuse_blend,
        } = &sc.synth;
        let fold = hash_fold;
        let mut k = 0x0DA7A_u64;
        for v in [
            *n_features as u64,
            *n_classes as u64,
            *n_subjects as u64,
            *samples_per_cell as u64,
            *variation_rank as u64,
            subject_sigma.to_bits(),
            drift_scale.to_bits(),
            noise_sigma.to_bits(),
            proto_sigma.to_bits(),
            variation_sigma.to_bits(),
            confuse_frac.to_bits(),
            confuse_blend.0.to_bits(),
            confuse_blend.1.to_bits(),
            Self::effective_data_seed(sc, seed),
        ] {
            k = fold(k, v);
        }
        k
    }

    /// Fit the shared artifacts for `(scenario.synth, data seed)`. The
    /// generation sequence is verbatim the historical `Fleet::new`
    /// preamble (same `Rng64` stream, same filter → fit → apply order), so
    /// a fleet built from these artifacts is bitwise identical to one
    /// built the old monolithic way.
    pub fn build(sc: &Scenario, seed: u64, with_pca: bool) -> ProvisionArtifacts {
        let data_seed = Self::effective_data_seed(sc, seed);
        let mut data_rng = Rng64::new(data_seed);
        let generator = SynthHar::new(sc.synth.clone(), &mut data_rng);

        // Provisioning pool: in-distribution subjects only.
        let pool = generator.generate(&mut data_rng);
        let in_dist = pool.filter(|_, s| !HELD_OUT_SUBJECTS.contains(&s));
        let standardizer = Standardizer::fit(&in_dist.xs);
        let mut train = in_dist;
        standardizer.apply(&mut train.xs);

        let in_subjects: Vec<usize> = (1..=sc.synth.n_subjects)
            .filter(|s| !HELD_OUT_SUBJECTS.contains(s))
            .collect();

        let pca = with_pca
            .then(|| Pca::fit(&train.xs, 2, &mut Rng64::new(data_seed ^ PCA_SEED_SALT)));

        ProvisionArtifacts {
            data_seed,
            key: Self::data_key(sc, seed),
            generator,
            standardizer,
            train,
            in_subjects,
            pca,
        }
    }

    /// The per-fleet provisioning row order under fleet seed `seed` —
    /// verbatim the historic in-place shuffle (same `Rng64::new(seed)`
    /// stream and draw sequence). A pure function of `(artifacts, seed)`,
    /// which is what lets the sweep engine memoize the shuffled pool per
    /// `(data key, seed)` pair and lend it to every cell that shares
    /// both.
    pub fn shuffled_train(&self, seed: u64) -> Dataset {
        let mut rng = Rng64::new(seed);
        self.train.shuffled(&mut rng)
    }
}

/// Fleet configuration = scenario + seed.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub scenario: Scenario,
    pub seed: u64,
}

/// One shard-local event. Shards never address each other, so events no
/// longer carry an edge id.
#[derive(Debug)]
enum Event {
    /// The edge senses a sample.
    Sense,
    /// Teacher reply lands at the edge.
    Reply { label: usize },
    /// Channel gave up on the query.
    QueryFailed,
    /// Periodic evaluation window (batched probe accuracy).
    Eval,
}

struct Scheduled {
    at: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by (time, seq) through reversal
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Read-only state shared by every shard (passed as `&SimContext`, all
/// fields `Sync`).
struct SimContext<'a> {
    scenario: &'a Scenario,
    generator: &'a SynthHar,
    standardizer: &'a Standardizer,
    power: PowerModel,
    cycles: CycleModel,
    /// Worker budget for the row-sharded predict inside evaluation
    /// windows. 1 when the fleet itself is sharded (the cores are already
    /// busy); the unsharded path may spend the caller's worker budget
    /// here instead — `OsElm::accuracy_par` is bitwise identical for any
    /// worker count, so this never shows in the report.
    eval_workers: usize,
}

/// Everything one edge needs to advance through virtual time on its own:
/// FSM + model, metrics, and counter-based RNG streams for every source
/// of randomness it consumes. Scheduling state (pending events, event
/// sequence numbers, the drift flag) lives in the shard's [`WheelEngine`]
/// as struct-of-arrays and is lent to the handlers one event at a time as
/// a [`Lane`]. No state is shared across `EdgeSim`s — the invariant
/// behind `run_parallel`'s bitwise determinism.
struct EdgeSim {
    edge: EdgeDevice,
    metrics: EdgeMetrics,
    /// (pre-drift subject, post-drift subject).
    subjects: (usize, usize),
    rng: CounterRng,
    eval_rng: CounterRng,
    channel: Channel,
    teacher: Teacher,
}

/// Draw one standardized sample for an edge from its current subject
/// distribution using the given stream.
fn draw_sample<R: RngStream>(
    generator: &SynthHar,
    standardizer: &Standardizer,
    subjects: (usize, usize),
    drifted: bool,
    n_classes: usize,
    rng: &mut R,
) -> (Vec<f32>, usize) {
    let subject = if drifted { subjects.1 } else { subjects.0 };
    let class = rng.below(n_classes);
    let mut x = generator.sample(class, subject, rng);
    // standardize like the provisioning data
    for ((v, &m), &s) in x
        .iter_mut()
        .zip(&standardizer.mean)
        .zip(&standardizer.std)
    {
        *v = (*v - m) / s;
    }
    (x, class)
}

/// The wheel-owned scheduling state of one edge, lent to the edge's
/// event handlers for the duration of one event: the virtual clock and
/// drift flag (copies — only the engine advances them) plus mutable
/// access to the edge's sequence counter and sorted pending-event list.
struct Lane<'a> {
    now: f64,
    drifted: bool,
    seq: &'a mut u64,
    pending: &'a mut Vec<Scheduled>,
}

impl Lane<'_> {
    /// Schedule an event for this lane's edge. `pending` is kept sorted
    /// ascending under [`Scheduled`]'s reversed order — i.e. descending
    /// `(at, seq)` — so the earliest event is always at the back and
    /// `pending.pop()` yields exactly the `(at, seq)` order the retired
    /// per-edge `BinaryHeap` popped (pinned by
    /// `wheel_pops_in_heap_order`). The list holds ≤ 3 events in practice
    /// (next Sense, next Eval, at most one in-flight Reply/QueryFailed),
    /// so the sorted insert is a byte-move of a few entries.
    fn schedule(&mut self, at: f64, event: Event) {
        *self.seq += 1;
        let item = Scheduled {
            at,
            seq: *self.seq,
            event,
        };
        let idx = self.pending.partition_point(|e| e < &item);
        self.pending.insert(idx, item);
    }
}

/// Per-shard sketch state fed while an aggregate-mode wheel runs. HLL
/// merging is register-wise max — partition- and order-invariant — so
/// per-chunk sketches merged in chunk order equal one sketch fed by the
/// sequential walk, for every worker count.
#[derive(Default)]
struct ShardSketches {
    visited_cells: Hll,
    edge_states: Hll,
}

/// One fleet-wide calendar queue per shard: `buckets[b]` holds the local
/// indices of every edge whose next event falls in virtual-time slice
/// `[b·width, (b+1)·width)`, and the per-edge scheduling state lives in
/// parallel struct-of-arrays (`seq`/`drifted`/`pending`), indexed the
/// same way as the `EdgeSim` slice the engine runs over. The hot loop
/// walks buckets in order; within a bucket each resident edge drains its
/// due events in `(at, seq)` order, then hops to the bucket of its next
/// event. Events past the wheel's end clamp into the last bucket — they
/// are at or beyond the horizon and only ever halt their edge.
struct WheelEngine {
    width: f64,
    buckets: Vec<Vec<u32>>,
    seq: Vec<u64>,
    drifted: Vec<bool>,
    pending: Vec<Vec<Scheduled>>,
}

impl WheelEngine {
    /// Bucket granularity: the sense period (every edge has a Sense due
    /// each period, so finer buckets buy nothing), with a guard for
    /// degenerate periods.
    fn bucket_width(sc: &Scenario) -> f64 {
        if sc.event_period_s > 0.0 && sc.event_period_s.is_finite() {
            sc.event_period_s
        } else {
            1.0
        }
    }

    /// Build the wheel over a shard and boot every edge: Sense at its
    /// stagger phase, then Eval at the eval period — the same `(at, seq)`
    /// boot order `build_edge_sim` used to push into the heap.
    fn new(ctx: &SimContext, sims: &[EdgeSim]) -> WheelEngine {
        let sc = ctx.scenario;
        let width = WheelEngine::bucket_width(sc);
        // enough buckets to cover the horizon plus the halting slice;
        // capped so a pathological horizon/period ratio degrades to
        // coarser final buckets instead of an allocation blow-up
        let n_buckets = ((sc.horizon_s / width) as usize)
            .saturating_add(2)
            .clamp(1, 1 << 16);
        let n = sims.len();
        let mut engine = WheelEngine {
            width,
            buckets: vec![Vec::new(); n_buckets],
            seq: vec![0; n],
            drifted: vec![false; n],
            pending: (0..n).map(|_| Vec::with_capacity(4)).collect(),
        };
        for (i, sim) in sims.iter().enumerate() {
            let id = sim.edge.id;
            let mut lane = Lane {
                now: 0.0,
                drifted: false,
                seq: &mut engine.seq[i],
                pending: &mut engine.pending[i],
            };
            // stagger edges across the period; seed the eval cadence
            let phase = sc.event_period_s * (id as f64 / sc.n_edges.max(1) as f64);
            lane.schedule(phase, Event::Sense);
            if sc.eval_period_s > 0.0 {
                lane.schedule(sc.eval_period_s, Event::Eval);
            }
            let first_at = engine.pending[i].last().expect("boot event").at;
            let b = engine.bucket(first_at);
            engine.buckets[b].push(i as u32);
        }
        engine
    }

    fn bucket(&self, at: f64) -> usize {
        ((at / self.width) as usize).min(self.buckets.len() - 1)
    }

    /// Walk the wheel to the horizon. Per edge this reproduces the
    /// retired heap loop exactly: events pop in `(at, seq)` order; a
    /// popped event past the horizon halts the edge (event consumed,
    /// edge leaves the wheel — every later event is provably later
    /// still); the scripted drift is applied before the first in-horizon
    /// event at or after `drift_at_s`. Nothing an edge does between
    /// events can observe another edge, so the bucket interleaving
    /// across edges is free.
    fn run(
        &mut self,
        sims: &mut [EdgeSim],
        ctx: &SimContext,
        sketches: &mut Option<ShardSketches>,
    ) {
        let horizon = ctx.scenario.horizon_s;
        let drift_at = ctx.scenario.drift_at_s;
        for b in 0..self.buckets.len() {
            let batch = std::mem::take(&mut self.buckets[b]);
            for &slot in &batch {
                let i = slot as usize;
                loop {
                    let next_at = match self.pending[i].last() {
                        Some(next) => next.at,
                        None => break,
                    };
                    let nb = self.bucket(next_at);
                    if nb > b {
                        self.buckets[nb].push(slot);
                        break;
                    }
                    let Scheduled { at, event, .. } =
                        self.pending[i].pop().expect("peeked event");
                    if at > horizon {
                        break;
                    }
                    if !self.drifted[i] && at >= drift_at {
                        self.drifted[i] = true;
                        if ctx.scenario.detector == DetectorKind::Oracle {
                            sims[i].edge.force_training();
                        }
                    }
                    let mut lane = Lane {
                        now: at,
                        drifted: self.drifted[i],
                        seq: &mut self.seq[i],
                        pending: &mut self.pending[i],
                    };
                    sims[i].handle_event(event, &mut lane, ctx, sketches);
                }
            }
        }
    }
}

impl EdgeSim {
    /// Dispatch one event. Self-rescheduling events (Sense, Eval) land at
    /// `lane.now + period ≥ now`, so a handler can only ever schedule
    /// into the current or a later bucket — the wheel walk never misses
    /// an event.
    fn handle_event(
        &mut self,
        event: Event,
        lane: &mut Lane,
        ctx: &SimContext,
        sketches: &mut Option<ShardSketches>,
    ) {
        match event {
            Event::Sense => {
                self.handle_sense(lane, ctx, sketches);
                let next = lane.now + ctx.scenario.event_period_s;
                lane.schedule(next, Event::Sense);
            }
            Event::Reply { label } => {
                self.edge.on_label(label);
                self.metrics.trained = self.edge.total_trained;
                self.metrics.record_state(
                    PowerState::Train,
                    ctx.cycles.train_time_s(),
                    ctx.power.power_mw(PowerState::Train),
                );
            }
            Event::QueryFailed => {
                self.edge.on_query_failed();
                self.metrics.query_failures += 1;
            }
            Event::Eval => {
                self.run_eval_window(lane, ctx);
                let next = lane.now + ctx.scenario.eval_period_s;
                lane.schedule(next, Event::Eval);
            }
        }
    }

    fn handle_sense(
        &mut self,
        lane: &mut Lane,
        ctx: &SimContext,
        sketches: &mut Option<ShardSketches>,
    ) {
        let (x, true_label) = draw_sample(
            ctx.generator,
            ctx.standardizer,
            self.subjects,
            lane.drifted,
            ctx.scenario.synth.n_classes,
            &mut self.rng,
        );
        self.metrics.events += 1;
        self.metrics.record_state(
            PowerState::Predict,
            ctx.cycles.predict_time_s(),
            ctx.power.power_mw(PowerState::Predict),
        );
        let (pred, action) = self.edge.on_sense(&x);
        self.metrics.record_prediction(lane.now, pred.class == true_label);
        if let Some(sk) = sketches {
            // distinct (subject, class) cells the fleet has sensed, and
            // distinct (edge, FSM mode) states occupied at sense events —
            // keys packed so equal observations encode equally
            let subject = if lane.drifted {
                self.subjects.1
            } else {
                self.subjects.0
            };
            sk.visited_cells
                .insert(((subject as u64) << 32) | true_label as u64);
            let mode_tag = match self.edge.mode {
                Mode::Predicting => 0u64,
                Mode::Training => 1,
            };
            sk.edge_states.insert(((self.edge.id as u64) << 2) | mode_tag);
        }
        if action == StepAction::QueryTeacher {
            let delivery = self.channel.transmit();
            self.metrics.radio_energy_mj += delivery.energy_mj;
            if delivery.delivered {
                let label =
                    self.teacher
                        .respond(&x, true_label, ctx.scenario.synth.n_classes);
                let at = lane.now + delivery.elapsed_s + self.teacher.service_time_s;
                lane.schedule(at, Event::Reply { label });
            } else {
                let at = lane.now + delivery.elapsed_s;
                lane.schedule(at, Event::QueryFailed);
            }
        }
    }

    /// One evaluation window: draw a probe batch from this edge's
    /// *current* sampling distribution and score it through the batched
    /// predict path (one packed-α panel sweep + one logits GEMM per
    /// block, row-sharded when `ctx.eval_workers > 1`). Probes never
    /// touch the edge FSM, the pruner, or the sense stream; they touch
    /// the power ledger only when `Scenario::eval_costs_power` asks for
    /// honest on-device probe energy.
    fn run_eval_window(&mut self, lane: &Lane, ctx: &SimContext) {
        let ns = ctx.scenario.eval_samples;
        if ns == 0 {
            return;
        }
        let nf = ctx.scenario.synth.n_features;
        let n_classes = ctx.scenario.synth.n_classes;
        let mut xs = Mat::zeros(ns, nf);
        let mut labels = Vec::with_capacity(ns);
        for r in 0..ns {
            let (x, class) = draw_sample(
                ctx.generator,
                ctx.standardizer,
                self.subjects,
                lane.drifted,
                n_classes,
                &mut self.eval_rng,
            );
            xs.row_mut(r).copy_from_slice(&x);
            labels.push(class);
        }
        let acc = if ctx.eval_workers > 1 {
            self.edge.model.accuracy_par(&xs, &labels, ctx.eval_workers)
        } else {
            self.edge.model.accuracy(&xs, &labels)
        };
        self.metrics.eval_trace.push((lane.now, acc));
        if ctx.scenario.eval_costs_power {
            // a real deployment runs the probes on-device: book ns
            // inferences of predict-state time through the same ledger as
            // the sense path
            self.metrics.record_state(
                PowerState::Predict,
                ctx.cycles.predict_time_s() * ns as f64,
                ctx.power.power_mw(PowerState::Predict),
            );
        }
    }
}

/// The OS-ELM config every edge of a scenario runs — the single source
/// for the inline and memoized provisioning paths.
fn edge_model_config(sc: &Scenario) -> OsElmConfig {
    OsElmConfig {
        n_in: sc.synth.n_features,
        n_hidden: sc.n_hidden,
        n_out: sc.synth.n_classes,
        alpha: AlphaKind::Hash,
        ..Default::default()
    }
}

/// α hash seed of edge `id` under fleet seed `seed`. Frozen: part of
/// every recorded trajectory. Wrapping arithmetic throughout — the
/// product overflows u16 from edge 2115 up, which is well inside a
/// "millions of edges" fleet; release builds always wrapped here, and
/// `wrapping_mul` keeps debug builds bit-identical instead of panicking.
fn edge_hash_seed(seed: u64, id: usize) -> u16 {
    (seed as u16).wrapping_add((id as u16).wrapping_mul(31))
}

/// Construct + batch-provision edge `id`'s OS-ELM core from the
/// (shuffled) provisioning pool. `edge_rng` must be the edge's canonical
/// `stream_seed(seed, PROVISION, id)` stream (unused under
/// `AlphaKind::Hash` — α comes from the 16-bit xorshift keyed by
/// [`edge_hash_seed`] — but a future α kind may sample here).
fn provision_edge_model_with(
    sc: &Scenario,
    seed: u64,
    id: usize,
    edge_rng: &mut Rng64,
    train: &Dataset,
) -> Result<OsElm> {
    let mut model = OsElm::new(edge_model_config(sc), edge_rng, edge_hash_seed(seed, id));
    model.init_batch(&train.xs, &train.labels)?;
    Ok(model)
}

/// [`provision_edge_model_with`] on a freshly derived canonical stream —
/// the entry point the sweep engine's **edge-state memo** uses. The
/// provisioned core is a pure function of the data/model knobs (synth
/// config, data seed, `n_hidden`), the fleet seed, the edge id, and the
/// shuffled pool — and is **independent of `n_edges`**, `fixed_theta`,
/// the detector, the channel, and the teacher — so cells of a scenario
/// grid that share those inputs can share one build per edge and clone
/// it, bitwise indistinguishable from provisioning from scratch.
pub fn provisioned_edge_model(
    sc: &Scenario,
    seed: u64,
    id: usize,
    train: &Dataset,
) -> Result<OsElm> {
    let mut rng = Rng64::new(stream_seed(seed, domain::PROVISION, id as u64));
    provision_edge_model_with(sc, seed, id, &mut rng, train)
}

/// Assemble one [`EdgeSim`] shard around an already-provisioned core.
/// Pure function of the scenario, the fleet seed, the edge id, and the
/// model — the invariant that makes sharded construction bitwise equal
/// to the sequential walk for any worker partitioning (and a memoized
/// model clone bitwise equal to a fresh provisioning).
fn build_edge_sim(
    sc: &Scenario,
    seed: u64,
    id: usize,
    model: OsElm,
    in_subjects: &[usize],
) -> EdgeSim {
    let policy = match sc.fixed_theta {
        Some(t) => ThetaPolicy::Fixed(t),
        None => ThetaPolicy::auto(),
    };
    let detector: Box<dyn DriftDetector + Send> = match sc.detector {
        DetectorKind::Oracle => Box::new(OracleDetector::new()),
        DetectorKind::Centroid => Box::new(CentroidDetector::new(sc.synth.n_features)),
    };
    let warmup = crate::pruning::warmup_for(sc.n_hidden).min(sc.train_target / 2);
    let edge = EdgeDevice::from_parts(
        id,
        model,
        Pruner::new(policy, Metric::P1P2, warmup),
        detector,
        sc.train_target,
    );
    let pre = in_subjects[id % in_subjects.len()];
    let post = HELD_OUT_SUBJECTS[id % HELD_OUT_SUBJECTS.len()];
    let eid = id as u64;
    // boot events (staggered Sense, the eval cadence) are scheduled by
    // the shard's WheelEngine from `edge.id` when the run starts
    EdgeSim {
        edge,
        metrics: EdgeMetrics::default(),
        subjects: (pre, post),
        rng: CounterRng::new(seed, domain::SENSE, eid),
        eval_rng: CounterRng::new(seed, domain::EVAL, eid),
        channel: Channel::new(sc.channel.clone(), stream_seed(seed, domain::CHANNEL, eid)),
        teacher: Teacher::oracle(sc.teacher_error, stream_seed(seed, domain::TEACHER, eid)),
    }
}

/// The simulator. Holds only what the event loop needs from the
/// provisioning artifacts (generator, standardizer, resolved data seed —
/// a few hundred KB at most); the training pool itself is dropped when
/// construction finishes, exactly like the pre-staging code.
pub struct Fleet {
    pub cfg: FleetConfig,
    sims: Vec<EdgeSim>,
    generator: SynthHar,
    standardizer: Standardizer,
    data_seed: u64,
    power: PowerModel,
    cycles: CycleModel,
}

impl Fleet {
    /// Sequential construction — defined as [`Fleet::new_parallel`] with
    /// one provisioning worker, so the sequential and sharded paths are
    /// one code path (the same by-construction argument `run` makes for
    /// the event loop).
    pub fn new(cfg: FleetConfig) -> Result<Fleet> {
        Fleet::new_parallel(cfg, 1)
    }

    /// Construct the fleet with per-edge provisioning (`OsElm::init_batch`
    /// + `EdgeDevice::provision`) sharded over up to `provision_workers`
    /// scoped threads. The shared artifacts are built once on the calling
    /// thread; every edge build is a pure function of `(scenario, seed,
    /// id, shuffled pool)`, so the resulting fleet — and every report it
    /// produces — is **bitwise identical** to sequential construction for
    /// every worker count (asserted in `tests/fleet_determinism.rs`).
    pub fn new_parallel(cfg: FleetConfig, provision_workers: usize) -> Result<Fleet> {
        let artifacts = ProvisionArtifacts::build(&cfg.scenario, cfg.seed, false);
        // the pool inside `artifacts` is dropped here, right after the
        // edges are provisioned from it
        Fleet::with_artifacts(cfg, &artifacts, provision_workers)
    }

    /// Construct from pre-built shared artifacts (the sweep engine's
    /// memoized path — it keeps them in `Arc`s and lends them out per
    /// cell). `artifacts.key` must match the scenario's
    /// [`ProvisionArtifacts::data_key`] under `cfg.seed`. The fleet
    /// copies out only the generator/standardizer; it never retains the
    /// pool.
    pub fn with_artifacts(
        cfg: FleetConfig,
        artifacts: &ProvisionArtifacts,
        provision_workers: usize,
    ) -> Result<Fleet> {
        // The per-fleet row order: same stream and draw sequence as the
        // historical in-place shuffle.
        let train = artifacts.shuffled_train(cfg.seed);
        Fleet::with_shuffled_pool(cfg, artifacts, &train, provision_workers)
    }

    /// Construct from pre-built shared artifacts **and** a pre-shuffled
    /// provisioning pool. `train` must be
    /// `artifacts.shuffled_train(cfg.seed)` — the per-fleet row order is
    /// part of every recorded trajectory, so the sweep engine memoizes it
    /// per `(data key, seed)` pair and lends the same shuffled pool to
    /// every cell sharing both.
    pub fn with_shuffled_pool(
        cfg: FleetConfig,
        artifacts: &ProvisionArtifacts,
        train: &Dataset,
        provision_workers: usize,
    ) -> Result<Fleet> {
        Fleet::build_with_models(cfg, artifacts, train, None, provision_workers)
    }

    /// Construct from pre-built artifacts, a pre-shuffled pool, **and**
    /// pre-provisioned per-edge cores — the sweep engine's edge-state
    /// memo path. `models[id]` must be (bitwise) the model
    /// [`provisioned_edge_model`]`(sc, seed, id, train)` returns; each
    /// edge clones its core instead of re-running `init_batch`, so the
    /// fleet — and every report it produces — is bitwise identical to
    /// [`Fleet::with_shuffled_pool`] while skipping the dominant
    /// construction cost.
    pub fn with_edge_models(
        cfg: FleetConfig,
        artifacts: &ProvisionArtifacts,
        train: &Dataset,
        models: &[Arc<OsElm>],
        provision_workers: usize,
    ) -> Result<Fleet> {
        ensure!(
            models.len() >= cfg.scenario.n_edges,
            "edge-state memo holds {} model(s) but the scenario needs {}",
            models.len(),
            cfg.scenario.n_edges
        );
        let want = edge_model_config(&cfg.scenario);
        for (id, m) in models.iter().take(cfg.scenario.n_edges).enumerate() {
            ensure!(
                m.cfg.n_in == want.n_in
                    && m.cfg.n_hidden == want.n_hidden
                    && m.cfg.n_out == want.n_out,
                "memoized model for edge {id} was provisioned for a different \
                 shape ({}x{}x{} vs {}x{}x{})",
                m.cfg.n_in,
                m.cfg.n_hidden,
                m.cfg.n_out,
                want.n_in,
                want.n_hidden,
                want.n_out
            );
        }
        Fleet::build_with_models(cfg, artifacts, train, Some(models), provision_workers)
    }

    fn build_with_models(
        cfg: FleetConfig,
        artifacts: &ProvisionArtifacts,
        train: &Dataset,
        models: Option<&[Arc<OsElm>]>,
        provision_workers: usize,
    ) -> Result<Fleet> {
        let sc = &cfg.scenario;
        ensure!(
            artifacts.key == ProvisionArtifacts::data_key(sc, cfg.seed),
            "provisioning artifacts were built for a different data config"
        );
        let n_edges = sc.n_edges;
        let seed = cfg.seed;
        // Per-edge provisioning over the shared executor's keyed streams:
        // edge `id` draws (if its α kind ever samples) from the private
        // `stream_seed(seed, PROVISION, id)` stream, so the build is a
        // pure function of `(scenario, seed, id, shuffled pool)` and the
        // ordered fan-out is bitwise identical to the sequential walk for
        // every worker count. A memoized core was provisioned on the
        // identical stream, so cloning it cannot move a bit either.
        let sims: Vec<EdgeSim> = parallel::parallel_map_keyed(
            provision_workers,
            n_edges,
            seed,
            domain::PROVISION,
            |id, edge_rng| -> Result<EdgeSim> {
                let model = match models {
                    Some(ms) => (*ms[id]).clone(),
                    None => provision_edge_model_with(sc, seed, id, edge_rng, train)?,
                };
                Ok(build_edge_sim(sc, seed, id, model, &artifacts.in_subjects))
            },
        )
        .into_iter()
        .collect::<Result<_>>()?;

        let cycles = CycleModel::prototype().with_dims(
            sc.synth.n_features,
            sc.n_hidden,
            sc.synth.n_classes,
        );
        Ok(Fleet {
            sims,
            generator: artifacts.generator.clone(),
            standardizer: artifacts.standardizer.clone(),
            data_seed: artifacts.data_seed,
            power: PowerModel::default(),
            cycles,
            cfg,
        })
    }

    /// Run to the horizon on the calling thread; returns the report.
    /// Defined as `run_parallel(1)`, so the sequential and sharded paths
    /// are one code path — determinism by construction, not by test alone.
    pub fn run(self) -> FleetReport {
        self.run_parallel(1)
    }

    /// Run to the horizon with the per-edge shards spread over up to
    /// `n_workers` scoped threads (clamped to the edge count; ≤ 1 runs on
    /// the calling thread). The report is **bitwise identical** to
    /// [`Fleet::run`] for the same seed and scenario, for every worker
    /// count — no shard reads another shard's state, and the close-of-
    /// books merge always walks edges in id order on one thread.
    pub fn run_parallel(self, n_workers: usize) -> FleetReport {
        let Fleet {
            cfg,
            mut sims,
            generator,
            standardizer,
            power,
            cycles,
            ..
        } = self;
        let n_edges = sims.len();
        let workers = n_workers.max(1).min(n_edges.max(1));
        let ctx = SimContext {
            scenario: &cfg.scenario,
            generator: &generator,
            standardizer: &standardizer,
            power,
            cycles,
            eval_workers: if workers > 1 { 1 } else { n_workers.max(1) },
        };
        // one time wheel per shard over contiguous ⌈n/w⌉ chunks — the
        // same chunk layout the heap-era executor used; aggregate mode
        // hands back each shard's O(1) HLL state for the chunk-ordered
        // merge below
        let aggregate = cfg.scenario.metrics == MetricsMode::Aggregate;
        let shard_sketches = parallel::map_shard_chunks(workers, &mut sims, |_, chunk| {
            let mut sketches = aggregate.then(ShardSketches::default);
            let mut wheel = WheelEngine::new(&ctx, chunk);
            wheel.run(chunk, &ctx, &mut sketches);
            sketches
        });

        // close the books: remaining time is sleep; merge in edge order.
        // Aggregate mode folds each edge's would-be row into the O(1)
        // aggregate (same id-order walk, same f64 association as the
        // full-mode getters) and drops it.
        let horizon = cfg.scenario.horizon_s;
        let mut report = FleetReport {
            horizon_s: horizon,
            per_edge: Vec::with_capacity(if aggregate { 0 } else { n_edges }),
            teacher_queries: 0,
            channel_attempts: 0,
            channel_failures: 0,
            aggregate: None,
        };
        let mut agg = aggregate.then(FleetAggregate::default);
        for sim in sims {
            let EdgeSim {
                edge,
                mut metrics,
                channel,
                teacher,
                ..
            } = sim;
            let active: f64 = metrics.state_time_s.values().sum();
            metrics.record_state(
                PowerState::Sleep,
                (horizon - active).max(0.0),
                power.power_mw(PowerState::Sleep),
            );
            metrics.queries = edge.total_queries;
            metrics.skips = edge.total_skips;
            metrics.trained = edge.total_trained;
            metrics.mode_switches = edge.mode_switches;
            report.teacher_queries += teacher.queries_served;
            report.channel_attempts += channel.total_attempts;
            report.channel_failures += channel.total_failures;
            match agg.as_mut() {
                None => report.per_edge.push(metrics),
                Some(agg) => {
                    agg.n_edges += 1;
                    agg.events += metrics.events;
                    agg.trained += metrics.trained;
                    agg.skips += metrics.skips;
                    agg.query_failures += metrics.query_failures;
                    agg.mode_switches += metrics.mode_switches;
                    agg.total_queries += metrics.queries;
                    agg.total_energy_mj += metrics.core_energy_mj + metrics.radio_energy_mj;
                    if let Some(&(_, acc)) = metrics.accuracy_trace.last() {
                        agg.accuracy.insert(acc);
                    }
                    agg.power_mw.insert(metrics.mean_power_mw(horizon));
                    agg.queries.insert(metrics.queries as f64);
                }
            }
        }
        if let Some(mut agg) = agg {
            for sk in shard_sketches.into_iter().flatten() {
                agg.visited_cells.merge(&sk.visited_cells);
                agg.edge_states.merge(&sk.edge_states);
            }
            report.aggregate = Some(agg);
        }
        report
    }

    /// Threaded live-system mode: each edge on its own thread, the teacher
    /// on another, queries over std mpsc. Event counts replace virtual
    /// time (energy bookkeeping is the event-loop mode's job; this mode
    /// demonstrates the concurrent topology works). Returns per-edge
    /// (queries, trained) counters.
    pub fn run_threaded(
        scenario: &Scenario,
        seed: u64,
        events_per_edge: usize,
    ) -> Result<Vec<(u64, u64)>> {
        use std::sync::mpsc;

        // Build the same fleet state, then split it across threads.
        let fleet = Fleet::new(FleetConfig {
            scenario: scenario.clone(),
            seed,
        })?;
        let n_classes = scenario.synth.n_classes;
        let mut teacher = Teacher::oracle(
            scenario.teacher_error,
            stream_seed(seed, domain::TEACHER, u64::MAX),
        );

        // teacher thread: serves (edge_id, x, true_label) -> label
        type Query = (usize, Vec<f32>, usize);
        let (q_tx, q_rx) = mpsc::channel::<(Query, mpsc::Sender<usize>)>();
        let teacher_handle = std::thread::spawn(move || {
            while let Ok(((_, x, truth), reply_tx)) = q_rx.recv() {
                let label = teacher.respond(&x, truth, n_classes);
                let _ = reply_tx.send(label);
            }
        });

        let mut handles = Vec::new();
        let generator_cfg = scenario.synth.clone();
        let data_seed = fleet.data_seed;
        let standardizer = fleet.standardizer;
        for (id, sim) in fleet.sims.into_iter().enumerate() {
            let q_tx = q_tx.clone();
            let mut edge = sim.edge;
            let (pre, post) = sim.subjects;
            let mean = standardizer.mean.clone();
            let std = standardizer.std.clone();
            let synth_cfg = generator_cfg.clone();
            let drift_at = events_per_edge / 3;
            handles.push(std::thread::spawn(move || -> (u64, u64) {
                // per-thread generator (same family, thread-local stream)
                let mut rng = Rng64::new(seed ^ (id as u64 + 1));
                let mut data_rng = Rng64::new(data_seed);
                let gen = SynthHar::new(synth_cfg.clone(), &mut data_rng);
                for ev in 0..events_per_edge {
                    let subject = if ev >= drift_at { post } else { pre };
                    if ev == drift_at {
                        edge.force_training();
                    }
                    let class = rng.below(synth_cfg.n_classes);
                    let mut x = gen.sample(class, subject, &mut rng);
                    for ((v, &m), &s) in x.iter_mut().zip(&mean).zip(&std) {
                        *v = (*v - m) / s;
                    }
                    let (_, action) = edge.on_sense(&x);
                    if action == StepAction::QueryTeacher {
                        let (r_tx, r_rx) = mpsc::channel();
                        q_tx.send(((id, x, class), r_tx)).expect("teacher gone");
                        let label = r_rx.recv().expect("teacher reply");
                        edge.on_label(label);
                    }
                }
                (edge.total_queries, edge.total_trained)
            }));
        }
        drop(q_tx);
        let counters: Vec<(u64, u64)> = handles
            .into_iter()
            .map(|h| h.join().expect("edge thread panicked"))
            .collect();
        teacher_handle.join().expect("teacher thread panicked");
        Ok(counters)
    }

    /// Current mode of an edge (tests).
    pub fn edge_mode(&self, id: usize) -> Mode {
        self.sims[id].edge.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_scenario() -> Scenario {
        Scenario {
            n_edges: 3,
            n_hidden: 32,
            event_period_s: 1.0,
            horizon_s: 300.0,
            drift_at_s: 60.0,
            train_target: 120,
            synth: SynthConfig {
                n_features: 40,
                n_classes: 4,
                n_subjects: 30,
                samples_per_cell: 10,
                proto_sigma: 1.1,
                confuse_frac: 0.04,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// The retired per-edge `BinaryHeap` event loop, reconstructed on top
    /// of the shared handlers — the executable spec of the tie-break
    /// contract the wheel must honour.
    fn run_heap_reference(fleet: Fleet) -> FleetReport {
        use std::collections::BinaryHeap;
        let Fleet {
            cfg,
            mut sims,
            generator,
            standardizer,
            power,
            cycles,
            ..
        } = fleet;
        let sc = cfg.scenario;
        let ctx = SimContext {
            scenario: &sc,
            generator: &generator,
            standardizer: &standardizer,
            power,
            cycles,
            eval_workers: 1,
        };
        for sim in sims.iter_mut() {
            let mut heap: BinaryHeap<Scheduled> = BinaryHeap::new();
            let mut seq = 0u64;
            seq += 1;
            let phase = sc.event_period_s * (sim.edge.id as f64 / sc.n_edges.max(1) as f64);
            heap.push(Scheduled {
                at: phase,
                seq,
                event: Event::Sense,
            });
            if sc.eval_period_s > 0.0 {
                seq += 1;
                heap.push(Scheduled {
                    at: sc.eval_period_s,
                    seq,
                    event: Event::Eval,
                });
            }
            let mut drifted = false;
            while let Some(Scheduled { at, event, .. }) = heap.pop() {
                if at > sc.horizon_s {
                    break;
                }
                if !drifted && at >= sc.drift_at_s {
                    drifted = true;
                    if sc.detector == DetectorKind::Oracle {
                        sim.edge.force_training();
                    }
                }
                let mut staged = Vec::new();
                let mut lane = Lane {
                    now: at,
                    drifted,
                    seq: &mut seq,
                    pending: &mut staged,
                };
                sim.handle_event(event, &mut lane, &ctx, &mut None);
                for s in staged {
                    heap.push(s);
                }
            }
        }
        let horizon = sc.horizon_s;
        let mut report = FleetReport {
            horizon_s: horizon,
            per_edge: Vec::with_capacity(sims.len()),
            teacher_queries: 0,
            channel_attempts: 0,
            channel_failures: 0,
            aggregate: None,
        };
        for sim in sims {
            let EdgeSim {
                edge,
                mut metrics,
                channel,
                teacher,
                ..
            } = sim;
            let active: f64 = metrics.state_time_s.values().sum();
            metrics.record_state(
                PowerState::Sleep,
                (horizon - active).max(0.0),
                power.power_mw(PowerState::Sleep),
            );
            metrics.queries = edge.total_queries;
            metrics.skips = edge.total_skips;
            metrics.trained = edge.total_trained;
            metrics.mode_switches = edge.mode_switches;
            report.teacher_queries += teacher.queries_served;
            report.channel_attempts += channel.total_attempts;
            report.channel_failures += channel.total_failures;
            report.per_edge.push(metrics);
        }
        report
    }

    #[test]
    fn wheel_pops_in_heap_order() {
        use std::collections::BinaryHeap;
        // the lane's sorted pending list must pop exactly the (at, seq)
        // sequence a BinaryHeap pops, under random interleavings of
        // schedules and pops with heavy exact-time ties (coarse at grid;
        // ties break by lower seq in both structures)
        let mut rng = Rng64::new(0x11EE1);
        for case in 0..50 {
            let mut heap: BinaryHeap<Scheduled> = BinaryHeap::new();
            let mut pending: Vec<Scheduled> = Vec::new();
            let mut seq = 0u64;
            for _op in 0..200 {
                if pending.is_empty() || rng.below(3) < 2 {
                    let at = rng.below(16) as f64 * 0.25;
                    let mut lane = Lane {
                        now: 0.0,
                        drifted: false,
                        seq: &mut seq,
                        pending: &mut pending,
                    };
                    lane.schedule(at, Event::Sense);
                    heap.push(Scheduled {
                        at,
                        seq,
                        event: Event::Sense,
                    });
                } else {
                    let a = pending.pop().unwrap();
                    let b = heap.pop().unwrap();
                    assert_eq!(a.at.to_bits(), b.at.to_bits(), "case {case}");
                    assert_eq!(a.seq, b.seq, "case {case}");
                }
            }
            loop {
                match (pending.pop(), heap.pop()) {
                    (Some(a), Some(b)) => {
                        assert_eq!(a.at.to_bits(), b.at.to_bits(), "case {case}");
                        assert_eq!(a.seq, b.seq, "case {case}");
                    }
                    (None, None) => break,
                    _ => panic!("pending and heap drained unevenly in case {case}"),
                }
            }
        }
    }

    #[test]
    fn wheel_matches_heap_reference_bitwise() {
        // the binding tie-break contract: the wheel must dispatch per-edge
        // events in exactly the retired heap's order — asserted by
        // replaying the heap loop over the shared handlers and requiring
        // bitwise-identical reports, under channel delays (Reply /
        // QueryFailed landing between sense ticks), eval ticks colliding
        // with sense ticks at t = 50k, and the drift boundary
        let mut sc = small_scenario();
        sc.eval_period_s = 50.0;
        sc.eval_samples = 16;
        sc.channel = ChannelConfig {
            loss_prob: 0.2,
            max_retries: 1,
            ..Default::default()
        };
        sc.teacher_error = 0.1;
        for seed in [5u64, 9] {
            let wheel = Fleet::new(FleetConfig {
                scenario: sc.clone(),
                seed,
            })
            .unwrap()
            .run();
            let heap = run_heap_reference(
                Fleet::new(FleetConfig {
                    scenario: sc.clone(),
                    seed,
                })
                .unwrap(),
            );
            assert!(wheel.bitwise_eq(&heap), "wheel diverged at seed {seed}");
        }
        // centroid flavour exercises the no-oracle drift path
        let mut c = sc.clone();
        c.detector = DetectorKind::Centroid;
        let wheel = Fleet::new(FleetConfig {
            scenario: c.clone(),
            seed: 4,
        })
        .unwrap()
        .run();
        let heap = run_heap_reference(
            Fleet::new(FleetConfig {
                scenario: c,
                seed: 4,
            })
            .unwrap(),
        );
        assert!(wheel.bitwise_eq(&heap), "wheel diverged on centroid scenario");
    }

    #[test]
    fn aggregate_mode_matches_full_totals_and_is_worker_invariant() {
        let mut sc = small_scenario();
        sc.eval_period_s = 50.0;
        sc.eval_samples = 8;
        sc.channel = ChannelConfig {
            loss_prob: 0.2,
            max_retries: 1,
            ..Default::default()
        };
        sc.teacher_error = 0.1;
        let full = Fleet::new(FleetConfig {
            scenario: sc.clone(),
            seed: 5,
        })
        .unwrap()
        .run();
        assert!(full.aggregate.is_none(), "full mode must not carry sketches");
        let mut agg_sc = sc.clone();
        agg_sc.metrics = MetricsMode::Aggregate;
        let run_agg = |workers: usize| {
            Fleet::new(FleetConfig {
                scenario: agg_sc.clone(),
                seed: 5,
            })
            .unwrap()
            .run_parallel(workers)
        };
        let r = run_agg(1);
        // O(1) report: no per-edge rows, one aggregate
        assert!(r.per_edge.is_empty());
        let a = r.aggregate.as_ref().unwrap();
        // exact counters must equal the full-mode fold bit for bit (the
        // simulated trajectories are identical; only reporting differs)
        assert_eq!(a.n_edges, 3);
        assert_eq!(
            a.events,
            full.per_edge.iter().map(|m| m.events).sum::<u64>()
        );
        assert_eq!(
            a.trained,
            full.per_edge.iter().map(|m| m.trained).sum::<u64>()
        );
        assert_eq!(a.skips, full.per_edge.iter().map(|m| m.skips).sum::<u64>());
        assert_eq!(
            a.query_failures,
            full.per_edge.iter().map(|m| m.query_failures).sum::<u64>()
        );
        assert_eq!(a.total_queries, full.total_queries());
        assert_eq!(a.total_energy_mj.to_bits(), full.total_energy_mj().to_bits());
        // the rollup getters agree across modes, bitwise
        assert_eq!(r.total_queries(), full.total_queries());
        assert_eq!(r.total_energy_mj().to_bits(), full.total_energy_mj().to_bits());
        assert_eq!(
            r.mean_edge_power_mw().to_bits(),
            full.mean_edge_power_mw().to_bits()
        );
        assert_eq!(r.teacher_queries, full.teacher_queries);
        assert_eq!(r.channel_attempts, full.channel_attempts);
        assert_eq!(r.channel_failures, full.channel_failures);
        // sketch plausibility: every edge contributes one sample to each
        // quantile sketch; HLL estimates sit in the exact small-range
        // windows (3 edges × ≤2 modes; ≤ 2 subjects × 4 classes per edge)
        assert_eq!(a.queries.count(), 3);
        assert_eq!(a.power_mw.count(), 3);
        assert_eq!(a.accuracy.count(), 3);
        let states = a.edge_states.estimate();
        assert!((2.5..=7.0).contains(&states), "edge states {states}");
        let cells = a.visited_cells.estimate();
        assert!((3.5..=30.0).contains(&cells), "visited cells {cells}");
        // bitwise worker invariance, sketch registers included
        for workers in [2usize, 3, 8] {
            assert!(
                r.bitwise_eq(&run_agg(workers)),
                "aggregate diverged at {workers} workers"
            );
        }
    }

    #[test]
    fn fleet_runs_and_recovers() {
        let fleet = Fleet::new(FleetConfig {
            scenario: small_scenario(),
            seed: 1,
        })
        .unwrap();
        let report = fleet.run();
        assert_eq!(report.per_edge.len(), 3);
        for m in &report.per_edge {
            assert!(m.events >= 295, "events {}", m.events);
            assert!(m.queries > 0, "drift must trigger queries");
            assert!(m.trained > 0);
            // accuracy at the end must be decent again (recovery)
            let last = m.accuracy_trace.last().unwrap().1;
            assert!(last > 0.7, "final rolling accuracy {last}");
        }
        assert_eq!(report.teacher_queries, report.total_queries());
    }

    #[test]
    fn fleet_is_deterministic() {
        let run = |seed| {
            let fleet = Fleet::new(FleetConfig {
                scenario: small_scenario(),
                seed,
            })
            .unwrap();
            let r = fleet.run();
            (
                r.total_queries(),
                r.per_edge.iter().map(|m| m.trained).collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn parallel_run_bitwise_matches_sequential() {
        // The engine contract, on the in-module scenario (the cross-seed
        // / cross-detector matrix lives in tests/fleet_determinism.rs):
        // identical FleetReport bits for every worker count.
        let mut sc = small_scenario();
        sc.eval_period_s = 50.0;
        sc.eval_samples = 16;
        sc.channel = ChannelConfig {
            loss_prob: 0.2,
            max_retries: 1,
            ..Default::default()
        };
        sc.teacher_error = 0.1;
        let seq = Fleet::new(FleetConfig {
            scenario: sc.clone(),
            seed: 5,
        })
        .unwrap()
        .run();
        for workers in [1usize, 2, 3, 8] {
            let par = Fleet::new(FleetConfig {
                scenario: sc.clone(),
                seed: 5,
            })
            .unwrap()
            .run_parallel(workers);
            assert!(seq.bitwise_eq(&par), "diverged at {workers} workers");
        }
    }

    #[test]
    fn parallel_provisioning_bitwise_matches_sequential_construction() {
        // The construction contract (the run-phase matrix lives in
        // tests/fleet_determinism.rs): a fleet provisioned with k workers
        // must be indistinguishable — report bits included — from the
        // sequentially built one.
        let sc = small_scenario();
        let seq = Fleet::new(FleetConfig {
            scenario: sc.clone(),
            seed: 9,
        })
        .unwrap()
        .run();
        for workers in [2usize, 3, 8] {
            let par = Fleet::new_parallel(
                FleetConfig {
                    scenario: sc.clone(),
                    seed: 9,
                },
                workers,
            )
            .unwrap()
            .run();
            assert!(
                seq.bitwise_eq(&par),
                "construction diverged at {workers} provisioning workers"
            );
        }
    }

    #[test]
    fn with_artifacts_matches_monolithic_construction() {
        let sc = small_scenario();
        let cfg = FleetConfig {
            scenario: sc.clone(),
            seed: 12,
        };
        let artifacts = ProvisionArtifacts::build(&sc, 12, false);
        let direct = Fleet::new(cfg.clone()).unwrap().run();
        let shared = Fleet::with_artifacts(cfg, &artifacts, 2).unwrap().run();
        assert!(direct.bitwise_eq(&shared));
    }

    #[test]
    fn memoized_shuffled_pool_matches_with_artifacts() {
        // the sweep engine's (data key, seed)-memoized shuffle path must
        // be indistinguishable from with_artifacts' private shuffle
        let sc = small_scenario();
        let cfg = FleetConfig {
            scenario: sc.clone(),
            seed: 12,
        };
        let artifacts = ProvisionArtifacts::build(&sc, 12, false);
        let train = artifacts.shuffled_train(12);
        let direct = Fleet::with_artifacts(cfg.clone(), &artifacts, 1).unwrap().run();
        let memoized = Fleet::with_shuffled_pool(cfg, &artifacts, &train, 2)
            .unwrap()
            .run();
        assert!(direct.bitwise_eq(&memoized));
    }

    #[test]
    fn memoized_edge_models_match_fresh_provisioning() {
        // the sweep engine's edge-state memo path: cores provisioned
        // once via provisioned_edge_model, cloned into fleets — bitwise
        // equal to provisioning from scratch, including for a smaller
        // fleet that borrows a prefix of the same model set
        let sc = small_scenario();
        let artifacts = ProvisionArtifacts::build(&sc, 21, false);
        let train = artifacts.shuffled_train(21);
        let models: Vec<Arc<OsElm>> = (0..sc.n_edges)
            .map(|id| Arc::new(provisioned_edge_model(&sc, 21, id, &train).unwrap()))
            .collect();
        let cfg = FleetConfig {
            scenario: sc.clone(),
            seed: 21,
        };
        let fresh = Fleet::with_shuffled_pool(cfg.clone(), &artifacts, &train, 1)
            .unwrap()
            .run();
        let memo = Fleet::with_edge_models(cfg, &artifacts, &train, &models, 2)
            .unwrap()
            .run();
        assert!(fresh.bitwise_eq(&memo));
        // n_edges is not a provisioning knob: a 2-edge cell clones the
        // first two of the same cores and must match a monolithic build
        let mut small = sc.clone();
        small.n_edges = 2;
        let cfg2 = FleetConfig {
            scenario: small,
            seed: 21,
        };
        let fresh2 = Fleet::new(cfg2.clone()).unwrap().run();
        let memo2 = Fleet::with_edge_models(cfg2, &artifacts, &train, &models, 1)
            .unwrap()
            .run();
        assert!(fresh2.bitwise_eq(&memo2));
    }

    #[test]
    fn with_edge_models_rejects_short_or_mismatched_sets() {
        let sc = small_scenario();
        let artifacts = ProvisionArtifacts::build(&sc, 4, false);
        let train = artifacts.shuffled_train(4);
        let cfg = FleetConfig {
            scenario: sc.clone(),
            seed: 4,
        };
        // too few models for the fleet
        let short: Vec<Arc<OsElm>> = (0..sc.n_edges - 1)
            .map(|id| Arc::new(provisioned_edge_model(&sc, 4, id, &train).unwrap()))
            .collect();
        assert!(Fleet::with_edge_models(cfg.clone(), &artifacts, &train, &short, 1).is_err());
        // models provisioned for a different hidden width
        let mut wide = sc.clone();
        wide.n_hidden = 48;
        let wrong: Vec<Arc<OsElm>> = (0..sc.n_edges)
            .map(|id| Arc::new(provisioned_edge_model(&wide, 4, id, &train).unwrap()))
            .collect();
        assert!(Fleet::with_edge_models(cfg, &artifacts, &train, &wrong, 1).is_err());
    }

    #[test]
    fn with_artifacts_rejects_mismatched_data_config() {
        let sc = small_scenario();
        // artifacts built under a different fleet seed resolve to a
        // different derived data seed → key mismatch
        let artifacts = ProvisionArtifacts::build(&sc, 1, false);
        let err = Fleet::with_artifacts(
            FleetConfig {
                scenario: sc,
                seed: 2,
            },
            &artifacts,
            1,
        );
        assert!(err.is_err());
    }

    #[test]
    fn explicit_data_seed_shares_artifacts_across_sim_seeds() {
        let mut sc = small_scenario();
        sc.data_seed = Some(0xFEED);
        // same data key for different simulation seeds…
        assert_eq!(
            ProvisionArtifacts::data_key(&sc, 1),
            ProvisionArtifacts::data_key(&sc, 2)
        );
        // …and one artifact build provisions both, while the simulation
        // streams still differ
        let artifacts = ProvisionArtifacts::build(&sc, 1, false);
        let r1 = Fleet::with_artifacts(
            FleetConfig {
                scenario: sc.clone(),
                seed: 1,
            },
            &artifacts,
            1,
        )
        .unwrap()
        .run();
        let r2 = Fleet::with_artifacts(
            FleetConfig {
                scenario: sc.clone(),
                seed: 2,
            },
            &artifacts,
            1,
        )
        .unwrap()
        .run();
        assert!(!r1.bitwise_eq(&r2), "different sim seeds must differ");
        // equality against the monolithic path for the same scenario
        let direct = Fleet::new(FleetConfig {
            scenario: sc,
            seed: 2,
        })
        .unwrap()
        .run();
        assert!(direct.bitwise_eq(&r2));
    }

    #[test]
    fn pca_artifact_is_opt_in_and_sized() {
        let sc = small_scenario();
        let bare = ProvisionArtifacts::build(&sc, 3, false);
        assert!(bare.pca.is_none());
        let with = ProvisionArtifacts::build(&sc, 3, true);
        let pca = with.pca.as_ref().unwrap();
        assert_eq!(pca.components.rows, 2);
        assert_eq!(pca.components.cols, sc.synth.n_features);
        assert_eq!(pca.eigenvalues.len(), 2);
    }

    #[test]
    fn energy_books_balance() {
        let sc = small_scenario();
        let horizon = sc.horizon_s;
        let fleet = Fleet::new(FleetConfig {
            scenario: sc,
            seed: 2,
        })
        .unwrap();
        let report = fleet.run();
        for m in &report.per_edge {
            let total_time: f64 = m.state_time_s.values().sum();
            assert!(
                (total_time - horizon).abs() < 1.0,
                "state times must cover the horizon: {total_time} vs {horizon}"
            );
            // sleep-floor sanity: mean power ≥ retention, ≤ predict+BLE peak
            let p = m.mean_power_mw(horizon);
            assert!(p >= 1.33, "mean power {p}");
        }
    }

    #[test]
    fn lossy_channel_causes_skips_not_deadlock() {
        let mut sc = small_scenario();
        sc.channel = ChannelConfig {
            loss_prob: 0.4,
            max_retries: 0,
            ..Default::default()
        };
        let fleet = Fleet::new(FleetConfig {
            scenario: sc,
            seed: 3,
        })
        .unwrap();
        let report = fleet.run();
        assert!(report.channel_failures > 0);
        for m in &report.per_edge {
            assert!(m.query_failures > 0, "failures must surface per edge");
            assert!(m.trained > 0, "training still progresses");
        }
    }

    #[test]
    fn centroid_detector_triggers_training_organically() {
        let mut sc = small_scenario();
        sc.detector = DetectorKind::Centroid;
        sc.horizon_s = 400.0;
        let fleet = Fleet::new(FleetConfig {
            scenario: sc,
            seed: 4,
        })
        .unwrap();
        let report = fleet.run();
        let total_trained: u64 = report.per_edge.iter().map(|m| m.trained).sum();
        assert!(
            total_trained > 50,
            "organic detection must kick off retraining (trained {total_trained})"
        );
    }

    #[test]
    fn eval_windows_record_probe_accuracy() {
        let mut sc = small_scenario();
        sc.eval_period_s = 50.0;
        sc.eval_samples = 40;
        let fleet = Fleet::new(FleetConfig {
            scenario: sc,
            seed: 6,
        })
        .unwrap();
        let report = fleet.run();
        for m in &report.per_edge {
            // horizon 300 / period 50 → 6 windows (the last lands on the
            // horizon boundary; allow 5..=6)
            assert!(
                (5..=6).contains(&m.eval_trace.len()),
                "eval windows: {}",
                m.eval_trace.len()
            );
            // pre-drift window must score the provisioned model well
            let (t0, acc0) = m.eval_trace[0];
            assert!(t0 <= 60.0, "first window at {t0}");
            assert!(acc0 > 0.7, "provisioned probe accuracy {acc0}");
            // post-recovery window must be healthy again (loose bound:
            // probe batches are small and the subject is held-out)
            let &(_, acc_last) = m.eval_trace.last().unwrap();
            assert!(acc_last > 0.55, "final probe accuracy {acc_last}");
        }
    }

    #[test]
    fn eval_windows_do_not_perturb_simulation() {
        // The probe draws come from dedicated per-edge streams: the same
        // seed must produce the identical simulation with eval windows
        // on/off.
        let run = |eval: bool| {
            let mut sc = small_scenario();
            if eval {
                sc.eval_period_s = 50.0;
                sc.eval_samples = 16;
            }
            let r = Fleet::new(FleetConfig {
                scenario: sc,
                seed: 11,
            })
            .unwrap()
            .run();
            (
                r.total_queries(),
                r.per_edge.iter().map(|m| m.trained).collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn eval_power_flag_books_probe_energy() {
        let run = |costed: bool| {
            let mut sc = small_scenario();
            sc.eval_period_s = 50.0;
            sc.eval_samples = 32;
            sc.eval_costs_power = costed;
            Fleet::new(FleetConfig {
                scenario: sc,
                seed: 8,
            })
            .unwrap()
            .run()
        };
        let free = run(false);
        let costed = run(true);
        for (mf, mc) in free.per_edge.iter().zip(&costed.per_edge) {
            // the trajectory itself is untouched…
            assert_eq!(mf.events, mc.events);
            assert_eq!(mf.queries, mc.queries);
            assert_eq!(mf.trained, mc.trained);
            assert_eq!(mf.eval_trace.len(), mc.eval_trace.len());
            // …but the costed run books extra predict-state time/energy
            assert!(
                mc.state_time_s["predict"] > mf.state_time_s["predict"],
                "probes must add predict time"
            );
            assert!(mc.core_energy_mj > mf.core_energy_mj);
        }
    }

    #[test]
    fn eval_windows_disabled_by_default() {
        let fleet = Fleet::new(FleetConfig {
            scenario: small_scenario(),
            seed: 1,
        })
        .unwrap();
        let report = fleet.run();
        assert!(report.per_edge.iter().all(|m| m.eval_trace.is_empty()));
    }

    #[test]
    fn threaded_mode_matches_topology() {
        let sc = small_scenario();
        let counters = Fleet::run_threaded(&sc, 5, 300).unwrap();
        assert_eq!(counters.len(), 3);
        for (queries, trained) in counters {
            assert!(queries > 0, "threaded edges must query");
            assert!(trained > 0);
        }
    }
}
