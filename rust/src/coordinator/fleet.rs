//! Deterministic discrete-event fleet simulator — Figure 2(a) at system
//! scale: one teacher, many edges, a lossy BLE channel, virtual time,
//! full energy accounting via the [`crate::hw`] models.
//!
//! Each edge senses one sample per `event_period_s` (phases staggered so
//! the teacher sees interleaved load). A scripted drift moment switches
//! every edge's sampling distribution from its in-distribution subject to
//! a held-out subject (the paper's deployment story). Detection is either
//! scripted (oracle) or organic (centroid detector). Queries ride the
//! channel with latency/loss/retry; teacher replies complete the edge's
//! pending training step.
//!
//! `run()` is a single-threaded binary-heap event loop (exactly
//! reproducible); `run_threaded()` drives real edge/teacher threads over
//! std mpsc channels for the live-system flavour (tokio is not available
//! offline — see DESIGN.md §9).

use super::channel::{Channel, ChannelConfig};
use super::edge::{EdgeConfig, EdgeDevice, Mode, StepAction};
use super::metrics::{EdgeMetrics, FleetReport};
use super::teacher::Teacher;
use crate::data::synth::{SynthConfig, SynthHar};
use crate::data::{Standardizer, HELD_OUT_SUBJECTS};
use crate::drift::{CentroidDetector, DriftDetector, OracleDetector};
use crate::hw::{CycleModel, PowerModel, PowerState};
use crate::linalg::Mat;
use crate::odl::{AlphaKind, OsElmConfig};
use crate::pruning::{Metric, Pruner, ThetaPolicy};
use anyhow::Result;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Drift-detector selection for the scenario.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DetectorKind {
    /// Scripted: the fleet flips edges into training mode at the drift moment.
    Oracle,
    /// Organic: the centroid detector must notice the shift by itself.
    Centroid,
}

/// Fleet scenario description.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub n_edges: usize,
    pub n_hidden: usize,
    pub event_period_s: f64,
    pub horizon_s: f64,
    /// Virtual time at which the data distribution shifts.
    pub drift_at_s: f64,
    pub detector: DetectorKind,
    /// θ policy: None = auto ladder, Some(t) = fixed.
    pub fixed_theta: Option<f32>,
    pub teacher_error: f64,
    pub channel: ChannelConfig,
    pub synth: SynthConfig,
    /// Training-phase length (IsTrainDone target).
    pub train_target: usize,
    /// Periodic evaluation window: every `eval_period_s` of virtual time,
    /// each edge's model is evaluated on a fresh probe batch drawn from
    /// its *current* distribution via the batched predict path
    /// (`OsElm::accuracy`). 0 disables (the default — evaluation windows
    /// are telemetry, not part of the paper's protocol).
    pub eval_period_s: f64,
    /// Probe-batch size per edge per evaluation window.
    pub eval_samples: usize,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            n_edges: 4,
            n_hidden: 128,
            event_period_s: 1.0,
            horizon_s: 600.0,
            drift_at_s: 120.0,
            detector: DetectorKind::Oracle,
            fixed_theta: None,
            teacher_error: 0.0,
            channel: ChannelConfig::default(),
            synth: SynthConfig::default(),
            train_target: 400,
            eval_period_s: 0.0,
            eval_samples: 64,
        }
    }
}

/// Fleet configuration = scenario + seed.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub scenario: Scenario,
    pub seed: u64,
}

#[derive(Debug)]
enum Event {
    /// Edge senses a sample.
    Sense { edge: usize },
    /// Teacher reply lands at the edge.
    Reply { edge: usize, label: usize },
    /// Channel gave up on the query.
    QueryFailed { edge: usize },
    /// Scripted drift moment.
    Drift,
    /// Periodic fleet-wide evaluation window (batched probe accuracy).
    Eval,
}

struct Scheduled {
    at: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by (time, seq) through reversal
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The simulator.
pub struct Fleet {
    pub cfg: FleetConfig,
    edges: Vec<EdgeDevice>,
    metrics: Vec<EdgeMetrics>,
    teacher: Teacher,
    channel: Channel,
    generator: SynthHar,
    standardizer: Standardizer,
    /// Per-edge (pre-drift subject, post-drift subject).
    edge_subjects: Vec<(usize, usize)>,
    drifted: bool,
    rng: crate::util::rng::Rng64,
    /// Dedicated stream for evaluation-window probe draws, so enabling
    /// the (telemetry-only) eval windows does not perturb the simulation
    /// trajectory of the main `rng` for a given seed.
    eval_rng: crate::util::rng::Rng64,
    power: PowerModel,
    cycles: CycleModel,
    queue: BinaryHeap<Scheduled>,
    seq: u64,
    now: f64,
    /// Buffered true label for each edge's in-flight query.
    pending_truth: Vec<Option<usize>>,
}

impl Fleet {
    pub fn new(cfg: FleetConfig) -> Result<Fleet> {
        let sc = &cfg.scenario;
        let mut rng = crate::util::rng::Rng64::new(cfg.seed);
        let mut data_rng = crate::util::rng::Rng64::new(cfg.seed ^ 0xDA7A);
        let generator = SynthHar::new(sc.synth.clone(), &mut data_rng);

        // Provisioning pool: in-distribution subjects only.
        let pool = generator.generate(&mut data_rng);
        let in_dist = pool.filter(|_, s| !HELD_OUT_SUBJECTS.contains(&s));
        let standardizer = Standardizer::fit(&in_dist.xs);
        let mut train = in_dist;
        standardizer.apply(&mut train.xs);
        train.shuffle(&mut rng);

        let in_subjects: Vec<usize> = (1..=sc.synth.n_subjects)
            .filter(|s| !HELD_OUT_SUBJECTS.contains(s))
            .collect();

        let mut edges = Vec::with_capacity(sc.n_edges);
        let mut edge_subjects = Vec::with_capacity(sc.n_edges);
        for id in 0..sc.n_edges {
            let model = OsElmConfig {
                n_in: sc.synth.n_features,
                n_hidden: sc.n_hidden,
                n_out: sc.synth.n_classes,
                alpha: AlphaKind::Hash,
                ..Default::default()
            };
            let policy = match sc.fixed_theta {
                Some(t) => ThetaPolicy::Fixed(t),
                None => ThetaPolicy::auto(),
            };
            let detector: Box<dyn DriftDetector + Send> = match sc.detector {
                DetectorKind::Oracle => Box::new(OracleDetector::new()),
                DetectorKind::Centroid => {
                    Box::new(CentroidDetector::new(sc.synth.n_features))
                }
            };
            let warmup = crate::pruning::warmup_for(sc.n_hidden).min(sc.train_target / 2);
            let mut edge = EdgeDevice::new(
                id,
                EdgeConfig {
                    model,
                    hash_seed: (cfg.seed as u16).wrapping_add(id as u16 * 31),
                    pruner: Pruner::new(policy, Metric::P1P2, warmup),
                    detector,
                    train_target: sc.train_target,
                },
                &mut rng,
            );
            edge.provision(&train.xs, &train.labels)?;
            let pre = in_subjects[id % in_subjects.len()];
            let post = HELD_OUT_SUBJECTS[id % HELD_OUT_SUBJECTS.len()];
            edge_subjects.push((pre, post));
            edges.push(edge);
        }

        let teacher = Teacher::oracle(sc.teacher_error, cfg.seed ^ 0x7EAC);
        let channel = Channel::new(sc.channel.clone(), cfg.seed ^ 0xC4A7);

        let n_edges = sc.n_edges;
        let mut fleet = Fleet {
            edges,
            metrics: vec![EdgeMetrics::default(); n_edges],
            teacher,
            channel,
            generator,
            standardizer,
            edge_subjects,
            drifted: false,
            eval_rng: crate::util::rng::Rng64::new(cfg.seed ^ 0xE7A1),
            rng,
            power: PowerModel::default(),
            cycles: CycleModel::prototype().with_dims(
                sc.synth.n_features,
                sc.n_hidden,
                sc.synth.n_classes,
            ),
            queue: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            pending_truth: vec![None; n_edges],
            cfg,
        };
        // stagger edges across the period; schedule the drift
        for id in 0..n_edges {
            let phase =
                fleet.cfg.scenario.event_period_s * (id as f64 / n_edges.max(1) as f64);
            fleet.schedule(phase, Event::Sense { edge: id });
        }
        let drift_at = fleet.cfg.scenario.drift_at_s;
        fleet.schedule(drift_at, Event::Drift);
        let eval_period = fleet.cfg.scenario.eval_period_s;
        if eval_period > 0.0 {
            fleet.schedule(eval_period, Event::Eval);
        }
        Ok(fleet)
    }

    fn schedule(&mut self, at: f64, event: Event) {
        self.seq += 1;
        self.queue.push(Scheduled {
            at,
            seq: self.seq,
            event,
        });
    }

    /// Draw one standardized sample for `edge` from its current subject
    /// distribution using the given stream (disjoint-field helper so the
    /// sense path and the eval-probe path can use different RNGs).
    fn draw_sample(
        generator: &SynthHar,
        standardizer: &Standardizer,
        subjects: (usize, usize),
        drifted: bool,
        n_classes: usize,
        rng: &mut crate::util::rng::Rng64,
    ) -> (Vec<f32>, usize) {
        let subject = if drifted { subjects.1 } else { subjects.0 };
        let class = rng.below(n_classes);
        let mut x = generator.sample(class, subject, rng);
        // standardize like the provisioning data
        for ((v, &m), &s) in x
            .iter_mut()
            .zip(&standardizer.mean)
            .zip(&standardizer.std)
        {
            *v = (*v - m) / s;
        }
        (x, class)
    }

    fn sense_sample(&mut self, edge: usize) -> (Vec<f32>, usize) {
        Self::draw_sample(
            &self.generator,
            &self.standardizer,
            self.edge_subjects[edge],
            self.drifted,
            self.cfg.scenario.synth.n_classes,
            &mut self.rng,
        )
    }

    /// Run to the horizon; returns the report.
    pub fn run(mut self) -> FleetReport {
        let horizon = self.cfg.scenario.horizon_s;
        while let Some(Scheduled { at, event, .. }) = self.queue.pop() {
            if at > horizon {
                break;
            }
            self.now = at;
            match event {
                Event::Drift => {
                    self.drifted = true;
                    if self.cfg.scenario.detector == DetectorKind::Oracle {
                        for e in self.edges.iter_mut() {
                            e.force_training();
                        }
                    }
                }
                Event::Sense { edge } => {
                    self.handle_sense(edge);
                    let next = self.now + self.cfg.scenario.event_period_s;
                    self.schedule(next, Event::Sense { edge });
                }
                Event::Reply { edge, label } => {
                    self.edges[edge].on_label(label);
                    self.metrics[edge].trained = self.edges[edge].total_trained;
                    self.metrics[edge].record_state(
                        PowerState::Train,
                        self.cycles.train_time_s(),
                        self.power.power_mw(PowerState::Train),
                    );
                }
                Event::QueryFailed { edge } => {
                    self.edges[edge].on_query_failed();
                    self.metrics[edge].query_failures += 1;
                }
                Event::Eval => {
                    self.run_eval_window();
                    let next = self.now + self.cfg.scenario.eval_period_s;
                    self.schedule(next, Event::Eval);
                }
            }
        }
        // close the books: remaining time is sleep
        let mut report = FleetReport {
            horizon_s: horizon,
            per_edge: Vec::new(),
            teacher_queries: self.teacher.queries_served,
            channel_attempts: self.channel.total_attempts,
            channel_failures: self.channel.total_failures,
        };
        for (i, mut m) in self.metrics.into_iter().enumerate() {
            let active: f64 = m.state_time_s.values().sum();
            m.record_state(
                PowerState::Sleep,
                (horizon - active).max(0.0),
                self.power.power_mw(PowerState::Sleep),
            );
            m.queries = self.edges[i].total_queries;
            m.skips = self.edges[i].total_skips;
            m.trained = self.edges[i].total_trained;
            m.mode_switches = self.edges[i].mode_switches;
            report.per_edge.push(m);
        }
        report
    }

    /// One evaluation window: draw a probe batch per edge from its
    /// *current* sampling distribution and score it through the batched
    /// predict path (`OsElm::accuracy` — one packed-α panel sweep + one
    /// logits GEMM per block, no per-sample allocation). Telemetry only:
    /// probes don't touch the edge FSM, the pruner, the power ledger, or
    /// the main RNG stream — the same seed yields the same simulation
    /// with eval windows on or off.
    fn run_eval_window(&mut self) {
        let ns = self.cfg.scenario.eval_samples;
        if ns == 0 {
            return;
        }
        let nf = self.cfg.scenario.synth.n_features;
        let n_classes = self.cfg.scenario.synth.n_classes;
        let now = self.now;
        for edge in 0..self.edges.len() {
            let mut xs = Mat::zeros(ns, nf);
            let mut labels = Vec::with_capacity(ns);
            for r in 0..ns {
                let (x, class) = Self::draw_sample(
                    &self.generator,
                    &self.standardizer,
                    self.edge_subjects[edge],
                    self.drifted,
                    n_classes,
                    &mut self.eval_rng,
                );
                xs.row_mut(r).copy_from_slice(&x);
                labels.push(class);
            }
            let acc = self.edges[edge].model.accuracy(&xs, &labels);
            self.metrics[edge].eval_trace.push((now, acc));
        }
    }

    fn handle_sense(&mut self, edge: usize) {
        let (x, true_label) = self.sense_sample(edge);
        self.metrics[edge].events += 1;
        self.metrics[edge].record_state(
            PowerState::Predict,
            self.cycles.predict_time_s(),
            self.power.power_mw(PowerState::Predict),
        );
        let (pred, action) = self.edges[edge].on_sense(&x);
        self.metrics[edge].record_prediction(self.now, pred.class == true_label);
        if action == StepAction::QueryTeacher {
            let delivery = self.channel.transmit();
            self.metrics[edge].radio_energy_mj += delivery.energy_mj;
            if delivery.delivered {
                let label = self.teacher.respond(
                    &x,
                    true_label,
                    self.cfg.scenario.synth.n_classes,
                );
                self.pending_truth[edge] = Some(true_label);
                let at = self.now + delivery.elapsed_s + self.teacher.service_time_s;
                self.schedule(at, Event::Reply { edge, label });
            } else {
                let at = self.now + delivery.elapsed_s;
                self.schedule(at, Event::QueryFailed { edge });
            }
        }
    }

    /// Threaded live-system mode: each edge on its own thread, the teacher
    /// on another, queries over std mpsc. Event counts replace virtual
    /// time (energy bookkeeping is the event-loop mode's job; this mode
    /// demonstrates the concurrent topology works). Returns per-edge
    /// (queries, trained) counters.
    pub fn run_threaded(
        scenario: &Scenario,
        seed: u64,
        events_per_edge: usize,
    ) -> Result<Vec<(u64, u64)>> {
        use std::sync::mpsc;

        // Build the same fleet state, then split it across threads.
        let fleet = Fleet::new(FleetConfig {
            scenario: scenario.clone(),
            seed,
        })?;
        let n_classes = scenario.synth.n_classes;
        let mut teacher = fleet.teacher;

        // teacher thread: serves (edge_id, x, true_label) -> label
        type Query = (usize, Vec<f32>, usize);
        let (q_tx, q_rx) = mpsc::channel::<(Query, mpsc::Sender<usize>)>();
        let teacher_handle = std::thread::spawn(move || {
            while let Ok(((_, x, truth), reply_tx)) = q_rx.recv() {
                let label = teacher.respond(&x, truth, n_classes);
                let _ = reply_tx.send(label);
            }
        });

        let mut handles = Vec::new();
        let generator_cfg = scenario.synth.clone();
        for (id, mut edge) in fleet.edges.into_iter().enumerate() {
            let q_tx = q_tx.clone();
            let (pre, post) = fleet.edge_subjects[id];
            let mean = fleet.standardizer.mean.clone();
            let std = fleet.standardizer.std.clone();
            let synth_cfg = generator_cfg.clone();
            let drift_at = events_per_edge / 3;
            handles.push(std::thread::spawn(move || -> (u64, u64) {
                // per-thread generator (same family, thread-local stream)
                let mut rng = crate::util::rng::Rng64::new(seed ^ (id as u64 + 1));
                let mut data_rng =
                    crate::util::rng::Rng64::new(seed ^ 0xDA7A);
                let gen = SynthHar::new(synth_cfg.clone(), &mut data_rng);
                for ev in 0..events_per_edge {
                    let subject = if ev >= drift_at { post } else { pre };
                    if ev == drift_at {
                        edge.force_training();
                    }
                    let class = rng.below(synth_cfg.n_classes);
                    let mut x = gen.sample(class, subject, &mut rng);
                    for ((v, &m), &s) in x.iter_mut().zip(&mean).zip(&std) {
                        *v = (*v - m) / s;
                    }
                    let (_, action) = edge.on_sense(&x);
                    if action == StepAction::QueryTeacher {
                        let (r_tx, r_rx) = mpsc::channel();
                        q_tx.send(((id, x, class), r_tx)).expect("teacher gone");
                        let label = r_rx.recv().expect("teacher reply");
                        edge.on_label(label);
                    }
                }
                (edge.total_queries, edge.total_trained)
            }));
        }
        drop(q_tx);
        let counters: Vec<(u64, u64)> = handles
            .into_iter()
            .map(|h| h.join().expect("edge thread panicked"))
            .collect();
        teacher_handle.join().expect("teacher thread panicked");
        Ok(counters)
    }

    /// Current mode of an edge (tests).
    pub fn edge_mode(&self, id: usize) -> Mode {
        self.edges[id].mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_scenario() -> Scenario {
        Scenario {
            n_edges: 3,
            n_hidden: 32,
            event_period_s: 1.0,
            horizon_s: 300.0,
            drift_at_s: 60.0,
            train_target: 120,
            synth: SynthConfig {
                n_features: 40,
                n_classes: 4,
                n_subjects: 30,
                samples_per_cell: 10,
                proto_sigma: 1.1,
                confuse_frac: 0.04,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn fleet_runs_and_recovers() {
        let fleet = Fleet::new(FleetConfig {
            scenario: small_scenario(),
            seed: 1,
        })
        .unwrap();
        let report = fleet.run();
        assert_eq!(report.per_edge.len(), 3);
        for m in &report.per_edge {
            assert!(m.events >= 295, "events {}", m.events);
            assert!(m.queries > 0, "drift must trigger queries");
            assert!(m.trained > 0);
            // accuracy at the end must be decent again (recovery)
            let last = m.accuracy_trace.last().unwrap().1;
            assert!(last > 0.7, "final rolling accuracy {last}");
        }
        assert_eq!(report.teacher_queries, report.total_queries());
    }

    #[test]
    fn fleet_is_deterministic() {
        let run = |seed| {
            let fleet = Fleet::new(FleetConfig {
                scenario: small_scenario(),
                seed,
            })
            .unwrap();
            let r = fleet.run();
            (
                r.total_queries(),
                r.per_edge.iter().map(|m| m.trained).collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn energy_books_balance() {
        let sc = small_scenario();
        let horizon = sc.horizon_s;
        let fleet = Fleet::new(FleetConfig {
            scenario: sc,
            seed: 2,
        })
        .unwrap();
        let report = fleet.run();
        for m in &report.per_edge {
            let total_time: f64 = m.state_time_s.values().sum();
            assert!(
                (total_time - horizon).abs() < 1.0,
                "state times must cover the horizon: {total_time} vs {horizon}"
            );
            // sleep-floor sanity: mean power ≥ retention, ≤ predict+BLE peak
            let p = m.mean_power_mw(horizon);
            assert!(p >= 1.33, "mean power {p}");
        }
    }

    #[test]
    fn lossy_channel_causes_skips_not_deadlock() {
        let mut sc = small_scenario();
        sc.channel = ChannelConfig {
            loss_prob: 0.4,
            max_retries: 0,
            ..Default::default()
        };
        let fleet = Fleet::new(FleetConfig {
            scenario: sc,
            seed: 3,
        })
        .unwrap();
        let report = fleet.run();
        assert!(report.channel_failures > 0);
        for m in &report.per_edge {
            assert!(m.query_failures > 0, "failures must surface per edge");
            assert!(m.trained > 0, "training still progresses");
        }
    }

    #[test]
    fn centroid_detector_triggers_training_organically() {
        let mut sc = small_scenario();
        sc.detector = DetectorKind::Centroid;
        sc.horizon_s = 400.0;
        let fleet = Fleet::new(FleetConfig {
            scenario: sc,
            seed: 4,
        })
        .unwrap();
        let report = fleet.run();
        let total_trained: u64 = report.per_edge.iter().map(|m| m.trained).sum();
        assert!(
            total_trained > 50,
            "organic detection must kick off retraining (trained {total_trained})"
        );
    }

    #[test]
    fn eval_windows_record_probe_accuracy() {
        let mut sc = small_scenario();
        sc.eval_period_s = 50.0;
        sc.eval_samples = 40;
        let fleet = Fleet::new(FleetConfig {
            scenario: sc,
            seed: 6,
        })
        .unwrap();
        let report = fleet.run();
        for m in &report.per_edge {
            // horizon 300 / period 50 → 6 windows (the last lands on the
            // horizon boundary; allow 5..=6)
            assert!(
                (5..=6).contains(&m.eval_trace.len()),
                "eval windows: {}",
                m.eval_trace.len()
            );
            // pre-drift window must score the provisioned model well
            let (t0, acc0) = m.eval_trace[0];
            assert!(t0 <= 60.0, "first window at {t0}");
            assert!(acc0 > 0.7, "provisioned probe accuracy {acc0}");
            // post-recovery window must be healthy again (loose bound:
            // probe batches are small and the subject is held-out)
            let &(_, acc_last) = m.eval_trace.last().unwrap();
            assert!(acc_last > 0.55, "final probe accuracy {acc_last}");
        }
    }

    #[test]
    fn eval_windows_do_not_perturb_simulation() {
        // The probe draws come from a dedicated RNG stream: the same seed
        // must produce the identical simulation with eval windows on/off.
        let run = |eval: bool| {
            let mut sc = small_scenario();
            if eval {
                sc.eval_period_s = 50.0;
                sc.eval_samples = 16;
            }
            let r = Fleet::new(FleetConfig {
                scenario: sc,
                seed: 11,
            })
            .unwrap()
            .run();
            (
                r.total_queries(),
                r.per_edge.iter().map(|m| m.trained).collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn eval_windows_disabled_by_default() {
        let fleet = Fleet::new(FleetConfig {
            scenario: small_scenario(),
            seed: 1,
        })
        .unwrap();
        let report = fleet.run();
        assert!(report.per_edge.iter().all(|m| m.eval_trace.is_empty()));
    }

    #[test]
    fn threaded_mode_matches_topology() {
        let sc = small_scenario();
        let counters = Fleet::run_threaded(&sc, 5, 300).unwrap();
        assert_eq!(counters.len(), 3);
        for (queries, trained) in counters {
            assert!(queries > 0, "threaded edges must query");
            assert!(trained > 0);
        }
    }
}
