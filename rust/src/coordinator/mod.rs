//! L3 coordinator — the paper's system layer (Figure 2(a), Algorithm 1).
//!
//! A single **teacher** (mobile computer with accurate labels) serves
//! multiple **edge devices** over a lossy BLE channel. Each edge runs the
//! Algorithm-1 state machine around its tiny ODL core: sense → (predicting
//! mode: drift check → predict) / (training mode: label acquisition with
//! auto-pruning → sequential train → done check).
//!
//! [`fleet::Fleet`] is a deterministic discrete-event simulator over
//! virtual time that wires edges, channel, and teacher together and
//! accounts energy with the [`crate::hw`] models — the substrate for the
//! fleet examples and the power case study. Its event loop is sharded
//! per edge over counter-based RNG streams, so
//! [`fleet::Fleet::run_parallel`] spreads a large fleet across worker
//! threads while producing a report bitwise identical to the sequential
//! [`fleet::Fleet::run`]. [`fleet::Fleet::run_threaded`] offers a
//! std-thread real-time-flavoured mode (tokio is not in the offline
//! vendor set; the event loop is explicit instead). Construction is
//! sharded the same way ([`fleet::Fleet::new_parallel`]), and
//! [`sweep`] fans whole scenario grids over a worker pool with the
//! shared provisioning artifacts (and per-fleet shuffles, and
//! per-`(data, seed, n_hidden)` provisioned edge cores) memoized,
//! lazily built, dropped at their last-use cell, and resumable into an
//! existing results file. Grids also fan out across *processes*:
//! `odl-har sweep --shard I/N` runs an artifact-locality-aware,
//! cost-weighted slice of the grid, and `odl-har merge` recombines a
//! complete shard set into a file byte-identical to a single-process
//! run. Every in-process fan-out rides the shared deterministic executor
//! in [`crate::util::parallel`]. [`supervise`] closes the loop for
//! unattended studies: `odl-har sweep --shard auto[:N]` launches one
//! child process per shard, watches each through a byte-growth
//! heartbeat, relaunches crashed or hung children with bounded
//! exponential backoff onto the existing `--resume` path, quarantines
//! shards that exhaust their retry budget, and auto-merges when the
//! shard set completes — see `rust/RELIABILITY.md` for the fault model.
//! [`serve`] lifts the coordinator into a long-running TCP service —
//! admission control, bounded queues, read/idle deadlines, exactly-once
//! in-order event application, and a graceful drain that publishes every
//! client's OS-ELM/pruner/teacher state through the same crash-consistent
//! snapshot path; [`proto`] is its JSONL wire protocol and
//! `odl-har loadgen` its deterministic, chaos-tested edge client.

pub mod channel;
pub mod edge;
pub mod fleet;
pub mod metrics;
pub mod proto;
pub mod serve;
pub mod supervise;
pub mod sweep;
pub mod teacher;

pub use channel::{Channel, ChannelConfig};
pub use edge::{EdgeConfig, EdgeDevice, Mode, StepAction};
pub use fleet::{Fleet, FleetConfig, ProvisionArtifacts, Scenario};
pub use metrics::{EdgeMetrics, FleetAggregate, FleetReport, MetricsMode, StateTimes};
pub use proto::{DecisionAction, Request, Response};
pub use serve::{
    loadgen, serve, serve_with, LoadgenConfig, LoadgenSummary, ServeConfig, ServeSummary,
};
pub use supervise::{
    shard_out_paths, supervise, Launcher, ProcessLauncher, ShardReport, SuperviseConfig,
    SuperviseOutcome, SuperviseStatus, ThreadLauncher,
};
pub use sweep::{
    MergeOutcome, ResumeOutcome, ShardSpec, SweepOutcome, SweepPlan, SweepSpec, SweepStats,
};
pub use teacher::{Teacher, TeacherKind};
