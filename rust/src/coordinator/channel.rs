//! The BLE channel between edges and teacher: latency, loss, retry.
//!
//! §2.2: "If such a nearby teacher is not available, the queries to the
//! teacher will be retried later or skipped." The channel models a lossy
//! sporadic-connection link: each attempt takes `latency_s` (from the
//! [`crate::hw::BleModel`] transaction timing) and fails with
//! `loss_prob`; up to `max_retries` re-attempts happen back-to-back, after
//! which the query is reported failed (the edge then skips that sample).

use crate::hw::BleModel;
use crate::util::rng::Rng64;

/// Channel parameters.
#[derive(Clone, Debug)]
pub struct ChannelConfig {
    /// One-attempt round-trip latency [s].
    pub latency_s: f64,
    /// Probability an attempt fails (out of range, interference).
    pub loss_prob: f64,
    /// Retries after the first failed attempt.
    pub max_retries: u32,
}

impl Default for ChannelConfig {
    fn default() -> Self {
        Self {
            latency_s: BleModel::default().query_latency_s(),
            loss_prob: 0.0,
            max_retries: 2,
        }
    }
}

/// Outcome of one query over the channel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Delivery {
    /// Did the query (eventually) reach the teacher and return?
    pub delivered: bool,
    /// Attempts made (1 = first try succeeded).
    pub attempts: u32,
    /// Total channel occupancy time [s].
    pub elapsed_s: f64,
    /// Radio energy spent [mJ] (every attempt transmits).
    pub energy_mj: f64,
}

/// The channel: stateless aside from its RNG stream.
pub struct Channel {
    pub cfg: ChannelConfig,
    ble: BleModel,
    rng: Rng64,
    pub total_attempts: u64,
    pub total_failures: u64,
}

impl Channel {
    pub fn new(cfg: ChannelConfig, seed: u64) -> Channel {
        Channel {
            cfg,
            ble: BleModel::default(),
            rng: Rng64::new(seed),
            total_attempts: 0,
            total_failures: 0,
        }
    }

    /// Attempt a query round-trip (with retries).
    pub fn transmit(&mut self) -> Delivery {
        let mut attempts = 0u32;
        let mut elapsed = 0.0;
        let mut energy = 0.0;
        loop {
            attempts += 1;
            self.total_attempts += 1;
            elapsed += self.cfg.latency_s;
            energy += self.ble.query_energy_mj();
            if !self.rng.bernoulli(self.cfg.loss_prob) {
                return Delivery {
                    delivered: true,
                    attempts,
                    elapsed_s: elapsed,
                    energy_mj: energy,
                };
            }
            self.total_failures += 1;
            if attempts > self.cfg.max_retries {
                return Delivery {
                    delivered: false,
                    attempts,
                    elapsed_s: elapsed,
                    energy_mj: energy,
                };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_channel_delivers_first_try() {
        let mut ch = Channel::new(ChannelConfig::default(), 1);
        for _ in 0..100 {
            let d = ch.transmit();
            assert!(d.delivered);
            assert_eq!(d.attempts, 1);
        }
        assert_eq!(ch.total_failures, 0);
    }

    #[test]
    fn lossy_channel_retries_and_sometimes_fails() {
        let cfg = ChannelConfig {
            loss_prob: 0.5,
            max_retries: 1,
            ..Default::default()
        };
        let mut ch = Channel::new(cfg, 2);
        let n = 4000;
        let mut failed = 0;
        for _ in 0..n {
            let d = ch.transmit();
            assert!(d.attempts <= 2);
            if !d.delivered {
                failed += 1;
                assert_eq!(d.attempts, 2);
            }
        }
        // P(fail) = 0.5² = 0.25
        let rate = failed as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.03, "failure rate {rate}");
    }

    #[test]
    fn retries_cost_energy_and_time() {
        let cfg = ChannelConfig {
            loss_prob: 1.0, // always fails
            max_retries: 3,
            ..Default::default()
        };
        let mut ch = Channel::new(cfg.clone(), 3);
        let d = ch.transmit();
        assert!(!d.delivered);
        assert_eq!(d.attempts, 4);
        assert!((d.elapsed_s - 4.0 * cfg.latency_s).abs() < 1e-12);
        assert!(d.energy_mj > 3.0 * BleModel::default().query_energy_mj());
    }

    #[test]
    fn total_loss_fails_every_query_deterministically() {
        // loss probability 1.0: every transmit exhausts first try +
        // max_retries and fails — and two channels with the same seed
        // produce byte-identical delivery streams (bernoulli(1.0) draws
        // from the RNG on every attempt, so the stream has positions to
        // replay)
        let cfg = ChannelConfig {
            loss_prob: 1.0,
            max_retries: 2,
            ..Default::default()
        };
        let run = || {
            let mut ch = Channel::new(cfg.clone(), 19);
            (0..64).map(|_| ch.transmit()).collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed must replay the same delivery stream");
        for d in &a {
            assert!(!d.delivered);
            assert_eq!(d.attempts, 3, "first try + 2 retries, always");
        }
        let mut ch = Channel::new(cfg, 19);
        let _ = ch.transmit();
        assert_eq!(ch.total_attempts, 3);
        assert_eq!(ch.total_failures, 3);
    }

    #[test]
    fn zero_retries_is_single_shot() {
        // max_retries 0: exactly one attempt no matter the outcome
        let cfg = ChannelConfig {
            loss_prob: 1.0,
            max_retries: 0,
            ..Default::default()
        };
        let mut ch = Channel::new(cfg, 23);
        let d = ch.transmit();
        assert!(!d.delivered);
        assert_eq!(d.attempts, 1);
    }

    #[test]
    fn zero_loss_draws_but_never_fails() {
        // loss 0.0 still draws once per attempt (bernoulli(0) consumes a
        // sample), so repeated streams stay aligned — pinned here so a
        // future "optimization" that skips the draw shows up as a
        // determinism break, not a silent trajectory change
        let run = || {
            let mut ch = Channel::new(ChannelConfig::default(), 31);
            (0..128).map(|_| ch.transmit()).collect::<Vec<_>>()
        };
        let a = run();
        assert_eq!(a, run());
        assert!(a.iter().all(|d| d.delivered && d.attempts == 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ChannelConfig {
            loss_prob: 0.3,
            ..Default::default()
        };
        let run = |seed| {
            let mut ch = Channel::new(cfg.clone(), seed);
            (0..50).map(|_| ch.transmit().attempts).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
