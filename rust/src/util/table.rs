//! Plain-text table / CSV rendering for the experiment harnesses, so that
//! `odl-har table3` prints rows directly comparable to the paper's tables.

/// A simple column-aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: Some(title.to_string()),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned text block.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", c, w = width[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (header included, title omitted).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|s| esc(s))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format "mean±std" the way the paper prints accuracy cells.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{:.1}±{:.1}", mean, std)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row_strs(&["xxx", "y"]);
        let r = t.render();
        assert!(r.contains("a    bbbb"));
        assert!(r.contains("xxx  y"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_strs(&["x,y", "q\"q"]);
        let c = t.to_csv();
        assert!(c.contains("\"x,y\""));
        assert!(c.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn pm_formats_like_paper() {
        assert_eq!(pm(92.94, 0.84), "92.9±0.8");
    }
}
