//! Tiny `log`-crate backend writing to stderr with a level filter taken
//! from `ODL_LOG` (error|warn|info|debug|trace; default info).

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::Once;

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let tag = match record.level() {
                Level::Error => "E",
                Level::Warn => "W",
                Level::Info => "I",
                Level::Debug => "D",
                Level::Trace => "T",
            };
            eprintln!("[{}] {}: {}", tag, record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;
static INIT: Once = Once::new();

/// Install the logger (idempotent). Level from `ODL_LOG` env var.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("ODL_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging works");
    }
}
