//! Tiny self-contained stderr logger with a level filter taken from
//! `ODL_LOG` (error|warn|info|debug|trace; default info).
//!
//! The external `log` crate is not in the offline vendor set, so this
//! module provides the subset the repo needs directly: a process-wide
//! atomic level, an idempotent `init()`, and plain `error/warn/info/...`
//! functions (call sites format with `format!` — none of them are on a
//! hot path).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static INIT: Once = Once::new();

/// Install the level filter (idempotent). Level from `ODL_LOG` env var.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("ODL_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        MAX_LEVEL.store(level as u8, Ordering::Relaxed);
    });
}

/// Would a record at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Override the filter programmatically (embedding / tests). `init()`
/// only applies `ODL_LOG` once; this always takes effect.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Emit one record to stderr (no-op when filtered out).
pub fn log(level: Level, target: &str, msg: &str) {
    if enabled(level) {
        eprintln!("[{}] {}: {}", level.tag(), target, msg);
    }
}

pub fn error(target: &str, msg: &str) {
    log(Level::Error, target, msg);
}

pub fn warn(target: &str, msg: &str) {
    log(Level::Warn, target, msg);
}

pub fn info(target: &str, msg: &str) {
    log(Level::Info, target, msg);
}

pub fn debug(target: &str, msg: &str) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        info("logging", "logging works");
    }

    #[test]
    fn level_filter_suppresses_below_threshold() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Trace));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        // restore the default so parallel tests see the usual filter
        set_level(Level::Info);
    }
}
