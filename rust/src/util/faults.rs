//! Deterministic, replayable fault injection for the sweep stack.
//!
//! The sweep engine's byte-identity contract is only credible if it holds
//! *through* failures — torn writes, killed shard processes, worker-cell
//! panics, transient I/O errors. This module is the one switchboard those
//! failures flow through: a [`FaultPlan`] names exactly which faults fire
//! where, the engine consults it at its injection points (the results
//! sink's per-slot drain, the cell pool's per-attempt entry), and an
//! empty plan is a no-op the fault-free path never pays for beyond one
//! branch.
//!
//! Two ways to build a schedule (the CLI form is
//! `--inject-faults SEED[:SITE[,SITE…]]`):
//!
//! * **Explicit sites** — `SEED:KIND@INDEX[#SHARD],…` fires exactly the
//!   named faults. `KIND` is one of `kill` (flush the row, then abort the
//!   process — an in-process stand-in for an external SIGKILL), `tear`
//!   (write a *prefix* of the row's bytes, flush, abort — a torn write),
//!   `ioerr` (the row write returns an I/O error), `hang` (flush the row,
//!   then block forever — exercises the supervisor's heartbeat timeout),
//!   `panic` (the cell's first attempt panics; the in-pool retry heals
//!   it), and `panic2` (both attempts panic; the cell becomes a
//!   structured error row). Write faults index the stream's **slot**
//!   (0 = header, k = the slice's k-th row); panic faults index the
//!   **global cell**. `#SHARD` restricts a site to one shard of a
//!   supervised run.
//!
//!   The serve stack (`odl-har serve` / `odl-har loadgen`) adds four
//!   **network** kinds consulted per *message* instead of per write slot:
//!   `drop` (swallow the message — the peer sees silence and must retry),
//!   `delay` (hold the message briefly before sending), `close` (shut the
//!   socket instead of sending — the peer reconnects), and `garble`
//!   (corrupt the message bytes — the peer sees unparseable JSON). `kill`
//!   doubles as a network site on the loadgen side (the client process
//!   aborts at that message). For network sites, `#SHARD` selects the
//!   socket *end*: the server consults its plan bound via
//!   `for_shard(NET_SERVER)` (= `#1`), the client via
//!   `for_shard(NET_CLIENT)` (= `#2`), so one spec can fault either end.
//!
//!   The storage layer (`storage::{LocalDir, RemoteStub}`) adds three
//!   **storage** kinds consulted per backend *operation* (each backend
//!   instance numbers its puts/gets/stats/lists/deletes from 0):
//!   `sioerr` (the operation fails with a transient backend error),
//!   `stear` (an upload tears mid-transfer — the staged bytes are
//!   truncated and the commit fails, but the object namespace is
//!   untouched), and `sdelay` (the operation stalls briefly, then
//!   succeeds). All three are transient from the caller's side, so the
//!   bounded-retry wrapper (`storage::Storage`) heals them; `#SHARD`
//!   scopes them exactly like write faults.
//! * **Seeded chaos** — a bare `SEED` derives a pseudo-random schedule
//!   from [`stream_seed`]`(seed, FAULT_DOMAIN, site)`: roughly one row
//!   write in eight draws a kill/tear/ioerr, roughly one cell in
//!   eight panics on its first attempt, and roughly one storage
//!   operation in eight is `sdelay`ed (latency only — seeded chaos
//!   never draws a destructive storage fault, so convergence holds
//!   under any retry budget). The schedule is a pure function
//!   of `(seed, shard, site)` — replaying the same seed replays the same
//!   chaos, which is what makes a chaos-suite failure debuggable.
//!
//! Faults never forge bytes: a torn write is a prefix of the *correct*
//! row, a kill lands after a fully flushed row, and panics fire before
//! the cell touches any shared memo state. Recovery (resume, supervisor
//! retry) therefore always converges on the uninterrupted stream, byte
//! for byte — the property `tests/sweep_faults.rs` asserts.

use crate::util::rng::{mix64, stream_seed};
use anyhow::{bail, ensure, Context, Result};

/// Domain tag separating fault-schedule streams from every other
/// [`stream_seed`] consumer (provisioning, shuffles, channel noise).
pub const FAULT_DOMAIN: u64 = 0xFA17;

/// The shard index the serve coordinator binds its network fault plan to
/// (`FaultPlan::for_shard`): `#1` sites fire on the server's socket end.
pub const NET_SERVER: usize = 1;

/// The shard index `odl-har loadgen` binds its network fault plan to:
/// `#2` sites fire on the client's socket end.
pub const NET_CLIENT: usize = 2;

/// One injectable failure kind. See the module docs for semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Abort the process after the indexed row is fully written+flushed.
    Kill,
    /// Write a prefix of the indexed row's bytes, flush, then abort.
    Tear,
    /// Fail the indexed row's write with an I/O error.
    IoErr,
    /// Flush the indexed row, then block forever (heartbeat-timeout bait).
    Hang,
    /// Panic the indexed cell's first attempt (the retry heals it).
    Panic,
    /// Panic the indexed cell's first two attempts (becomes an error row).
    Panic2,
    /// Network: swallow the indexed message (the peer must retry).
    Drop,
    /// Network: delay the indexed message before sending it.
    Delay,
    /// Network: close the socket instead of sending (the peer reconnects).
    Close,
    /// Network: corrupt the indexed message's bytes on the wire.
    Garble,
    /// Storage: fail the indexed backend operation with a transient error.
    StorageIoErr,
    /// Storage: tear the indexed upload mid-transfer (staged bytes
    /// truncated, commit fails, object namespace untouched).
    StorageTear,
    /// Storage: stall the indexed backend operation briefly, then succeed.
    StorageDelay,
}

impl FaultKind {
    fn parse(s: &str) -> Result<FaultKind> {
        Ok(match s {
            "kill" => FaultKind::Kill,
            "tear" => FaultKind::Tear,
            "ioerr" => FaultKind::IoErr,
            "hang" => FaultKind::Hang,
            "panic" => FaultKind::Panic,
            "panic2" => FaultKind::Panic2,
            "drop" => FaultKind::Drop,
            "delay" => FaultKind::Delay,
            "close" => FaultKind::Close,
            "garble" => FaultKind::Garble,
            "sioerr" => FaultKind::StorageIoErr,
            "stear" => FaultKind::StorageTear,
            "sdelay" => FaultKind::StorageDelay,
            _ => bail!(
                "unknown fault kind '{s}' \
                 (kill|tear|ioerr|hang|panic|panic2|drop|delay|close|garble|\
                  sioerr|stear|sdelay)"
            ),
        })
    }

    fn is_write_fault(self) -> bool {
        matches!(
            self,
            FaultKind::Kill | FaultKind::Tear | FaultKind::IoErr | FaultKind::Hang
        )
    }

    fn is_net_fault(self) -> bool {
        matches!(
            self,
            FaultKind::Drop
                | FaultKind::Delay
                | FaultKind::Close
                | FaultKind::Garble
                // `kill` doubles as a network site: the loadgen client
                // aborts at that message (serve ignores it — a server
                // cannot meaningfully self-SIGKILL per message)
                | FaultKind::Kill
        )
    }

    fn is_storage_fault(self) -> bool {
        matches!(
            self,
            FaultKind::StorageIoErr | FaultKind::StorageTear | FaultKind::StorageDelay
        )
    }
}

/// One explicit fault site: `KIND@INDEX[#SHARD]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSite {
    pub kind: FaultKind,
    /// Sink slot (write faults) or global cell index (panic faults).
    pub index: usize,
    /// Restrict the site to one shard index of a supervised run; `None`
    /// fires in every shard (and in unsharded runs).
    pub shard: Option<usize>,
}

/// A deterministic fault schedule. The default (empty) plan is a no-op;
/// [`FaultPlan::parse`] builds one from the CLI grammar; call
/// [`FaultPlan::for_shard`] to bind the shard context before handing the
/// plan to a shard's engine.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    seed: u64,
    /// Bare-seed mode: derive a pseudo-random schedule instead of (not in
    /// addition to) explicit sites.
    seeded: bool,
    sites: Vec<FaultSite>,
    /// Current shard context (1-based; 0 = unsharded / unbound). Explicit
    /// `#SHARD` sites and the seeded stream both key on it.
    shard: usize,
}

impl FaultPlan {
    /// Parse the CLI grammar `SEED[:SITE[,SITE…]]` with
    /// `SITE = KIND@INDEX[#SHARD]`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let spec = spec.trim();
        let (seed_text, sites_text) = match spec.split_once(':') {
            Some((s, rest)) => (s, Some(rest)),
            None => (spec, None),
        };
        let seed: u64 = seed_text
            .trim()
            .parse()
            .with_context(|| format!("bad fault seed in '{spec}'"))?;
        let mut sites = Vec::new();
        if let Some(text) = sites_text {
            for part in text.split(',') {
                let part = part.trim();
                ensure!(!part.is_empty(), "empty fault site in '{spec}'");
                let (kind_text, rest) = part
                    .split_once('@')
                    .with_context(|| format!("fault site '{part}' wants KIND@INDEX[#SHARD]"))?;
                let kind = FaultKind::parse(kind_text.trim())?;
                let (index_text, shard) = match rest.split_once('#') {
                    Some((i, s)) => {
                        let shard: usize = s
                            .trim()
                            .parse()
                            .with_context(|| format!("bad shard in fault site '{part}'"))?;
                        ensure!(shard >= 1, "fault site shard is 1-based, got '{part}'");
                        (i, Some(shard))
                    }
                    None => (rest, None),
                };
                let index: usize = index_text
                    .trim()
                    .parse()
                    .with_context(|| format!("bad index in fault site '{part}'"))?;
                sites.push(FaultSite { kind, index, shard });
            }
            ensure!(!sites.is_empty(), "no fault sites after ':' in '{spec}'");
        }
        Ok(FaultPlan {
            seed,
            seeded: sites.is_empty(),
            sites,
            shard: 0,
        })
    }

    /// The plan rebound to shard `index` (1-based): `#SHARD`-scoped sites
    /// fire only when their shard matches, and the seeded stream keys on
    /// the shard so different shards draw different chaos.
    pub fn for_shard(&self, index: usize) -> FaultPlan {
        let mut plan = self.clone();
        plan.shard = index;
        plan
    }

    /// Whether this plan can ever fire — the engine's fast path skips all
    /// fault bookkeeping when it cannot.
    pub fn is_noop(&self) -> bool {
        !self.seeded && self.sites.is_empty()
    }

    fn site_matches(&self, site: &FaultSite) -> bool {
        match site.shard {
            None => true,
            Some(s) => s == self.shard,
        }
    }

    /// Per-site stream draw for seeded mode: a pure function of
    /// `(seed, shard, domain-offset, index)`.
    fn draw(&self, lane: u64, index: usize) -> u64 {
        mix64(stream_seed(
            self.seed,
            FAULT_DOMAIN ^ lane,
            ((self.shard as u64) << 32) | index as u64,
        ))
    }

    /// The write fault (if any) for results-stream slot `slot`, consulted
    /// by the ordered sink as each line drains. Seeded mode draws
    /// kill/tear/ioerr with probability ~1/8 per slot (never `hang`: a
    /// seeded schedule must stay recoverable without a supervisor).
    pub fn write_fault(&self, slot: usize) -> Option<FaultKind> {
        for site in &self.sites {
            if site.index == slot && site.kind.is_write_fault() && self.site_matches(site) {
                return Some(site.kind);
            }
        }
        if self.seeded {
            return match self.draw(0, slot) % 24 {
                0 => Some(FaultKind::Kill),
                1 => Some(FaultKind::Tear),
                2 => Some(FaultKind::IoErr),
                _ => None,
            };
        }
        None
    }

    /// The network fault (if any) for message `index` on the bound socket
    /// end (see [`NET_SERVER`]/[`NET_CLIENT`]) — consulted by the serve
    /// coordinator per response and by loadgen per request. Seeded mode
    /// draws drop/delay/garble/close with probability ~1/6 per message —
    /// every seeded network fault is recoverable (the protocol dedups by
    /// sequence number and both ends retry), so seeded chaos still
    /// converges on the undisturbed final state; `kill` fires only as an
    /// explicit site.
    pub fn net_fault(&self, index: usize) -> Option<FaultKind> {
        for site in &self.sites {
            if site.index == index && site.kind.is_net_fault() && self.site_matches(site) {
                return Some(site.kind);
            }
        }
        if self.seeded {
            return match self.draw(2, index) % 24 {
                0 => Some(FaultKind::Drop),
                1 => Some(FaultKind::Delay),
                2 => Some(FaultKind::Garble),
                3 => Some(FaultKind::Close),
                _ => None,
            };
        }
        None
    }

    /// The storage fault (if any) for backend operation `op` — consulted
    /// by the storage backends as each put/get/stat/list/delete begins
    /// (each backend instance numbers its operations from 0). Seeded mode
    /// draws only `sdelay` (~1 op in 8): latency never breaks
    /// convergence, whereas a seeded `sioerr`/`stear` could exhaust a
    /// small retry budget and flip the outcome of the existing pinned
    /// chaos seeds — destructive storage faults fire only as explicit
    /// sites.
    pub fn storage_fault(&self, op: usize) -> Option<FaultKind> {
        for site in &self.sites {
            if site.index == op && site.kind.is_storage_fault() && self.site_matches(site) {
                return Some(site.kind);
            }
        }
        if self.seeded && self.draw(3, op) % 8 == 0 {
            return Some(FaultKind::StorageDelay);
        }
        None
    }

    /// Whether global cell `cell` panics on `attempt` (0-based). Seeded
    /// mode panics ~1 cell in 8, first attempt only, so an unsupervised
    /// seeded run still self-heals through the in-pool retry.
    pub fn cell_panics(&self, cell: usize, attempt: usize) -> bool {
        for site in &self.sites {
            if site.index == cell && self.site_matches(site) {
                match site.kind {
                    FaultKind::Panic if attempt == 0 => return true,
                    FaultKind::Panic2 if attempt <= 1 => return true,
                    _ => {}
                }
            }
        }
        self.seeded && attempt == 0 && self.draw(1, cell) % 8 == 0
    }
}

/// Abort the process without unwinding — the injected stand-in for an
/// external SIGKILL. Nothing beyond what the caller already flushed
/// reaches the results file, which is exactly the crash surface resume
/// is specified against.
pub fn die(reason: &str) -> ! {
    eprintln!("fault-injection: {reason} — aborting process");
    std::process::abort();
}

/// Block this thread forever — bait for the supervisor's heartbeat
/// timeout (the only way out is an external kill).
pub fn hang(reason: &str) -> ! {
    eprintln!("fault-injection: {reason} — hanging");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_default_plans_are_noops() {
        assert!(FaultPlan::default().is_noop());
        assert!(FaultPlan::default().write_fault(0).is_none());
        assert!(!FaultPlan::default().cell_panics(0, 0));
        // binding a shard keeps a no-op a no-op
        assert!(FaultPlan::default().for_shard(2).is_noop());
    }

    #[test]
    fn explicit_sites_parse_and_fire_exactly_where_named() {
        let plan = FaultPlan::parse("7:kill@2,tear@5#2,panic@3,panic2@4").unwrap();
        assert!(!plan.is_noop());
        // unscoped kill fires in any shard context
        assert_eq!(plan.write_fault(2), Some(FaultKind::Kill));
        assert_eq!(plan.for_shard(1).write_fault(2), Some(FaultKind::Kill));
        assert_eq!(plan.write_fault(0), None);
        assert_eq!(plan.write_fault(3), None);
        // #2-scoped tear fires only in shard 2
        assert_eq!(plan.write_fault(5), None);
        assert_eq!(plan.for_shard(1).write_fault(5), None);
        assert_eq!(plan.for_shard(2).write_fault(5), Some(FaultKind::Tear));
        // panic fires on attempt 0 only; panic2 on attempts 0 and 1
        assert!(plan.cell_panics(3, 0));
        assert!(!plan.cell_panics(3, 1));
        assert!(plan.cell_panics(4, 0));
        assert!(plan.cell_panics(4, 1));
        assert!(!plan.cell_panics(4, 2));
        // panic sites are not write faults and vice versa
        assert_eq!(plan.write_fault(4), None);
        assert!(!plan.cell_panics(2, 0));
    }

    #[test]
    fn network_sites_parse_and_bind_to_socket_ends() {
        let plan = FaultPlan::parse("3:drop@2#1,garble@4#2,close@5,delay@6#2,kill@7#2").unwrap();
        // #1 = server end, #2 = client end
        let server = plan.for_shard(NET_SERVER);
        let client = plan.for_shard(NET_CLIENT);
        assert_eq!(server.net_fault(2), Some(FaultKind::Drop));
        assert_eq!(client.net_fault(2), None);
        assert_eq!(client.net_fault(4), Some(FaultKind::Garble));
        assert_eq!(server.net_fault(4), None);
        // unscoped sites fire on either end
        assert_eq!(server.net_fault(5), Some(FaultKind::Close));
        assert_eq!(client.net_fault(5), Some(FaultKind::Close));
        assert_eq!(client.net_fault(6), Some(FaultKind::Delay));
        // kill doubles as a client-side network site
        assert_eq!(client.net_fault(7), Some(FaultKind::Kill));
        assert_eq!(server.net_fault(7), None);
        // network kinds never leak into the write-fault path and
        // write kinds (other than kill) never leak into the net path
        assert_eq!(server.write_fault(2), None);
        let wp = FaultPlan::parse("3:tear@1,ioerr@2,hang@3").unwrap();
        for i in 1..=3 {
            assert_eq!(wp.net_fault(i), None);
        }
    }

    #[test]
    fn seeded_net_schedule_is_replayable_end_keyed_and_recoverable() {
        let plan = FaultPlan::parse("1701").unwrap();
        let server: Vec<_> = (0..96)
            .map(|i| plan.for_shard(NET_SERVER).net_fault(i))
            .collect();
        // pure function of (seed, end, index)
        assert_eq!(
            server,
            (0..96)
                .map(|i| FaultPlan::parse("1701").unwrap().for_shard(NET_SERVER).net_fault(i))
                .collect::<Vec<_>>()
        );
        // chaos fires somewhere, and the two ends draw different streams
        assert!(server.iter().any(|f| f.is_some()));
        let client: Vec<_> = (0..96)
            .map(|i| plan.for_shard(NET_CLIENT).net_fault(i))
            .collect();
        assert_ne!(server, client);
        // seeded mode only draws recoverable kinds — never kill
        for f in server.iter().chain(client.iter()).flatten() {
            assert!(
                matches!(
                    f,
                    FaultKind::Drop | FaultKind::Delay | FaultKind::Garble | FaultKind::Close
                ),
                "seeded net fault drew unrecoverable {f:?}"
            );
        }
    }

    #[test]
    fn storage_sites_parse_fire_and_stay_in_their_lane() {
        let plan = FaultPlan::parse("11:sioerr@0,stear@2#2,sdelay@3").unwrap();
        assert_eq!(plan.storage_fault(0), Some(FaultKind::StorageIoErr));
        assert_eq!(plan.storage_fault(1), None);
        assert_eq!(plan.storage_fault(3), Some(FaultKind::StorageDelay));
        // #2-scoped tear fires only when the plan is bound to shard 2
        assert_eq!(plan.storage_fault(2), None);
        assert_eq!(plan.for_shard(1).storage_fault(2), None);
        assert_eq!(plan.for_shard(2).storage_fault(2), Some(FaultKind::StorageTear));
        // storage kinds never leak into the write/net/panic paths...
        for i in 0..4 {
            assert_eq!(plan.write_fault(i), None);
            assert_eq!(plan.net_fault(i), None);
            assert!(!plan.cell_panics(i, 0));
        }
        // ...and write/net kinds never leak into the storage path
        let wp = FaultPlan::parse("11:kill@0,tear@1,ioerr@2,drop@3,garble@4").unwrap();
        for i in 0..5 {
            assert_eq!(wp.storage_fault(i), None);
        }
    }

    #[test]
    fn seeded_storage_schedule_is_replayable_delay_only_and_shard_keyed() {
        let plan = FaultPlan::parse("1701").unwrap();
        let schedule: Vec<_> = (0..96).map(|op| plan.storage_fault(op)).collect();
        // pure function of (seed, shard, op)
        assert_eq!(
            schedule,
            (0..96)
                .map(|op| FaultPlan::parse("1701").unwrap().storage_fault(op))
                .collect::<Vec<_>>()
        );
        // chaos fires somewhere, but only as latency — a seeded schedule
        // must never break storage convergence under any retry budget
        assert!(schedule.iter().any(|f| f.is_some()));
        for f in schedule.iter().flatten() {
            assert_eq!(*f, FaultKind::StorageDelay, "seeded storage fault must be delay-only");
        }
        // different shards draw different storage chaos
        let other: Vec<_> = (0..96).map(|op| plan.for_shard(2).storage_fault(op)).collect();
        assert_ne!(schedule, other);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("x").is_err());
        assert!(FaultPlan::parse("7:").is_err());
        assert!(FaultPlan::parse("7:boom@1").is_err());
        assert!(FaultPlan::parse("7:kill").is_err());
        assert!(FaultPlan::parse("7:kill@x").is_err());
        assert!(FaultPlan::parse("7:kill@1#0").is_err());
        assert!(FaultPlan::parse("7:kill@1,").is_err());
    }

    #[test]
    fn seeded_schedules_are_replayable_and_shard_keyed() {
        let plan = FaultPlan::parse("1701").unwrap();
        assert!(!plan.is_noop());
        let schedule: Vec<Option<FaultKind>> = (0..64).map(|s| plan.write_fault(s)).collect();
        // pure function of (seed, shard, slot): replays identically
        assert_eq!(
            schedule,
            (0..64)
                .map(|s| FaultPlan::parse("1701").unwrap().write_fault(s))
                .collect::<Vec<_>>()
        );
        // a fault actually fires somewhere in a 64-slot window, and a
        // different shard draws a different schedule
        assert!(schedule.iter().any(|f| f.is_some()));
        let other: Vec<Option<FaultKind>> =
            (0..64).map(|s| plan.for_shard(2).write_fault(s)).collect();
        assert_ne!(schedule, other);
        // seeded panics are first-attempt only (self-healing)
        let panicky = (0..64).find(|&c| plan.cell_panics(c, 0));
        assert!(panicky.is_some());
        assert!(!plan.cell_panics(panicky.unwrap(), 1));
        // seeded mode never draws a hang
        assert!(schedule.iter().all(|f| *f != Some(FaultKind::Hang)));
    }
}
