//! Running statistics (Welford) and small helpers used by every experiment
//! harness to report the paper's "mean ± std over 20 trials" rows.

/// Numerically stable running mean / variance (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (what the paper's ±std over trials reads as).
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n−1 denominator).
    pub fn sample_var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn sample_std(&self) -> f64 {
        self.sample_var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel trials).
    pub fn merge(&mut self, other: &RunningStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Convenience: mean and population std of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let mut s = RunningStats::new();
    for &x in xs {
        s.push(x);
    }
    (s.mean(), s.std())
}

/// Argmax over a slice of floats; first index wins ties. Panics on empty.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices and values of the top-2 entries (p1 ≥ p2). Panics if len < 2.
pub fn top2(xs: &[f32]) -> ((usize, f32), (usize, f32)) {
    assert!(xs.len() >= 2, "top2 needs at least 2 entries");
    let (mut i1, mut i2) = if xs[0] >= xs[1] { (0, 1) } else { (1, 0) };
    for (i, &x) in xs.iter().enumerate().skip(2) {
        if x > xs[i1] {
            i2 = i1;
            i1 = i;
        } else if x > xs[i2] {
            i2 = i;
        }
    }
    ((i1, xs[i1]), (i2, xs[i2]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.5, -2.0];
        let mut s = RunningStats::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min(), -2.0);
        assert_eq!(s.max(), 5.5);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = RunningStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = RunningStats::new();
        let mut b = RunningStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.var() - whole.var()).abs() < 1e-10);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = RunningStats::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.var(), 0.0);
    }

    #[test]
    fn argmax_first_tie_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn top2_basic() {
        let ((i1, p1), (i2, p2)) = top2(&[0.1, 0.7, 0.15, 0.05]);
        assert_eq!((i1, i2), (1, 2));
        assert!((p1 - 0.7).abs() < 1e-9 && (p2 - 0.15).abs() < 1e-9);
    }

    #[test]
    fn top2_handles_descending_and_ties() {
        let ((i1, _), (i2, _)) = top2(&[0.9, 0.9, 0.1]);
        assert_eq!((i1, i2), (0, 1));
        let ((i1, _), (i2, _)) = top2(&[0.2, 0.8]);
        assert_eq!((i1, i2), (1, 0));
    }
}
