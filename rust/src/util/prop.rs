//! A miniature property-testing harness.
//!
//! The offline vendor set does not include `proptest`, so this module
//! provides the subset this repository's tests need: seeded generators,
//! `forall`-style runners with a configurable case count, and failure
//! reporting that prints the failing case's seed and index so it can be
//! replayed deterministically (`ODL_PROP_SEED`, `ODL_PROP_CASES`).

use crate::util::rng::Rng64;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let cases = std::env::var("ODL_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("ODL_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Self { cases, seed }
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with a replayable
/// report on the first failure (either a `false` return or a panic inside
/// the property).
pub fn forall<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng64) -> T,
    P: Fn(&T) -> bool + std::panic::RefUnwindSafe,
    T: std::panic::RefUnwindSafe,
{
    let cfg = Config::default();
    for case in 0..cfg.cases {
        let mut rng = Rng64::new(cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let input = gen(&mut rng);
        let outcome = std::panic::catch_unwind(|| prop(&input));
        let ok = match outcome {
            Ok(b) => b,
            Err(_) => false,
        };
        if !ok {
            panic!(
                "property '{}' failed at case {}/{} (seed {}): input = {:?}\n\
                 replay with ODL_PROP_SEED={} ODL_PROP_CASES={}",
                name,
                case,
                cfg.cases,
                cfg.seed,
                input,
                cfg.seed,
                cfg.cases
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Rng64;

    pub fn usize_in(rng: &mut Rng64, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn f32_in(rng: &mut Rng64, lo: f32, hi: f32) -> f32 {
        rng.uniform(lo as f64, hi as f64) as f32
    }

    pub fn vec_f32(rng: &mut Rng64, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| f32_in(rng, lo, hi)).collect()
    }

    pub fn vec_normal(rng: &mut Rng64, len: usize, std: f64) -> Vec<f32> {
        (0..len).map(|_| rng.normal_ms(0.0, std) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("add-commutes", |r| (r.next_f32(), r.next_f32()), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false'")]
    fn failing_property_reports() {
        forall("always-false", |r| r.next_u64(), |_| false);
    }

    #[test]
    #[should_panic(expected = "property 'panics-inside'")]
    fn panicking_property_is_caught() {
        forall("panics-inside", |r| r.next_u64(), |_| panic!("boom"));
    }
}
