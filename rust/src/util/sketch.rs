//! Streaming sketches for O(1)-memory fleet aggregates.
//!
//! The million-edge fleet engine cannot afford an [`EdgeMetrics`] row per
//! edge, so `fleet.metrics = aggregate` folds the fleet into two kinds of
//! fixed-size summaries:
//!
//! * [`Hll`] — a HyperLogLog distinct counter (p = 12, 4096 one-byte
//!   registers) for "how many distinct (class, subject) cells did the
//!   fleet visit" / "how many distinct (edge, mode) states occurred".
//!   Items are hashed with [`mix64`] — the repo's canonical avalanche
//!   mix, no `RandomState`/`HashMap` involvement — so the register file
//!   is a pure function of the inserted set. Merging is register-wise
//!   max, which makes the sketch **partition-invariant**: feeding a set
//!   through any number of per-shard sketches and merging gives bitwise
//!   the registers of one sketch fed everything, the property that lets
//!   the parallel fleet engine feed one `Hll` per worker chunk.
//! * [`QuantileSketch`] — five-marker P² estimators (Jain & Chlamtac
//!   1985) for the p50/p90/p99 of a stream, plus exact count/min/max/sum.
//!   Five `f64` markers per tracked quantile, no sample buffer; below
//!   [`SMALL_N`] observations the sketch still holds every value and
//!   answers exactly. P² is *not* mergeable — the fleet feeds it only on
//!   the single-threaded close-of-books walk (edge-id order), which is
//!   already the bitwise-determinism convention for every f64 fold in the
//!   report.
//!
//! Both sketches use only IEEE-754 `+ - * /` (plus one `ln` in the HLL
//! estimator), so the golden pins below are reproducible from the Python
//! reference implementation used to derive them.
//!
//! [`EdgeMetrics`]: crate::coordinator::metrics::EdgeMetrics

use crate::util::rng::{hash_fold, mix64};

/// HyperLogLog precision: 2^12 = 4096 registers, ~1.6 % standard error.
pub const HLL_P: u32 = 12;
/// Register count.
pub const HLL_M: usize = 1 << HLL_P;

/// Seed of [`Hll::fingerprint`]'s register fold.
const HLL_FP_SEED: u64 = 0x5E7C;

/// Deterministic HyperLogLog distinct counter. See the module docs for
/// the determinism/merge contract.
#[derive(Clone)]
pub struct Hll {
    regs: Box<[u8; HLL_M]>,
}

impl Default for Hll {
    fn default() -> Self {
        Hll::new()
    }
}

impl std::fmt::Debug for Hll {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Hll")
            .field("estimate", &self.estimate())
            .finish()
    }
}

impl Hll {
    pub fn new() -> Hll {
        Hll {
            regs: Box::new([0u8; HLL_M]),
        }
    }

    /// Insert one item (callers encode their key into a `u64`; equal
    /// items must encode equally).
    pub fn insert(&mut self, item: u64) {
        let h = mix64(item);
        let idx = (h >> (64 - HLL_P)) as usize;
        // rank = leading zeros of the remaining 52 bits, plus one
        let rest = h << HLL_P;
        let rank = (rest.leading_zeros().min(64 - HLL_P) + 1) as u8;
        if rank > self.regs[idx] {
            self.regs[idx] = rank;
        }
    }

    /// Register-wise max merge. Exactly the registers a single sketch fed
    /// the union would hold — partition- and order-invariant.
    pub fn merge(&mut self, other: &Hll) {
        for (a, &b) in self.regs.iter_mut().zip(other.regs.iter()) {
            if b > *a {
                *a = b;
            }
        }
    }

    /// Distinct-count estimate: the harmonic-mean HLL estimator with the
    /// standard linear-counting correction for the small range. The sum
    /// walks registers in index order and every `2^-r` term is an exact
    /// power of two, so the estimate is deterministic for a given
    /// register file (the one `ln` call is the only libm dependence).
    pub fn estimate(&self) -> f64 {
        let m = HLL_M as f64;
        let mut sum = 0.0;
        let mut zeros = 0u32;
        for &r in self.regs.iter() {
            // exact 2^-r via exponent-field construction (r <= 53)
            sum += f64::from_bits((1023 - r as u64) << 52);
            if r == 0 {
                zeros += 1;
            }
        }
        let alpha = 0.7213 / (1.0 + 1.079 / m);
        let raw = alpha * m * m / sum;
        if raw <= 2.5 * m && zeros > 0 {
            m * (m / zeros as f64).ln()
        } else {
            raw
        }
    }

    /// Content hash of the register file ([`hash_fold`] in index order) —
    /// the golden-pin handle: ln-free, so it is bit-exact across libms.
    pub fn fingerprint(&self) -> u64 {
        self.regs
            .iter()
            .fold(HLL_FP_SEED, |acc, &r| hash_fold(acc, r as u64))
    }

    pub fn bitwise_eq(&self, o: &Hll) -> bool {
        self.regs[..] == o.regs[..]
    }
}

/// The quantiles every [`QuantileSketch`] tracks, in marker order.
pub const QUANTILE_TARGETS: [f64; 3] = [0.5, 0.9, 0.99];

/// Below this many observations the sketch holds the values themselves
/// and answers exactly (P² needs five samples to seed its markers).
pub const SMALL_N: usize = 5;

/// One five-marker P² estimator for a single target quantile.
#[derive(Clone, Copy, Debug)]
struct P2 {
    q: f64,
    /// Marker heights; `heights[2]` is the running quantile estimate.
    heights: [f64; 5],
    /// Marker positions (integral, kept as f64 like the paper).
    pos: [f64; 5],
    /// Desired marker positions.
    desired: [f64; 5],
}

impl P2 {
    /// Seed the markers from the first five observations.
    fn new(q: f64, first5: &[f64; 5]) -> P2 {
        let mut heights = *first5;
        heights.sort_by(|a, b| a.partial_cmp(b).expect("finite sketch sample"));
        P2 {
            q,
            heights,
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
        }
    }

    fn insert(&mut self, x: f64) {
        let (h, pos) = (&mut self.heights, &mut self.pos);
        // locate the cell k with h[k] <= x < h[k+1], extending the ends
        let k = if x < h[0] {
            h[0] = x;
            0
        } else if x >= h[4] {
            if x > h[4] {
                h[4] = x;
            }
            3
        } else {
            let mut k = 0;
            for i in 0..4 {
                if x >= h[i] {
                    k = i;
                }
            }
            k
        };
        for p in pos.iter_mut().skip(k + 1) {
            *p += 1.0;
        }
        let inc = [0.0, self.q / 2.0, self.q, (1.0 + self.q) / 2.0, 1.0];
        for (dst, step) in self.desired.iter_mut().zip(inc) {
            *dst += step;
        }
        // nudge the three interior markers toward their desired positions
        for i in 1..4 {
            let d = self.desired[i] - pos[i];
            if (d >= 1.0 && pos[i + 1] - pos[i] > 1.0)
                || (d <= -1.0 && pos[i - 1] - pos[i] < -1.0)
            {
                let d = if d > 0.0 { 1.0 } else { -1.0 };
                // piecewise-parabolic prediction, linear fallback when it
                // would leave the bracketing heights
                let qp = h[i]
                    + d / (pos[i + 1] - pos[i - 1])
                        * ((pos[i] - pos[i - 1] + d) * (h[i + 1] - h[i])
                            / (pos[i + 1] - pos[i])
                            + (pos[i + 1] - pos[i] - d) * (h[i] - h[i - 1])
                                / (pos[i] - pos[i - 1]));
                h[i] = if h[i - 1] < qp && qp < h[i + 1] {
                    qp
                } else {
                    let j = if d > 0.0 { i + 1 } else { i - 1 };
                    h[i] + d * (h[j] - h[i]) / (pos[j] - pos[i])
                };
                pos[i] += d;
            }
        }
    }
}

/// Fixed-size quantile sketch: exact count/min/max/sum plus one [`P2`]
/// estimator per [`QUANTILE_TARGETS`] entry. Feed order matters (P² is a
/// streaming recurrence), so callers that need determinism must feed in
/// a canonical order — the fleet feeds it on the single-threaded
/// close-of-books walk in edge-id order.
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    count: u64,
    min: f64,
    max: f64,
    sum: f64,
    /// The first [`SMALL_N`] observations in arrival order (marker seed
    /// for P², exact answers below SMALL_N).
    first: [f64; SMALL_N],
    cells: Option<[P2; 3]>,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        QuantileSketch::new()
    }
}

impl QuantileSketch {
    pub fn new() -> QuantileSketch {
        QuantileSketch {
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
            first: [0.0; SMALL_N],
            cells: None,
        }
    }

    pub fn insert(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if self.count as usize <= SMALL_N {
            self.first[self.count as usize - 1] = x;
            if self.count as usize == SMALL_N {
                self.cells = Some([
                    P2::new(QUANTILE_TARGETS[0], &self.first),
                    P2::new(QUANTILE_TARGETS[1], &self.first),
                    P2::new(QUANTILE_TARGETS[2], &self.first),
                ]);
            }
            return;
        }
        for cell in self.cells.as_mut().expect("cells seeded at SMALL_N").iter_mut() {
            cell.insert(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// NaN while empty.
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// NaN while empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// NaN while empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    fn target(&self, j: usize) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let n = self.count as usize;
        if n < SMALL_N {
            // exact nearest-rank answer from the retained prefix
            let mut vals = self.first;
            let vals = &mut vals[..n];
            vals.sort_by(|a, b| a.partial_cmp(b).expect("finite sketch sample"));
            let idx = (QUANTILE_TARGETS[j] * (n as f64 - 1.0)).round() as usize;
            return vals[idx.min(n - 1)];
        }
        self.cells.as_ref().expect("cells seeded at SMALL_N")[j].heights[2]
    }

    pub fn p50(&self) -> f64 {
        self.target(0)
    }

    pub fn p90(&self) -> f64 {
        self.target(1)
    }

    pub fn p99(&self) -> f64 {
        self.target(2)
    }

    /// Bitwise equality of the full sketch state (floats by bit pattern).
    pub fn bitwise_eq(&self, o: &QuantileSketch) -> bool {
        fn feq(a: f64, b: f64) -> bool {
            a.to_bits() == b.to_bits()
        }
        let cells_eq = match (&self.cells, &o.cells) {
            (None, None) => true,
            (Some(a), Some(b)) => a.iter().zip(b).all(|(x, y)| {
                feq(x.q, y.q)
                    && x.heights.iter().zip(&y.heights).all(|(p, q)| feq(*p, *q))
                    && x.pos.iter().zip(&y.pos).all(|(p, q)| feq(*p, *q))
                    && x.desired.iter().zip(&y.desired).all(|(p, q)| feq(*p, *q))
            }),
            _ => false,
        };
        self.count == o.count
            && feq(self.min, o.min)
            && feq(self.max, o.max)
            && feq(self.sum, o.sum)
            && self.first.iter().zip(&o.first).all(|(a, b)| feq(*a, *b))
            && cells_eq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The deterministic test stream shared with the Python reference:
    /// `x_i = (mix64(i) >> 11) / 2^53`, uniform in [0, 1).
    fn stream(i: u64) -> f64 {
        (mix64(i) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[test]
    fn hll_estimates_distinct_counts() {
        for n in [100u64, 1000, 10_000] {
            let mut h = Hll::new();
            for i in 0..n {
                h.insert(i);
            }
            // duplicates must not move anything
            let fp = h.fingerprint();
            for i in 0..n {
                h.insert(i);
            }
            assert_eq!(h.fingerprint(), fp, "duplicates moved registers at n={n}");
            let est = h.estimate();
            let err = (est - n as f64).abs() / n as f64;
            assert!(err < 0.05, "n={n} estimate={est} err={err}");
        }
    }

    #[test]
    fn hll_merge_is_union_and_partition_invariant() {
        let mut whole = Hll::new();
        for i in 0..3000u64 {
            whole.insert(i);
        }
        // any partition of the items, merged in any order, must reproduce
        // the single sketch's registers exactly
        for parts in [2usize, 3, 7] {
            let mut shards: Vec<Hll> = (0..parts).map(|_| Hll::new()).collect();
            for i in 0..3000u64 {
                shards[(i as usize) % parts].insert(i);
            }
            let mut merged = Hll::new();
            for s in shards.iter().rev() {
                merged.merge(s);
            }
            assert!(merged.bitwise_eq(&whole), "partition into {parts} diverged");
            assert_eq!(merged.fingerprint(), whole.fingerprint());
        }
        // overlapping shards are a union, not a sum
        let mut a = Hll::new();
        let mut b = Hll::new();
        for i in 0..2000u64 {
            a.insert(i);
        }
        for i in 1000..3000u64 {
            b.insert(i);
        }
        a.merge(&b);
        assert!(a.bitwise_eq(&whole));
    }

    #[test]
    fn hll_golden_pins() {
        // pinned against the Python reference implementation (same mix64,
        // same register fold); the fingerprint is ln-free and must match
        // bit for bit, the estimate's single ln() gets an epsilon
        let mut h = Hll::new();
        for i in 0..1000u64 {
            h.insert(i);
        }
        assert_eq!(h.fingerprint(), 0x1C13_527E_E6A2_0A45);
        let est = h.estimate();
        assert!(
            (est - 1011.1388792075297).abs() < 1e-6,
            "estimate moved: {est}"
        );
        // the small range rides the linear-counting branch
        let mut small = Hll::new();
        for i in 0..100u64 {
            small.insert(i);
        }
        let est = small.estimate();
        assert!(
            (est - 101.24094239088463).abs() < 1e-6,
            "linear-counting estimate moved: {est}"
        );
        // empty sketch: every register zero → linear counting of zero
        assert_eq!(Hll::new().estimate(), 0.0);
    }

    #[test]
    fn quantile_sketch_tracks_exact_quantiles() {
        let n = 2000u64;
        let mut s = QuantileSketch::new();
        let mut vals: Vec<f64> = Vec::new();
        for i in 0..n {
            let x = stream(i);
            s.insert(x);
            vals.push(x);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(s.count(), n);
        assert_eq!(s.min(), vals[0]);
        assert_eq!(s.max(), vals[n as usize - 1]);
        for (j, q) in QUANTILE_TARGETS.iter().enumerate() {
            let exact = vals[(q * (n as f64 - 1.0)).round() as usize];
            let est = s.target(j);
            assert!(
                (est - exact).abs() < 0.02,
                "q={q} exact={exact} est={est}"
            );
        }
    }

    #[test]
    fn quantile_sketch_golden_pins() {
        // pinned against the Python reference on the shared test stream;
        // P² is pure +-*/ so the tolerance only covers association noise
        let mut s = QuantileSketch::new();
        for i in 0..2000u64 {
            s.insert(stream(i));
        }
        let pins = [
            (s.sum(), 990.8406017020923),
            (s.min(), 0.0),
            (s.max(), 0.9991968036544369),
            (s.p50(), 0.49376951274810826),
            (s.p90(), 0.8953870747218335),
            (s.p99(), 0.9909333826236507),
        ];
        for (i, (got, want)) in pins.iter().enumerate() {
            assert!(
                (got - want).abs() < 1e-9,
                "pin {i}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn quantile_sketch_is_exact_below_small_n() {
        let mut s = QuantileSketch::new();
        assert!(s.p50().is_nan());
        assert!(s.min().is_nan());
        for x in [3.0, 1.0, 2.0] {
            s.insert(x);
        }
        assert_eq!(s.count(), 3);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert_eq!(s.sum(), 6.0);
        assert_eq!(s.p50(), 2.0);
        assert_eq!(s.p99(), 3.0);
        assert_eq!(s.mean(), 2.0);
    }

    #[test]
    fn quantile_sketch_bitwise_eq_detects_divergence() {
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        for i in 0..100u64 {
            a.insert(stream(i));
            b.insert(stream(i));
        }
        assert!(a.bitwise_eq(&b));
        b.insert(0.5);
        assert!(!a.bitwise_eq(&b));
    }

    #[test]
    fn hll_rank_handles_extremes() {
        // items whose hash has a long run of leading zeros after the
        // index bits must clamp at 53 and never overflow the register
        let mut h = Hll::new();
        for i in 0..200_000u64 {
            h.insert(i);
        }
        let est = h.estimate();
        let err = (est - 200_000.0).abs() / 200_000.0;
        assert!(err < 0.05, "estimate={est} err={err}");
    }
}
