//! Minimal JSON reading/writing (serde is not in the offline vendor set).
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), the golden
//! vectors shared with the python test suite, and experiment result dumps.
//! Supports the JSON subset those files use: objects, arrays, strings,
//! numbers, booleans, null. No exotic escapes beyond \" \\ \/ \n \t \r \uXXXX.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

pub fn arr_f32(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{}': {}", s, e))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] found {:?}", other)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} found {:?}", other)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = obj(vec![
            ("name", Json::Str("predict_hash_n128".into())),
            ("n", Json::Num(561.0)),
            ("inputs", Json::Arr(vec![Json::Str("x".into())])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2], Json::Num(-300.0));
        assert_eq!(
            j.get("b").unwrap().get("c").unwrap().as_str().unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn numbers_render_cleanly() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn float_array_roundtrip() {
        let xs = [1.5f64, -0.001, 12345.0];
        let j = arr_f64(&xs);
        let back = Json::parse(&j.to_string()).unwrap();
        let got: Vec<f64> = back
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(got, xs);
    }
}
