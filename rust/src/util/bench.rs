//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Each `rust/benches/*.rs` target (`harness = false`) uses this: timed
//! closures with warmup, mean/std/min reporting, and a `ODL_BENCH_FAST=1`
//! mode for CI-speed runs. Regeneration benches also *print the paper
//! table/figure* they correspond to, so `cargo bench` reproduces the
//! evaluation end to end.

use crate::util::stats::RunningStats;
use std::time::Instant;

/// Are we in fast (CI) mode?
pub fn fast_mode() -> bool {
    std::env::var("ODL_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Trial count for experiment-regeneration benches (paper uses 20).
pub fn bench_trials() -> usize {
    if fast_mode() {
        3
    } else {
        std::env::var("ODL_BENCH_TRIALS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(20)
    }
}

/// Time `f` for `iters` iterations after `warmup` calls; print a row.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = RunningStats::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        stats.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        mean_s: stats.mean(),
        std_s: stats.std(),
        min_s: stats.min(),
        iters,
    };
    println!("{r}");
    r
}

/// One benchmark row.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub iters: usize,
}

impl BenchResult {
    /// Throughput given a per-iteration work count.
    pub fn per_sec(&self, work_per_iter: f64) -> f64 {
        work_per_iter / self.mean_s
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "bench {:<42} {:>12} ± {:<10} (min {}, n={})",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
            self.iters
        )
    }
}

/// Peak resident set size of this process in bytes, from Linux's
/// `/proc/self/status` `VmHWM` (high-water mark) line. `None` on
/// platforms or sandboxes without procfs — callers should report the
/// reading as best-effort, never gate on it being present.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Human time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s);
    }

    #[test]
    fn peak_rss_reads_plausibly_on_linux() {
        // on Linux procfs must yield a nonzero reading at least as large
        // as one page; elsewhere None is the contract
        if let Some(bytes) = peak_rss_bytes() {
            assert!(bytes >= 4096, "implausible VmHWM reading: {bytes}");
        } else {
            assert!(!cfg!(target_os = "linux"));
        }
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 µs");
        assert_eq!(fmt_time(5e-9), "5.0 ns");
    }
}
