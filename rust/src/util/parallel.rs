//! The one deterministic execution layer — every scoped-thread fan-out in
//! the stack routes through here (`OsElm::accuracy_par` shards, protocol
//! trials, fleet provisioning and the event loop, the sweep engine's cell
//! pool). One audited implementation means one place where the
//! determinism argument has to hold:
//!
//! * **Worker-count-invariant output order.** [`parallel_map`] /
//!   [`parallel_map_n`] return results in *item* order no matter how the
//!   scheduler interleaves workers — each item's slot is written by
//!   exactly one worker, and the collection walk happens on the caller's
//!   thread after every worker has joined. [`for_each_shard_mut`] splits
//!   a mutable slice into contiguous `⌈n/w⌉` chunks (the fleet's shard
//!   layout), so no item is ever touched by two workers.
//! * **Worker counts are wall-clock knobs only.** As long as the mapped
//!   function is a pure function of the item (or, for RNG-bearing tasks,
//!   of the item plus its [`parallel_map_keyed`] stream), the output is
//!   bitwise identical for every worker count — the property the fleet
//!   and sweep determinism suites assert over the shared
//!   [`WORKER_SWEEP`].
//! * **Panic propagation.** Workers run inside [`std::thread::scope`];
//!   a panicking task propagates to the caller when the scope joins, for
//!   every worker count (the single-worker path panics inline).
//! * **Scheduling.** `parallel_map*` uses a dynamic atomic cursor
//!   (work-stealing order, robust to heterogeneous task costs);
//!   `for_each_shard_mut` uses static contiguous chunks (cache-friendly
//!   for the fleet's long-running shards). Neither choice can show up in
//!   any output bit.
//! * **`auto_workers` integration.** Worker requests follow the repo
//!   convention (`0` = auto, resolved once at startup); use
//!   [`resolve_workers`] where a raw `--workers`-style request meets an
//!   item count. The executors themselves clamp to `[1, n]` and treat
//!   `0` like `1`, preserving the historical "0 workers runs inline"
//!   behaviour of the call sites they replaced.

use crate::util::auto_workers;
use crate::util::rng::{stream_seed, Rng64};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The canonical worker counts the determinism suites sweep: sequential,
/// the smallest real split, and an oversubscribed pool. Shared by the
/// in-module property tests and the fleet/sweep suites so "bitwise
/// identical for 1/2/8 workers" means the same thing everywhere.
pub const WORKER_SWEEP: [usize; 3] = [1, 2, 8];

/// Clamp an already-resolved worker request to `[1, n_items]` (`0`, like
/// the call sites this layer replaced, runs inline).
fn clamp_workers(requested: usize, n_items: usize) -> usize {
    requested.max(1).min(n_items.max(1))
}

/// Resolve a `--workers`-style request against an item count: `0` means
/// auto ([`auto_workers`] → `available_parallelism`), then clamp to
/// `[1, n_items]`.
pub fn resolve_workers(requested: usize, n_items: usize) -> usize {
    clamp_workers(auto_workers(requested), n_items)
}

/// Ordered parallel map over indices `0..n`: spread `f(i)` over up to
/// `workers` scoped threads (dynamic scheduling) and return the results
/// in index order. The output is independent of the worker count and of
/// scheduling; a panicking `f` propagates to the caller.
pub fn parallel_map_n<R, F>(workers: usize, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let workers = clamp_workers(workers, n);
    if workers <= 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    // every slot is written by exactly one worker (the one that claimed
    // its index off the cursor); the Mutex is the cheap safe idiom for
    // "disjoint writes, collected after the join"
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let r = f(i);
                *slots[i].lock().expect("parallel_map slot poisoned") = Some(r);
            });
        }
        // scope join: a panicked worker re-raises here, before any slot
        // is read
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("parallel_map slot poisoned")
                .expect("parallel_map item never ran")
        })
        .collect()
}

/// [`parallel_map_n`] with per-item panic isolation: each `f(i)` runs
/// under [`std::panic::catch_unwind`], so one panicking item yields an
/// `Err` in *its* slot while every other item still completes and the
/// pool survives. This is the sweep engine's cell executor — a worker
/// panic (injected or real) must become that cell's structured error
/// row, not a poisoned pool that takes the whole shard down. Ordering
/// and worker-count invariance are exactly [`parallel_map_n`]'s; `f` is
/// wrapped in `AssertUnwindSafe` (the callers' shared state is
/// lock-guarded, and a panicked item's result is never read).
pub fn parallel_map_n_caught<R, F>(workers: usize, n: usize, f: F) -> Vec<std::thread::Result<R>>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    parallel_map_n(workers, n, |i| {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)))
    })
}

/// Best-effort human-readable message from a caught panic payload
/// (`&str` and `String` payloads — `panic!` produces these — are
/// extracted; anything else gets a placeholder).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Ordered parallel map over a slice: `f(index, &item)` with the results
/// in item order for every worker count.
pub fn parallel_map<T, R, F>(workers: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    parallel_map_n(workers, items.len(), |i| f(i, &items[i]))
}

/// [`parallel_map_n`] for RNG-bearing tasks: item `i` receives a private
/// `Rng64` on the `stream_seed(seed, domain, i)` stream — keyed by the
/// *item index*, never the worker — so a task may draw randomness and the
/// output stays worker-count invariant. This is the fleet's per-edge
/// provisioning-stream convention, lifted into the executor.
pub fn parallel_map_keyed<R, F>(workers: usize, n: usize, seed: u64, domain: u64, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut Rng64) -> R + Sync,
{
    parallel_map_n(workers, n, |i| {
        let mut rng = Rng64::new(stream_seed(seed, domain, i as u64));
        f(i, &mut rng)
    })
}

/// Chunked shard executor: split `items` into contiguous `⌈n/workers⌉`
/// chunks, one scoped thread per chunk, and run `f(&mut item)` on every
/// item. Each item is visited exactly once by exactly one worker; within
/// a chunk, items run in slice order. This is the fleet event loop's
/// shard layout (long-running stateful shards want contiguity, not
/// work-stealing).
pub fn for_each_shard_mut<T, F>(workers: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let n = items.len();
    let workers = clamp_workers(workers, n);
    if workers <= 1 {
        for item in items.iter_mut() {
            f(item);
        }
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for shard in items.chunks_mut(chunk) {
            let f = &f;
            scope.spawn(move || {
                for item in shard.iter_mut() {
                    f(item);
                }
            });
        }
    });
}

/// [`for_each_shard_mut`] with a per-chunk return value: split `items`
/// into the same contiguous `⌈n/workers⌉` chunks, run `f(chunk_index,
/// &mut chunk)` on one scoped thread per chunk, and return the results in
/// chunk order. This is the fleet engine's wheel-per-shard layout — each
/// worker runs one time wheel over its whole chunk and hands back that
/// shard's O(1) sketch state, merged on the caller's thread in chunk
/// order. A panicking chunk re-raises on the caller (join in spawn
/// order + `resume_unwind`), matching [`for_each_shard_mut`].
pub fn map_shard_chunks<T, R, F>(workers: usize, items: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut [T]) -> R + Sync,
{
    let n = items.len();
    let workers = clamp_workers(workers, n);
    if workers <= 1 {
        return vec![f(0, items)];
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        let handles: Vec<_> = items
            .chunks_mut(chunk)
            .enumerate()
            .map(|(idx, shard)| {
                let f = &f;
                scope.spawn(move || f(idx, shard))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(payload) => std::panic::resume_unwind(payload),
            })
            .collect()
    })
}

/// Assert `f` produces an identical output vector under every
/// [`WORKER_SWEEP`] worker count. (The fleet/sweep determinism suites
/// compare whole `FleetReport`s via `bitwise_eq` and share only
/// [`WORKER_SWEEP`]; this `PartialEq` flavour serves the in-module
/// property tests, so it is test-gated rather than shipped.)
#[cfg(test)]
fn assert_worker_invariant<T, R, F>(items: &[T], f: F)
where
    T: Sync,
    R: Send + PartialEq + std::fmt::Debug,
    F: Fn(usize, &T) -> R + Sync,
{
    let reference = parallel_map(WORKER_SWEEP[0], items, &f);
    for &workers in &WORKER_SWEEP[1..] {
        let got = parallel_map(workers, items, &f);
        assert_eq!(
            reference, got,
            "parallel_map output changed at {workers} workers"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_is_ordered_for_every_worker_count_and_boundary_size() {
        // sizes straddling chunk/cursor boundaries: empty, single, around
        // the 8-worker split, exact multiples, off-by-one
        for n in [0usize, 1, 2, 3, 7, 8, 9, 16, 17, 64] {
            let items: Vec<usize> = (0..n).collect();
            let expect: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
            for w in [0usize, 1, 2, 3, 8, 64] {
                let got = parallel_map(w, &items, |_, &x| x * x + 1);
                assert_eq!(got, expect, "n={n} workers={w}");
            }
        }
    }

    #[test]
    fn empty_input_runs_nothing() {
        let got: Vec<u32> = parallel_map_n(8, 0, |_| unreachable!());
        assert!(got.is_empty());
        let mut items: Vec<u32> = Vec::new();
        for_each_shard_mut(8, &mut items, |_| unreachable!());
    }

    #[test]
    fn worker_invariance_helper_covers_the_canonical_sweep() {
        assert_eq!(WORKER_SWEEP, [1, 2, 8]);
        let items: Vec<u64> = (0..100).collect();
        assert_worker_invariant(&items, |i, &x| x.wrapping_mul(0x9E37) ^ i as u64);
    }

    #[test]
    fn keyed_streams_depend_on_index_not_worker() {
        let draw = |w: usize| parallel_map_keyed(w, 16, 42, 0x7E57, |_, rng| rng.next_u64());
        let reference = draw(1);
        for &w in &WORKER_SWEEP[1..] {
            assert_eq!(reference, draw(w), "keyed stream moved at {w} workers");
        }
        // the stream really is (seed, domain, index)-keyed
        let mut direct = Rng64::new(stream_seed(42, 0x7E57, 3));
        assert_eq!(reference[3], direct.next_u64());
    }

    #[test]
    fn shard_mut_touches_every_item_exactly_once() {
        for n in [0usize, 1, 5, 8, 9, 17] {
            for w in [1usize, 2, 3, 8, 32] {
                let mut items = vec![0u32; n];
                for_each_shard_mut(w, &mut items, |x| *x += 1);
                assert!(items.iter().all(|&x| x == 1), "n={n} w={w}");
            }
        }
    }

    #[test]
    fn shard_chunk_map_visits_every_item_and_orders_results() {
        for n in [0usize, 1, 5, 8, 9, 17] {
            for w in [1usize, 2, 3, 8, 32] {
                let mut items = vec![1u64; n];
                let sums = map_shard_chunks(w, &mut items, |idx, chunk| {
                    for x in chunk.iter_mut() {
                        *x += 1;
                    }
                    (idx, chunk.iter().sum::<u64>())
                });
                assert!(items.iter().all(|&x| x == 2), "n={n} w={w}");
                // chunk results come back in chunk order and cover n
                let total: u64 = sums.iter().map(|(_, s)| s).sum();
                assert_eq!(total, 2 * n as u64, "n={n} w={w}");
                for (slot, (idx, _)) in sums.iter().enumerate() {
                    assert_eq!(slot, *idx, "n={n} w={w}");
                }
            }
        }
    }

    #[test]
    fn shard_chunk_map_propagates_panics() {
        for w in [1usize, 4] {
            let caught = std::panic::catch_unwind(|| {
                let mut items = vec![0u32; 8];
                map_shard_chunks(w, &mut items, |idx, _| {
                    if idx == 0 {
                        panic!("chunk panic");
                    }
                    idx
                })
            });
            assert!(caught.is_err(), "panic must propagate at {w} workers");
        }
    }

    #[test]
    fn panics_propagate_for_every_worker_count() {
        for w in [1usize, 4] {
            let caught = std::panic::catch_unwind(|| {
                parallel_map_n(w, 8, |i| {
                    if i == 5 {
                        panic!("task panic");
                    }
                    i
                })
            });
            assert!(caught.is_err(), "panic must propagate at {w} workers");
        }
    }

    #[test]
    fn caught_map_isolates_panics_to_their_slot() {
        for w in [1usize, 2, 8] {
            let results = parallel_map_n_caught(w, 9, |i| {
                if i == 4 {
                    panic!("boom at {i}");
                }
                i * 10
            });
            assert_eq!(results.len(), 9, "workers={w}");
            for (i, r) in results.iter().enumerate() {
                if i == 4 {
                    let payload = r.as_ref().expect_err("item 4 must be caught");
                    assert_eq!(panic_message(payload.as_ref()), "boom at 4");
                } else {
                    assert_eq!(*r.as_ref().unwrap(), i * 10, "workers={w}");
                }
            }
        }
        // String payloads extract too; exotic payloads degrade gracefully
        let caught = std::panic::catch_unwind(|| std::panic::panic_any(7usize)).unwrap_err();
        assert_eq!(panic_message(caught.as_ref()), "non-string panic payload");
    }

    #[test]
    fn resolve_workers_clamps_and_autodetects() {
        assert!(resolve_workers(0, 1000) >= 1);
        assert_eq!(resolve_workers(4, 2), 2);
        assert_eq!(resolve_workers(3, 100), 3);
        assert_eq!(resolve_workers(8, 0), 1);
    }
}
