//! General-purpose substrates: deterministic RNGs, the deterministic
//! parallel executor, running statistics, tabular/JSON output, a tiny
//! logger, and an in-house property-testing harness (the offline vendor
//! set has no `proptest`).

pub mod bench;
pub mod faults;
pub mod json;
pub mod logging;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod sketch;
pub mod stats;
pub mod table;

pub use rng::{Rng64, SplitMix64};
pub use stats::{mean_std, RunningStats};

/// Resolve a worker-count request at startup: `0` means **auto** — use
/// [`std::thread::available_parallelism`]. This is the convention for
/// every `--workers` flag and TOML `workers` key (see `rust/PERF.md`);
/// worker counts are wall-clock knobs only, so auto-resolution can never
/// change a recorded report.
pub fn auto_workers(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    }
}

#[cfg(test)]
mod worker_tests {
    use super::auto_workers;

    #[test]
    fn zero_resolves_to_available_parallelism() {
        assert!(auto_workers(0) >= 1);
        assert_eq!(auto_workers(3), 3);
        assert_eq!(auto_workers(1), 1);
    }
}
