//! General-purpose substrates: deterministic RNGs, running statistics,
//! tabular/JSON output, a tiny logger, and an in-house property-testing
//! harness (the offline vendor set has no `proptest`).

pub mod bench;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::{Rng64, SplitMix64};
pub use stats::{mean_std, RunningStats};
