//! Deterministic pseudo-random number generators.
//!
//! Everything in this repository that needs randomness (the synthetic HAR
//! generator, OS-ELM initialization, trial seeding, channel loss, the
//! property-test harness) draws from these generators so that every
//! experiment is exactly reproducible from a single `u64` seed.
//!
//! The *paper's* 16-bit Xorshift (coefficients 7, 9, 8) used for ODLHash
//! weight generation lives in [`crate::odl::xorshift`]; the generators here
//! are infrastructure, not part of the reproduced system.

/// Weyl-sequence increment of SplitMix64 (2⁶⁴/φ, odd).
pub const GOLDEN_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// Odd multiplier that separates the `domain` coordinate of
/// [`stream_seed`] from the `stream` coordinate (so `(domain, stream)`
/// and `(stream, domain)` land on different keys).
const DOMAIN_MULT: u64 = 0x9FB2_1C65_1E98_DF25;

/// The SplitMix64 output finalizer: a bijective 64-bit avalanche mix.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One step of the canonical identity-hash fold used by every
/// content-addressed key in the repo (`ProvisionArtifacts::data_key`, the
/// sweep engine's `grid_hash`): a golden-ratio spread of `v` mixed into
/// the accumulator. One definition so the derivations can never drift
/// apart.
#[inline]
pub fn hash_fold(acc: u64, v: u64) -> u64 {
    mix64(acc ^ v.wrapping_mul(GOLDEN_GAMMA))
}

/// Derive the key of stream `stream` in domain `domain` under `master`:
/// three chained [`mix64`] rounds so that nearby masters, domains, and
/// stream ids (0, 1, 2, …) decorrelate fully. This is the seed schedule
/// behind every per-edge RNG stream in the fleet engine — each (edge,
/// purpose) pair owns a statistically independent stream that can be
/// created O(1) on any shard without a shared generator to contend on.
#[inline]
pub fn stream_seed(master: u64, domain: u64, stream: u64) -> u64 {
    let a = mix64(master.wrapping_add(GOLDEN_GAMMA));
    let b = mix64(a ^ domain.wrapping_mul(DOMAIN_MULT));
    mix64(b ^ stream.wrapping_mul(GOLDEN_GAMMA))
}

/// The sampling surface shared by every generator in the repository.
///
/// Implementors provide raw 64-bit draws; all derived samplers are
/// provided methods whose bodies are **verbatim** the historical `Rng64`
/// formulas, so routing a call site through the trait (e.g. the generic
/// [`crate::data::synth::SynthHar::sample`]) never changes the values an
/// `Rng64` produces for a given state.
pub trait RngStream {
    fn next_u64(&mut self) -> u64;

    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_f64() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller (second value dropped).
    fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T])
    where
        Self: Sized,
    {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Counter-based stream generator: output *i* is `mix64(key + i·γ)` for a
/// key derived by [`stream_seed`]. Unlike a stateful xorshift, the whole
/// sequence is a pure function of `(master, domain, stream, i)` — streams
/// for different edges/purposes are created independently on any worker
/// thread, draw in any interleaving, and still produce exactly the
/// sequence the single-threaded simulation sees. This is what makes the
/// fleet's parallel engine bitwise-deterministic (see
/// `coordinator::fleet`).
#[derive(Clone, Debug)]
pub struct CounterRng {
    key: u64,
    ctr: u64,
}

impl CounterRng {
    pub fn new(master: u64, domain: u64, stream: u64) -> Self {
        Self {
            key: stream_seed(master, domain, stream),
            ctr: 0,
        }
    }

    /// Number of 64-bit draws made so far.
    #[inline]
    pub fn position(&self) -> u64 {
        self.ctr
    }
}

impl RngStream for CounterRng {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.ctr = self.ctr.wrapping_add(1);
        mix64(self.key.wrapping_add(self.ctr.wrapping_mul(GOLDEN_GAMMA)))
    }
}

/// SplitMix64: used to derive independent stream seeds from a master seed.
///
/// Reference: Steele, Lea, Flood — "Fast Splittable Pseudorandom Number
/// Generators" (OOPSLA 2014). Passes BigCrush when used as a stream.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN_GAMMA);
        mix64(self.state)
    }
}

impl RngStream for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        SplitMix64::next_u64(self)
    }
}

/// xoshiro-flavoured 64-bit generator (xorshift64* core): the workhorse RNG.
#[derive(Clone, Debug)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    /// Create from a seed; a zero seed is remapped (xorshift state must be ≠ 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut state = sm.next_u64();
        if state == 0 {
            state = 0x9E37_79B9_7F4A_7C15;
        }
        Self { state }
    }

    /// The raw generator state — for crash-consistent snapshots that must
    /// resume a stream mid-sequence (e.g. the serve coordinator's
    /// per-client teacher). Round-trips exactly through
    /// [`Self::from_state`].
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator from a previously captured [`Self::state`].
    /// Unlike [`Self::new`] this is NOT a seeding function: the value is
    /// installed verbatim (zero, which a healthy stream can never reach,
    /// is remapped the same way `new` remaps it).
    pub fn from_state(state: u64) -> Self {
        Self {
            state: if state == 0 { 0x9E37_79B9_7F4A_7C15 } else { state },
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    // The samplers below delegate to the RngStream provided methods (one
    // source of truth — direct call sites and generic ones draw the same
    // values by construction); inherent wrappers are kept so the many
    // `Rng64` call sites need no trait import.

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        RngStream::next_u32(self)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        RngStream::next_f64(self)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        RngStream::next_f32(self)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        RngStream::uniform(self, lo, hi)
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        RngStream::below(self, n)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generation is not on any hot path).
    pub fn normal(&mut self) -> f64 {
        RngStream::normal(self)
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        RngStream::normal_ms(self, mean, std)
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        RngStream::bernoulli(self, p)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        RngStream::shuffle(self, xs)
    }

    /// Derive a child RNG with a distinct stream (for per-trial seeding).
    pub fn fork(&mut self, tag: u64) -> Rng64 {
        Rng64::new(self.next_u64() ^ tag.wrapping_mul(GOLDEN_GAMMA))
    }
}

impl RngStream for Rng64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        // resolves to the inherent method (inherent wins over the trait),
        // so generic call sites draw exactly the historical stream
        Rng64::next_u64(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // First outputs for seed 0 (cross-checked against the reference
        // implementation in the SplitMix64 paper).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn rng_deterministic() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seeds_differ() {
        let mut a = Rng64::new(1);
        let mut b = Rng64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng64::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely to be identity
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng64::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }

    #[test]
    fn counter_rng_stream_is_stable() {
        // Per-edge stream stability: the fleet's parallel engine relies on
        // the whole sequence being a pure function of (master, domain,
        // stream), so the key schedule and the first outputs are golden-
        // pinned (cross-checked against an independent reference
        // implementation of mix64/stream_seed). Any change here breaks
        // bitwise reproducibility of every recorded fleet run.
        assert_eq!(stream_seed(42, 1, 0), 0x3993_CB26_10D6_0FA2);
        assert_eq!(stream_seed(42, 1, 1), 0x21B9_7A3B_E8B2_1F0E);
        assert_eq!(stream_seed(42, 2, 0), 0xD124_D804_2A35_3E86);
        assert_eq!(stream_seed(7, 1, 0), 0xC77C_A3E6_A391_5E7B);
        let mut r = CounterRng::new(42, 1, 0);
        assert_eq!(r.next_u64(), 0x5872_8671_4722_995D);
        assert_eq!(r.next_u64(), 0x3288_8C35_1744_4854);
        assert_eq!(r.next_u64(), 0x557B_8DDC_7F49_83B7);
        assert_eq!(r.next_u64(), 0xE7BA_7E0D_A8A8_63AC);
        assert_eq!(r.position(), 4);
    }

    #[test]
    fn counter_rng_clone_resumes_identically() {
        // A shard may clone a stream mid-flight (e.g. report snapshots);
        // the clone must continue the exact sequence.
        let mut a = CounterRng::new(3, 9, 2);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = a.clone();
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn counter_rng_streams_disjoint() {
        // Disjointness across edge ids and domains: 4 streams × 256 draws
        // must not collide (they are distinct mix64 fibers).
        let mut seen = std::collections::HashSet::new();
        for domain in [1u64, 2] {
            for stream in 0..4u64 {
                let mut r = CounterRng::new(9, domain, stream);
                for _ in 0..256 {
                    assert!(seen.insert(r.next_u64()), "stream collision");
                }
            }
        }
    }

    #[test]
    fn counter_rng_f64_in_unit_interval() {
        let mut r = CounterRng::new(11, 0, 0);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
        for n in [1usize, 3, 10] {
            for _ in 0..100 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn trait_samplers_match_inherent_rng64() {
        // Inherent Rng64 samplers delegate to the RngStream bodies, so a
        // generic call site must draw identical values for every method
        // (this test is the tripwire should the delegation ever fork).
        fn generic_draws<R: RngStream>(r: &mut R) -> (u32, f64, f32, f64, usize, bool, f64, f64, Vec<u32>) {
            let mut xs: Vec<u32> = (0..20).collect();
            r.shuffle(&mut xs);
            (
                r.next_u32(),
                r.next_f64(),
                r.next_f32(),
                r.uniform(-2.0, 3.0),
                r.below(13),
                r.bernoulli(0.4),
                r.normal(),
                r.normal_ms(1.0, 2.0),
                xs,
            )
        }
        let mut a = Rng64::new(77);
        let mut xs: Vec<u32> = (0..20).collect();
        a.shuffle(&mut xs);
        let inherent = (
            a.next_u32(),
            a.next_f64(),
            a.next_f32(),
            a.uniform(-2.0, 3.0),
            a.below(13),
            a.bernoulli(0.4),
            a.normal(),
            a.normal_ms(1.0, 2.0),
            xs,
        );
        let mut b = Rng64::new(77);
        let via_trait = generic_draws(&mut b);
        assert_eq!(inherent.0, via_trait.0);
        assert_eq!(inherent.1.to_bits(), via_trait.1.to_bits());
        assert_eq!(inherent.2.to_bits(), via_trait.2.to_bits());
        assert_eq!(inherent.3.to_bits(), via_trait.3.to_bits());
        assert_eq!(inherent.4, via_trait.4);
        assert_eq!(inherent.5, via_trait.5);
        assert_eq!(inherent.6.to_bits(), via_trait.6.to_bits());
        assert_eq!(inherent.7.to_bits(), via_trait.7.to_bits());
        assert_eq!(inherent.8, via_trait.8);
    }
}
