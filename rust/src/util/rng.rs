//! Deterministic pseudo-random number generators.
//!
//! Everything in this repository that needs randomness (the synthetic HAR
//! generator, OS-ELM initialization, trial seeding, channel loss, the
//! property-test harness) draws from these generators so that every
//! experiment is exactly reproducible from a single `u64` seed.
//!
//! The *paper's* 16-bit Xorshift (coefficients 7, 9, 8) used for ODLHash
//! weight generation lives in [`crate::odl::xorshift`]; the generators here
//! are infrastructure, not part of the reproduced system.

/// SplitMix64: used to derive independent stream seeds from a master seed.
///
/// Reference: Steele, Lea, Flood — "Fast Splittable Pseudorandom Number
/// Generators" (OOPSLA 2014). Passes BigCrush when used as a stream.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro-flavoured 64-bit generator (xorshift64* core): the workhorse RNG.
#[derive(Clone, Debug)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    /// Create from a seed; a zero seed is remapped (xorshift state must be ≠ 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut state = sm.next_u64();
        if state == 0 {
            state = 0x9E37_79B9_7F4A_7C15;
        }
        Self { state }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free mapping is fine here (non-crypto).
        (self.next_f64() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generation is not on any hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive a child RNG with a distinct stream (for per-trial seeding).
    pub fn fork(&mut self, tag: u64) -> Rng64 {
        Rng64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // First outputs for seed 0 (cross-checked against the reference
        // implementation in the SplitMix64 paper).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn rng_deterministic() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seeds_differ() {
        let mut a = Rng64::new(1);
        let mut b = Rng64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng64::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely to be identity
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng64::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }
}
