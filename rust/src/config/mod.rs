//! Configuration system: a TOML-subset parser (serde/toml are not in the
//! offline vendor set) + typed experiment and fleet configs with presets.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! ("…"), integer, float, and boolean values, `#` comments. That covers
//! every config this repo ships (`configs/*.toml`).

pub mod toml;

use crate::coordinator::fleet::{DetectorKind, Scenario};
use crate::coordinator::ChannelConfig;
use crate::data::SynthConfig;
use crate::exp::protocol::{ProtocolConfig, PruningSpec, Variant};
use crate::odl::AlphaKind;
use anyhow::{bail, Context, Result};
use std::path::Path;
use toml::TomlDoc;

/// Typed experiment configuration (drives `odl-har run`).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub protocol: ProtocolConfig,
}

impl ExperimentConfig {
    pub fn from_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_str(&text)
    }

    pub fn from_str(text: &str) -> Result<ExperimentConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;

        let variant_name = doc.get_str("model", "variant").unwrap_or("odlhash");
        let n_hidden = doc.get_int("model", "n_hidden").unwrap_or(128) as usize;
        let variant = match variant_name.to_ascii_lowercase().as_str() {
            "odlhash" => Variant::Odl(AlphaKind::Hash),
            "odlbase" => Variant::Odl(AlphaKind::Stored),
            "noodl" => Variant::NoOdl(AlphaKind::Hash),
            "dnn" => Variant::Dnn(vec![561, 512, 256, 6]),
            other => bail!("unknown model.variant '{other}'"),
        };

        let mut cfg = ProtocolConfig::new(variant, n_hidden);
        if let Some(t) = doc.get_int("experiment", "trials") {
            cfg.trials = t as usize;
        }
        if let Some(s) = doc.get_int("experiment", "seed") {
            cfg.master_seed = s as u64;
        }
        if let Some(f) = doc.get_float("experiment", "train_frac") {
            cfg.train_frac = f;
        }
        if let Some(e) = doc.get_float("teacher", "error_rate") {
            cfg.teacher_error = e;
        }
        cfg.pruning = match doc.get_str("pruning", "mode").unwrap_or("off") {
            "off" => PruningSpec::Off,
            "fixed" => {
                let theta = doc
                    .get_float("pruning", "theta")
                    .context("pruning.mode=fixed requires pruning.theta")?;
                PruningSpec::Fixed(theta as f32)
            }
            "auto" => PruningSpec::Auto {
                x: doc.get_int("pruning", "x").unwrap_or(10) as u32,
            },
            other => bail!("unknown pruning.mode '{other}'"),
        };
        if let Some(w) = doc.get_int("pruning", "warmup") {
            cfg.warmup = Some(w as usize);
        }
        apply_synth(&mut cfg.synth, &doc)?;
        Ok(ExperimentConfig { protocol: cfg })
    }
}

fn apply_synth(synth: &mut SynthConfig, doc: &TomlDoc) -> Result<()> {
    if let Some(v) = doc.get_int("data", "n_features") {
        synth.n_features = v as usize;
    }
    if let Some(v) = doc.get_int("data", "n_classes") {
        synth.n_classes = v as usize;
    }
    if let Some(v) = doc.get_int("data", "n_subjects") {
        synth.n_subjects = v as usize;
    }
    if let Some(v) = doc.get_int("data", "samples_per_cell") {
        synth.samples_per_cell = v as usize;
    }
    if let Some(v) = doc.get_float("data", "noise_sigma") {
        synth.noise_sigma = v;
    }
    if let Some(v) = doc.get_float("data", "drift_scale") {
        synth.drift_scale = v;
    }
    Ok(())
}

/// Fleet scenario config (drives `odl-har fleet`).
pub fn fleet_from_file(path: &Path) -> Result<(Scenario, u64)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    fleet_from_str(&text)
}

pub fn fleet_from_str(text: &str) -> Result<(Scenario, u64)> {
    let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
    let mut sc = Scenario::default();
    if let Some(v) = doc.get_int("fleet", "n_edges") {
        sc.n_edges = v as usize;
    }
    if let Some(v) = doc.get_int("fleet", "n_hidden") {
        sc.n_hidden = v as usize;
    }
    if let Some(v) = doc.get_float("fleet", "event_period_s") {
        sc.event_period_s = v;
    }
    if let Some(v) = doc.get_float("fleet", "horizon_s") {
        sc.horizon_s = v;
    }
    if let Some(v) = doc.get_float("fleet", "drift_at_s") {
        sc.drift_at_s = v;
    }
    if let Some(v) = doc.get_int("fleet", "train_target") {
        sc.train_target = v as usize;
    }
    if let Some(v) = doc.get_str("fleet", "detector") {
        sc.detector = match v {
            "oracle" => DetectorKind::Oracle,
            "centroid" => DetectorKind::Centroid,
            other => bail!("unknown fleet.detector '{other}'"),
        };
    }
    if let Some(v) = doc.get_float("fleet", "eval_period_s") {
        sc.eval_period_s = v;
    }
    if let Some(v) = doc.get_int("fleet", "eval_samples") {
        sc.eval_samples = v as usize;
    }
    if let Some(v) = doc.get_bool("fleet", "eval_costs_power") {
        sc.eval_costs_power = v;
    }
    if let Some(v) = doc.get_float("pruning", "theta") {
        sc.fixed_theta = Some(v as f32);
    }
    if let Some(v) = doc.get_float("teacher", "error_rate") {
        sc.teacher_error = v;
    }
    let mut ch = ChannelConfig::default();
    if let Some(v) = doc.get_float("channel", "loss_prob") {
        ch.loss_prob = v;
    }
    if let Some(v) = doc.get_int("channel", "max_retries") {
        ch.max_retries = v as u32;
    }
    sc.channel = ch;
    apply_synth(&mut sc.synth, &doc)?;
    let seed = doc.get_int("fleet", "seed").unwrap_or(1) as u64;
    Ok((sc, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[model]
variant = "odlhash"
n_hidden = 256

[experiment]
trials = 5
seed = 99
train_frac = 0.8

[pruning]
mode = "auto"
x = 7

[teacher]
error_rate = 0.05
"#;

    #[test]
    fn experiment_config_parses() {
        let cfg = ExperimentConfig::from_str(SAMPLE).unwrap().protocol;
        assert_eq!(cfg.n_hidden, 256);
        assert_eq!(cfg.trials, 5);
        assert_eq!(cfg.master_seed, 99);
        assert!((cfg.train_frac - 0.8).abs() < 1e-12);
        assert!((cfg.teacher_error - 0.05).abs() < 1e-12);
        assert!(matches!(cfg.pruning, PruningSpec::Auto { x: 7 }));
        assert!(matches!(cfg.variant, Variant::Odl(AlphaKind::Hash)));
    }

    #[test]
    fn fixed_theta_requires_value() {
        let bad = "[pruning]\nmode = \"fixed\"\n";
        assert!(ExperimentConfig::from_str(bad).is_err());
        let good = "[pruning]\nmode = \"fixed\"\ntheta = 0.16\n";
        let cfg = ExperimentConfig::from_str(good).unwrap().protocol;
        assert!(matches!(cfg.pruning, PruningSpec::Fixed(t) if (t - 0.16).abs() < 1e-6));
    }

    #[test]
    fn unknown_variant_rejected() {
        assert!(ExperimentConfig::from_str("[model]\nvariant = \"transformer\"\n").is_err());
    }

    #[test]
    fn fleet_config_parses() {
        let text = r#"
[fleet]
n_edges = 8
horizon_s = 1200.0
detector = "centroid"
seed = 42

[channel]
loss_prob = 0.1
"#;
        let (sc, seed) = fleet_from_str(text).unwrap();
        assert_eq!(sc.n_edges, 8);
        assert_eq!(sc.detector, DetectorKind::Centroid);
        assert!((sc.channel.loss_prob - 0.1).abs() < 1e-12);
        assert_eq!(seed, 42);
    }

    #[test]
    fn defaults_when_sections_missing() {
        let cfg = ExperimentConfig::from_str("").unwrap().protocol;
        assert_eq!(cfg.n_hidden, 128);
        assert_eq!(cfg.trials, 20);
    }
}
